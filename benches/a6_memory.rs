//! Appendix A.6: optimizer memory consumption.
//!
//! Three evidence layers:
//!  1. analytic accounting over the paper's exact layer inventories
//!     (Jorge = 1.5x Adam without grafting, 2x with, in the blocked
//!     square limit);
//!  2. measured `state_floats()` of the live native mirrors;
//!  3. the manifest's state tensors (what the coordinator actually
//!     allocates — native-backend synthesised or HLO-artifact).

use jorge::benchrun::engine;
use jorge::benchx::Table;
use jorge::models;
use jorge::optim::memory::{ratio_vs_adam, state_bytes, OptKind};
use jorge::optim::{build, Hyper};
use jorge::runtime::Role;

fn analytic() {
    let mut table = Table::new(
        "A6a (analytic): optimizer state on paper inventories (512-blocked)",
        &["network", "sgd", "adamw", "jorge", "jorge+graft", "shampoo+graft"],
    );
    for net_name in ["resnet18", "resnet50", "deeplabv3", "maskrcnn"] {
        let net = models::by_name(net_name).unwrap().blocked(512);
        let mb = |o, g| format!("{:.0} MB", state_bytes(&net, o, g) as f64 / 1e6);
        table.row(&[
            net_name.into(),
            mb(OptKind::Sgd, false),
            mb(OptKind::AdamW, false),
            format!("{} ({:.2}x)", mb(OptKind::Jorge, false), ratio_vs_adam(&net, OptKind::Jorge, false)),
            format!("{} ({:.2}x)", mb(OptKind::Jorge, true), ratio_vs_adam(&net, OptKind::Jorge, true)),
            format!("{} ({:.2}x)", mb(OptKind::Shampoo, true), ratio_vs_adam(&net, OptKind::Shampoo, true)),
        ]);
    }
    table.print();
    println!("Paper claim: Jorge = 1.5x Adam (3 states/param), 2x with grafting (4 states/param).");
}

fn measured_mirrors() {
    let mut table = Table::new(
        "A6b (measured): live native-mirror state floats, resnet18 inventory",
        &["optimizer", "state floats", "vs adam"],
    );
    let net = models::resnet18().blocked(512);
    let shapes: Vec<(usize, usize)> = net.layers.iter().map(|l| (l.m, l.n)).collect();
    let adam_floats = build("adamw".parse().unwrap(), &shapes, Hyper::default()).state_floats();
    for opt in ["sgd", "adamw", "jorge", "shampoo"] {
        let o = build(opt.parse().unwrap(), &shapes, Hyper::default());
        table.row(&[
            opt.into(),
            o.state_floats().to_string(),
            format!("{:.2}x", o.state_floats() as f64 / adam_floats as f64),
        ]);
    }
    table.print();
}

fn manifest_states() -> anyhow::Result<()> {
    let engine = engine()?;
    let mut table = Table::new(
        "A6c (manifest): state floats per train artifact (what the coordinator allocates)",
        &["model", "optimizer", "param floats", "state floats", "state/param"],
    );
    for model in ["mlp", "cnn", "segnet", "transformer"] {
        for opt in ["sgd", "adamw", "jorge", "shampoo"] {
            let art = engine.manifest().artifact(&format!("train_{model}_{opt}")).unwrap();
            let p: usize = art
                .inputs
                .iter()
                .filter(|i| i.role == Role::Param)
                .map(|i| i.elements())
                .sum();
            let s: usize = art
                .inputs
                .iter()
                .filter(|i| i.role == Role::State)
                .map(|i| i.elements())
                .sum();
            table.row(&[
                model.into(),
                opt.into(),
                p.to_string(),
                s.to_string(),
                format!("{:.2}", s as f64 / p as f64),
            ]);
        }
    }
    table.print();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    analytic();
    measured_mirrors();
    manifest_states()
}
