//! Figure 1: learning-rate schedules for Jorge — validation metric vs
//! epoch for cosine/poly (the SGD defaults) vs step decay at 1/3 & 2/3.
//!
//! Left plot slot: synth-CIFAR CNN (ResNet-18/CIFAR-10 in the paper).
//! Right plot slot: synth-seg (DeepLabv3/MS-COCO in the paper).
//! Expected shape: the step-decay series dominates after the first decay.

use jorge::benchrun::{base_config, engine, fast, run};
use jorge::benchx::Table;
use jorge::config::ScheduleKind;

fn main() -> anyhow::Result<()> {
    let engine = engine()?;
    let models = if fast() { vec!["segnet"] } else { vec!["cnn", "segnet"] };
    for model in models {
        let mut series: Vec<(String, Vec<f64>)> = Vec::new();
        for kind in [ScheduleKind::Cosine, ScheduleKind::Poly, ScheduleKind::Step] {
            let mut cfg = base_config(model);
            cfg.optimizer = "jorge".parse().unwrap();
            cfg.weight_decay *= 10.0;
            cfg.precond_every = 4;
            cfg.schedule = kind;
            cfg.decay_at = vec![1.0 / 3.0, 2.0 / 3.0];
            cfg.seed = 42;
            let r = run(cfg, engine.clone())?;
            series.push((
                kind.name().to_string(),
                r.epochs.iter().map(|e| e.val_metric).collect(),
            ));
        }
        let mut table = Table::new(
            &format!("Fig 1 ({model}): Jorge validation metric vs epoch by schedule"),
            &["epoch", "cosine", "poly", "step"],
        );
        let n = series[0].1.len();
        for e in 0..n {
            table.row(&[
                e.to_string(),
                format!("{:.4}", series[0].1.get(e).copied().unwrap_or(f64::NAN)),
                format!("{:.4}", series[1].1.get(e).copied().unwrap_or(f64::NAN)),
                format!("{:.4}", series[2].1.get(e).copied().unwrap_or(f64::NAN)),
            ]);
        }
        table.print();
        let best = |i: usize| {
            series[i].1.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        };
        println!(
            "best: cosine {:.4}  poly {:.4}  step {:.4}  (expected: step >= others)",
            best(0),
            best(1),
            best(2)
        );
    }
    Ok(())
}
