//! Figure 2: large-batch training — validation metric vs *epochs* (left)
//! and vs *time* (right) for SGD / AdamW / Jorge / Shampoo / Distributed
//! Shampoo.
//!
//! Left panel: MEASURED epoch trajectories on the synth-CIFAR CNN with 4
//! data-parallel workers (the bs-1024/16-GPU slot of the paper).
//! Right panel: the same trajectories placed on a PROJECTED A100 time
//! axis (measured epochs x perf-model per-iteration times), including the
//! sharded dist-shampoo projection.
//!
//! Expected shape: Jorge ~ Shampoo in epochs; in time Jorge < dist-shampoo
//! < SGD < serial Shampoo.

use jorge::benchrun::{
    base_config, bench_envelope, engine, fast, json_row, run, target_for, tune_for,
    write_bench_json,
};
use jorge::benchx::Table;
use jorge::collectives::CommCostModel;
use jorge::jsonio::Json;
use jorge::models;
use jorge::optim::memory::OptKind;
use jorge::perfmodel::{
    project_dist_shampoo_iteration, project_iteration, project_sharded_iteration, GpuModel,
};

fn main() -> anyhow::Result<()> {
    let engine = engine()?;
    let workers = if fast() { 1 } else { 4 };
    let opts = ["sgd", "adamw", "jorge", "shampoo"];

    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for opt in opts {
        let mut cfg = base_config("cnn");
        tune_for(&mut cfg, opt);
        cfg.workers = workers;
        cfg.dataset_size *= workers; // weak scaling, like the paper
        cfg.precond_every = if matches!(opt, "jorge" | "shampoo") { 4 } else { 1 };
        cfg.seed = 7;
        let r = run(cfg, engine.clone())?;
        series.push((opt.to_string(), r.epochs.iter().map(|e| e.val_metric).collect()));
    }

    let mut left = Table::new(
        &format!("Fig 2-left (measured, {workers} workers): val metric vs epoch"),
        &["epoch", "sgd", "adamw", "jorge", "shampoo"],
    );
    let n = series.iter().map(|s| s.1.len()).max().unwrap_or(0);
    for e in 0..n {
        let mut cells = vec![e.to_string()];
        for (_, s) in &series {
            cells.push(
                s.get(e)
                    .map(|v| format!("{v:.4}"))
                    .unwrap_or_default(),
            );
        }
        left.row(&cells);
    }
    left.print();

    // middle panel: MEASURED owner-computes sharding vs the serial native
    // apply at the same worker count — the real (not projected) step-time
    // win, plus the sharding telemetry that proves refreshes were split
    let mut mid = Table::new(
        &format!("Fig 2-mid (measured, {workers} workers, native apply): preconditioner sharding"),
        &["optimizer", "s/iter serial", "s/iter sharded", "owners", "ag floats", "comm ms"],
    );
    let mut sharded_rows: Vec<Json> = Vec::new();
    for opt in ["shampoo", "jorge"] {
        let mut serial_cfg = base_config("cnn");
        tune_for(&mut serial_cfg, opt);
        serial_cfg.workers = workers;
        serial_cfg.native = workers > 1; // same apply path as the sharded run
        serial_cfg.dataset_size *= workers;
        serial_cfg.seed = 7;
        let serial_r = run(serial_cfg, engine.clone())?;

        let sharded_name = format!("{opt}_sharded");
        let mut cfg = base_config("cnn");
        tune_for(&mut cfg, &sharded_name);
        cfg.workers = workers;
        cfg.dataset_size *= workers;
        cfg.seed = 7;
        let r = run(cfg, engine.clone())?;
        assert_eq!(
            serial_r.step_losses, r.step_losses,
            "{sharded_name} must be bitwise identical to serial {opt}"
        );
        let sh = r.shard.clone().unwrap_or_default();
        let owners = sh.owned_layers.iter().filter(|ls| !ls.is_empty()).count();
        mid.row(&[
            opt.to_string(),
            format!("{:.4}", serial_r.mean_iter_s),
            format!("{:.4}", r.mean_iter_s),
            owners.to_string(),
            sh.allgather_floats.to_string(),
            format!("{:.3}", sh.modeled_comm_s * 1e3),
        ]);
        sharded_rows.push(json_row(
            opt,
            &[
                ("serial_s_iter", serial_r.mean_iter_s),
                ("sharded_s_iter", r.mean_iter_s),
                ("allgather_floats", sh.allgather_floats as f64),
                ("modeled_comm_s", sh.modeled_comm_s),
            ],
        ));
    }
    mid.print();
    let payload = bench_envelope("fig2_sharded", Json::Arr(sharded_rows));
    let path = write_bench_json("fig2_sharded", &payload)?;
    println!("wrote {path}");

    // right panel: projected time axis at paper scale (ResNet-50, 16 A100s)
    let gpu = GpuModel::a100();
    let comm = CommCostModel::nvlink_a100();
    let net = models::by_name("resnet50").unwrap().blocked(1024);
    let anchor = 0.085;
    let steps_per_epoch = 1_281_167.0 / 1024.0; // ImageNet / bs 1024
    let iter_s = |opt| project_iteration(&gpu, &comm, &net, opt, 50, anchor, 16).total();
    let dist_s = project_dist_shampoo_iteration(&gpu, &comm, &net, 50, anchor, 16).total();
    let shard_s = |opt| project_sharded_iteration(&gpu, &comm, &net, opt, 50, anchor, 16).total();

    let target = target_for("cnn");
    let epochs_to = |name: &str| {
        series
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, s)| s.iter().position(|&v| v >= target))
            .map(|e| (e + 1) as f64)
    };
    let mut right = Table::new(
        "Fig 2-right (projected A100 minutes to target, paper-scale epochs-to-target ratio)",
        &["optimizer", "epochs→target (measured)", "s/iter (projected)", "minutes (projected)"],
    );
    let mut entries: Vec<(&str, Option<f64>, f64)> = vec![
        ("sgd", epochs_to("sgd"), iter_s(OptKind::Sgd)),
        ("adamw", epochs_to("adamw"), iter_s(OptKind::AdamW)),
        ("jorge", epochs_to("jorge"), iter_s(OptKind::Jorge)),
        ("shampoo (serial)", epochs_to("shampoo"), iter_s(OptKind::Shampoo)),
        ("dist-shampoo", epochs_to("shampoo"), dist_s),
        ("shampoo_sharded", epochs_to("shampoo"), shard_s(OptKind::Shampoo)),
        ("jorge_sharded", epochs_to("jorge"), shard_s(OptKind::Jorge)),
    ];
    for (name, epochs, it) in entries.drain(..) {
        let minutes = epochs.map(|e| e * steps_per_epoch * it / 60.0);
        right.row(&[
            name.to_string(),
            epochs.map(|e| format!("{e:.0}")).unwrap_or_else(|| "—".into()),
            format!("{it:.3}"),
            minutes.map(|m| format!("{m:.0}")).unwrap_or_else(|| "—".into()),
        ]);
    }
    right.print();
    println!("\nPaper reference: Jorge 239 min < dist-shampoo ~249 < SGD ~319 < serial Shampoo 325.");
    println!("Shape check: Jorge ≈ Shampoo in epochs; in projected time Jorge ≤ dist-shampoo < SGD < serial Shampoo.");
    Ok(())
}
