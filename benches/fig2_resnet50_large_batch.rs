//! Figure 2: large-batch training — validation metric vs *epochs* (left)
//! and vs *time* (right) for SGD / AdamW / Jorge / Shampoo / Distributed
//! Shampoo.
//!
//! Left panel: MEASURED epoch trajectories on the synth-CIFAR CNN with 4
//! data-parallel workers (the bs-1024/16-GPU slot of the paper).
//! Right panel: the same trajectories placed on a PROJECTED A100 time
//! axis (measured epochs x perf-model per-iteration times), including the
//! sharded dist-shampoo projection.
//!
//! Expected shape: Jorge ~ Shampoo in epochs; in time Jorge < dist-shampoo
//! < SGD < serial Shampoo.

use jorge::benchrun::{base_config, engine, fast, run, target_for, tune_for};
use jorge::benchx::Table;
use jorge::collectives::CommCostModel;
use jorge::models;
use jorge::optim::memory::OptKind;
use jorge::perfmodel::{project_dist_shampoo_iteration, project_iteration, GpuModel};

fn main() -> anyhow::Result<()> {
    let engine = engine()?;
    let workers = if fast() { 1 } else { 4 };
    let opts = ["sgd", "adamw", "jorge", "shampoo"];

    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for opt in opts {
        let mut cfg = base_config("cnn");
        tune_for(&mut cfg, opt);
        cfg.workers = workers;
        cfg.dataset_size *= workers; // weak scaling, like the paper
        cfg.precond_every = if matches!(opt, "jorge" | "shampoo") { 4 } else { 1 };
        cfg.seed = 7;
        let r = run(cfg, engine.clone())?;
        series.push((opt.to_string(), r.epochs.iter().map(|e| e.val_metric).collect()));
    }

    let mut left = Table::new(
        &format!("Fig 2-left (measured, {workers} workers): val metric vs epoch"),
        &["epoch", "sgd", "adamw", "jorge", "shampoo"],
    );
    let n = series.iter().map(|s| s.1.len()).max().unwrap_or(0);
    for e in 0..n {
        let mut cells = vec![e.to_string()];
        for (_, s) in &series {
            cells.push(
                s.get(e)
                    .map(|v| format!("{v:.4}"))
                    .unwrap_or_default(),
            );
        }
        left.row(&cells);
    }
    left.print();

    // right panel: projected time axis at paper scale (ResNet-50, 16 A100s)
    let gpu = GpuModel::a100();
    let comm = CommCostModel::nvlink_a100();
    let net = models::by_name("resnet50").unwrap().blocked(1024);
    let anchor = 0.085;
    let steps_per_epoch = 1_281_167.0 / 1024.0; // ImageNet / bs 1024
    let iter_s = |opt| project_iteration(&gpu, &comm, &net, opt, 50, anchor, 16).total();
    let dist_s = project_dist_shampoo_iteration(&gpu, &comm, &net, 50, anchor, 16).total();

    let target = target_for("cnn");
    let epochs_to = |name: &str| {
        series
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, s)| s.iter().position(|&v| v >= target))
            .map(|e| (e + 1) as f64)
    };
    let mut right = Table::new(
        "Fig 2-right (projected A100 minutes to target, paper-scale epochs-to-target ratio)",
        &["optimizer", "epochs→target (measured)", "s/iter (projected)", "minutes (projected)"],
    );
    let mut entries: Vec<(&str, Option<f64>, f64)> = vec![
        ("sgd", epochs_to("sgd"), iter_s(OptKind::Sgd)),
        ("adamw", epochs_to("adamw"), iter_s(OptKind::AdamW)),
        ("jorge", epochs_to("jorge"), iter_s(OptKind::Jorge)),
        ("shampoo (serial)", epochs_to("shampoo"), iter_s(OptKind::Shampoo)),
        ("dist-shampoo", epochs_to("shampoo"), dist_s),
    ];
    for (name, epochs, it) in entries.drain(..) {
        let minutes = epochs.map(|e| e * steps_per_epoch * it / 60.0);
        right.row(&[
            name.to_string(),
            epochs.map(|e| format!("{e:.0}")).unwrap_or_else(|| "—".into()),
            format!("{it:.3}"),
            minutes.map(|m| format!("{m:.0}")).unwrap_or_else(|| "—".into()),
        ]);
    }
    right.print();
    println!("\nPaper reference: Jorge 239 min < dist-shampoo ~249 < SGD ~319 < serial Shampoo 325.");
    println!("Shape check: Jorge ≈ Shampoo in epochs; in projected time Jorge ≤ dist-shampoo < SGD < serial Shampoo.");
    Ok(())
}
