//! Figure 3: validation metric vs epochs for the small-batch benchmarks
//! (SGD / AdamW / Jorge / Shampoo), mean over seeds.
//!
//! Expected shape: Jorge (and Shampoo) reach the target in ~25-40% fewer
//! epochs than SGD; AdamW trails or misses the target.

use jorge::benchrun::{base_config, engine, fast, n_seeds, run, target_for, tune_for};
use jorge::benchx::Table;

fn main() -> anyhow::Result<()> {
    let engine = engine()?;
    let models = if fast() { vec!["mlp"] } else { vec!["mlp", "cnn", "segnet"] };
    let opts = ["sgd", "adamw", "jorge", "shampoo"];
    let seeds: Vec<u64> = (0..n_seeds() as u64).map(|s| 300 + s).collect();

    for model in models {
        // mean trajectory per optimizer
        let mut series: Vec<(String, Vec<f64>)> = Vec::new();
        for opt in opts {
            let mut acc: Vec<f64> = Vec::new();
            for &seed in &seeds {
                let mut cfg = base_config(model);
                tune_for(&mut cfg, opt);
                cfg.seed = seed;
                let r = run(cfg, engine.clone())?;
                for (e, rec) in r.epochs.iter().enumerate() {
                    if acc.len() <= e {
                        acc.push(0.0);
                    }
                    acc[e] += rec.val_metric / seeds.len() as f64;
                }
            }
            series.push((opt.to_string(), acc));
        }

        let mut table = Table::new(
            &format!("Fig 3 ({model}): mean val metric vs epoch ({} seeds)", seeds.len()),
            &["epoch", "sgd", "adamw", "jorge", "shampoo"],
        );
        let n = series.iter().map(|s| s.1.len()).max().unwrap_or(0);
        for e in 0..n {
            let mut cells = vec![e.to_string()];
            for (_, s) in &series {
                cells.push(s.get(e).map(|v| format!("{v:.4}")).unwrap_or_default());
            }
            table.row(&cells);
        }
        table.print();

        let target = target_for(model);
        let to_target: Vec<String> = series
            .iter()
            .map(|(name, s)| {
                match s.iter().position(|&v| v >= target) {
                    Some(e) => format!("{name}: {}", e + 1),
                    None => format!("{name}: —"),
                }
            })
            .collect();
        println!("epochs to target {target:.2}:  {}", to_target.join("   "));
    }
    println!("\nShape check: jorge/shampoo need fewer epochs than sgd; jorge ≈ shampoo.");
    Ok(())
}
