//! Figure 4 (App. A.4): schedule-induced overfitting with Jorge — the
//! cosine/poly schedules reach a *lower training loss* than step decay
//! yet a *worse validation metric*.
//!
//! Runs Jorge under cosine vs step on the cnn (Faster-RCNN slot) and
//! poly vs step on segnet (DeepLabv3 slot), printing both train-loss and
//! val-metric trajectories.

use jorge::benchrun::{base_config, engine, fast, run};
use jorge::benchx::Table;
use jorge::config::ScheduleKind;

fn main() -> anyhow::Result<()> {
    let engine = engine()?;
    let pairs: Vec<(&str, ScheduleKind)> = if fast() {
        vec![("segnet", ScheduleKind::Poly)]
    } else {
        vec![("cnn", ScheduleKind::Cosine), ("segnet", ScheduleKind::Poly)]
    };

    for (model, alt) in pairs {
        let mut results = Vec::new();
        for kind in [alt, ScheduleKind::Step] {
            let mut cfg = base_config(model);
            cfg.optimizer = "jorge".parse().unwrap();
            cfg.weight_decay *= 10.0;
            cfg.precond_every = 4;
            cfg.schedule = kind;
            cfg.seed = 23;
            // longer budget so the schedules fully play out
            cfg.epochs = cfg.epochs * 3 / 2;
            let r = run(cfg, engine.clone())?;
            results.push((kind.name().to_string(), r));
        }
        let mut table = Table::new(
            &format!("Fig 4 ({model}): Jorge train loss + val metric, {} vs step", results[0].0),
            &[
                "epoch",
                &format!("{} loss", results[0].0),
                &format!("{} val", results[0].0),
                "step loss",
                "step val",
            ],
        );
        let n = results[0].1.epochs.len().max(results[1].1.epochs.len());
        for e in 0..n {
            let cell = |r: &jorge::coordinator::RunResult, f: fn(&jorge::coordinator::EpochRecord) -> f64| {
                r.epochs.get(e).map(|rec| format!("{:.4}", f(rec))).unwrap_or_default()
            };
            table.row(&[
                e.to_string(),
                cell(&results[0].1, |r| r.train_loss),
                cell(&results[0].1, |r| r.val_metric),
                cell(&results[1].1, |r| r.train_loss),
                cell(&results[1].1, |r| r.val_metric),
            ]);
        }
        table.print();
        let final_loss =
            |r: &jorge::coordinator::RunResult| r.epochs.last().map(|e| e.train_loss).unwrap_or(f64::NAN);
        println!(
            "{model}: {} final loss {:.4} / best val {:.4}   vs   step final loss {:.4} / best val {:.4}",
            results[0].0,
            final_loss(&results[0].1),
            results[0].1.best_val_metric,
            final_loss(&results[1].1),
            results[1].1.best_val_metric,
        );
        println!("overfitting signature: alt schedule may reach LOWER loss yet NOT beat step on val.\n");
    }
    Ok(())
}
