//! Microbench: the paper's core claim at the op level — inverse-root
//! computation (eigh / coupled Newton) vs Jorge's inverse-free update,
//! as a function of preconditioner dimension.
//!
//! Also benches the GEMM substrate (scaling + threading) since every
//! second-order path reduces to it.

use jorge::benchx::{bench, human_time, Table};
use jorge::rngx::Rng;
use jorge::tensor::{
    gram_left, inv_fourth_root_eigh, inv_fourth_root_newton, jorge_update, matmul, matmul_st,
    Matrix,
};

fn spd(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let g = Matrix::randn(n, n, 1.0, &mut rng);
    let mut s = gram_left(&g);
    s.scale_inplace(1.0 / n as f32);
    for i in 0..n {
        s.data[i * n + i] += 0.1;
    }
    s
}

fn main() {
    let fast = std::env::var("JORGE_FAST").map(|v| v == "1").unwrap_or(false);
    let dims: &[usize] = if fast { &[64, 128] } else { &[64, 128, 256, 512] };

    let mut table = Table::new(
        "Preconditioner update cost vs dimension (the paper's core trade)",
        &["n", "eigh root", "newton root (15 it)", "jorge update", "jorge/newton", "jorge/eigh"],
    );
    for &n in dims {
        let a = spd(n, n as u64);
        let p = Matrix::eye(n, (1e-6f32).powf(-0.25));
        let budget = if fast { 0.2 } else { 0.5 };
        let eigh = bench("eigh", budget, || {
            std::hint::black_box(inv_fourth_root_eigh(&a, 1e-9));
        });
        let newton = bench("newton", budget, || {
            std::hint::black_box(inv_fourth_root_newton(&a, 15, 1e-6));
        });
        let jorge = bench("jorge", budget, || {
            std::hint::black_box(jorge_update(&p, &a));
        });
        table.row(&[
            n.to_string(),
            human_time(eigh.mean_s),
            human_time(newton.mean_s),
            human_time(jorge.mean_s),
            format!("{:.2}x", jorge.mean_s / newton.mean_s),
            format!("{:.2}x", jorge.mean_s / eigh.mean_s),
        ]);
    }
    table.print();
    println!("Shape check: jorge update ≪ eigh at every n; ≈ 1/3 of a 15-iteration Newton root");
    println!("(5 GEMMs vs ~60), which is exactly the FLOP ratio the paper exploits.\n");

    let mut gemm = Table::new(
        "GEMM substrate scaling (single- vs multi-threaded)",
        &["n", "matmul_st", "matmul (threaded)", "speedup", "GFLOP/s (mt)"],
    );
    for &n in dims {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let budget = if fast { 0.2 } else { 0.4 };
        let st = bench("st", budget, || {
            std::hint::black_box(matmul_st(&a, &b));
        });
        let mt = bench("mt", budget, || {
            std::hint::black_box(matmul(&a, &b));
        });
        let gflops = 2.0 * (n as f64).powi(3) / mt.mean_s / 1e9;
        gemm.row(&[
            n.to_string(),
            human_time(st.mean_s),
            human_time(mt.mean_s),
            format!("{:.2}x", st.mean_s / mt.mean_s),
            format!("{gflops:.1}"),
        ]);
    }
    gemm.print();
}
