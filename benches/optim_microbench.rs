//! Microbench: the paper's core claim at the op level — inverse-root
//! computation (eigh / coupled Newton) vs Jorge's inverse-free update,
//! as a function of preconditioner dimension.
//!
//! Also benches the GEMM substrate (scaling + threading) since every
//! second-order path reduces to it.

use jorge::benchrun::{bench_envelope, json_row, write_bench_json};
use jorge::benchx::{bench, human_time, Table};
use jorge::jsonio::Json;
use jorge::rngx::Rng;
use jorge::tensor::{
    gram_left, gram_right, inv_fourth_root_eigh, inv_fourth_root_newton, jorge_update, matmul,
    matmul_bias_relu, matmul_nt, matmul_st, matmul_tn, Matrix,
};
use std::collections::BTreeMap;

fn spd(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let g = Matrix::randn(n, n, 1.0, &mut rng);
    let mut s = gram_left(&g);
    s.scale_inplace(1.0 / n as f32);
    for i in 0..n {
        s.data[i * n + i] += 0.1;
    }
    s
}

fn main() {
    let fast = std::env::var("JORGE_FAST").map(|v| v == "1").unwrap_or(false);
    let dims: &[usize] = if fast { &[64, 128] } else { &[64, 128, 256, 512] };
    let mut precond_rows: Vec<Json> = Vec::new();
    let mut gemm_rows: Vec<Json> = Vec::new();
    let mut kernel_rows: Vec<Json> = Vec::new();

    let mut table = Table::new(
        "Preconditioner update cost vs dimension (the paper's core trade)",
        &["n", "eigh root", "newton root (15 it)", "jorge update", "jorge/newton", "jorge/eigh"],
    );
    for &n in dims {
        let a = spd(n, n as u64);
        let p = Matrix::eye(n, (1e-6f32).powf(-0.25));
        let budget = if fast { 0.2 } else { 0.5 };
        let eigh = bench("eigh", budget, || {
            std::hint::black_box(inv_fourth_root_eigh(&a, 1e-9));
        });
        let newton = bench("newton", budget, || {
            std::hint::black_box(inv_fourth_root_newton(&a, 15, 1e-6));
        });
        let jorge = bench("jorge", budget, || {
            std::hint::black_box(jorge_update(&p, &a));
        });
        table.row(&[
            n.to_string(),
            human_time(eigh.mean_s),
            human_time(newton.mean_s),
            human_time(jorge.mean_s),
            format!("{:.2}x", jorge.mean_s / newton.mean_s),
            format!("{:.2}x", jorge.mean_s / eigh.mean_s),
        ]);
        let mut cells: Vec<(&str, f64)> = vec![("eigh_s", eigh.mean_s)];
        cells.push(("newton_s", newton.mean_s));
        cells.push(("jorge_s", jorge.mean_s));
        precond_rows.push(json_row(&n.to_string(), &cells));
    }
    table.print();
    println!("Shape check: jorge update ≪ eigh at every n; ≈ 1/3 of a 15-iteration Newton root");
    println!("(5 GEMMs vs ~60), which is exactly the FLOP ratio the paper exploits.\n");

    let mut gemm = Table::new(
        "GEMM substrate scaling (single- vs multi-threaded)",
        &["n", "matmul_st", "matmul (threaded)", "speedup", "GFLOP/s (mt)"],
    );
    for &n in dims {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let budget = if fast { 0.2 } else { 0.4 };
        let st = bench("st", budget, || {
            std::hint::black_box(matmul_st(&a, &b));
        });
        let mt = bench("mt", budget, || {
            std::hint::black_box(matmul(&a, &b));
        });
        let gflops = 2.0 * (n as f64).powi(3) / mt.mean_s / 1e9;
        gemm.row(&[
            n.to_string(),
            human_time(st.mean_s),
            human_time(mt.mean_s),
            format!("{:.2}x", st.mean_s / mt.mean_s),
            format!("{gflops:.1}"),
        ]);
        let cells = [("st_s", st.mean_s), ("mt_s", mt.mean_s), ("gflops_mt", gflops)];
        gemm_rows.push(json_row(&n.to_string(), &cells));
    }
    gemm.print();

    // the transpose-free / fused kernels the backward passes run on,
    // plus the threaded grams sitting on every precond update
    let mut kernels = Table::new(
        "GEMM variants (transpose-free, fused epilogue) and threaded grams",
        &["n", "nn", "nt (A B^T)", "tn (A^T B)", "nn+bias+relu", "gram_left", "gram_right"],
    );
    for &n in dims {
        let mut rng = Rng::new(11);
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let bias = Matrix::randn(n, 1, 1.0, &mut rng);
        let g = Matrix::randn(n, n, 1.0, &mut rng);
        let budget = if fast { 0.15 } else { 0.3 };
        let nn = bench("nn", budget, || {
            std::hint::black_box(matmul(&a, &b));
        });
        let nt = bench("nt", budget, || {
            std::hint::black_box(matmul_nt(&a, &b));
        });
        let tn = bench("tn", budget, || {
            std::hint::black_box(matmul_tn(&a, &b));
        });
        let fused = bench("fused", budget, || {
            std::hint::black_box(matmul_bias_relu(&a, &b, &bias));
        });
        let gl = bench("gram_left", budget, || {
            std::hint::black_box(gram_left(&g));
        });
        let gr = bench("gram_right", budget, || {
            std::hint::black_box(gram_right(&g));
        });
        kernels.row(&[
            n.to_string(),
            human_time(nn.mean_s),
            human_time(nt.mean_s),
            human_time(tn.mean_s),
            human_time(fused.mean_s),
            human_time(gl.mean_s),
            human_time(gr.mean_s),
        ]);
        let mut cells: Vec<(&str, f64)> = vec![("nn_s", nn.mean_s), ("nt_s", nt.mean_s)];
        cells.push(("tn_s", tn.mean_s));
        cells.push(("nn_bias_relu_s", fused.mean_s));
        cells.push(("gram_left_s", gl.mean_s));
        cells.push(("gram_right_s", gr.mean_s));
        kernel_rows.push(json_row(&n.to_string(), &cells));
    }
    kernels.print();

    let mut results = BTreeMap::new();
    results.insert("precond_update".to_string(), Json::Arr(precond_rows));
    results.insert("gemm_scaling".to_string(), Json::Arr(gemm_rows));
    results.insert("gemm_kernels".to_string(), Json::Arr(kernel_rows));
    let payload = bench_envelope("microbench", Json::Obj(results));
    let path = write_bench_json("microbench", &payload).expect("write BENCH_microbench.json");
    println!("\nwrote {path}");
}
