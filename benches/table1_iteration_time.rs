//! Table 1: per-iteration wall-clock for SGD / Jorge / Shampoo.
//!
//! Three evidence layers, each printed as a table:
//!  1. MEASURED fused-train-step times of the HLO artifacts on this host
//!     (our models; the real request path the coordinator runs);
//!  2. MEASURED native-mirror optimizer step times on the paper's exact
//!     ResNet-50 / DeepLabv3 layer inventories;
//!  3. PROJECTED A100 iteration times via the perf model, printed next
//!     to the paper's reported numbers.
//!
//! Expected shape: Jorge within ~1-10% of SGD, Shampoo 20-35% slower.

use jorge::benchrun::{
    base_config, bench_envelope, engine, fast, json_row, tune_for, write_bench_json,
};
use jorge::benchx::{bench_n, Table};
use jorge::collectives::CommCostModel;
use jorge::coordinator::Trainer;
use jorge::jsonio::Json;
use jorge::models;
use jorge::optim::memory::OptKind;
use jorge::optim::{build, Hyper, StepCtx};
use jorge::perfmodel::{project_iteration, GpuModel};
use jorge::rngx::Rng;
use jorge::tensor::Matrix;

fn measured_artifact_times() -> anyhow::Result<Vec<Json>> {
    let engine = engine()?;
    let mut table = Table::new(
        "Table 1a (measured): fused HLO train-step s/iter on this host",
        &["model", "sgd", "adamw", "jorge", "shampoo", "jorge/sgd", "shampoo/sgd"],
    );
    let mut rows = Vec::new();
    let opts = ["sgd", "adamw", "jorge", "shampoo"];
    let models = if fast() { vec!["mlp"] } else { vec!["mlp", "cnn", "segnet"] };
    for model in models {
        let mut times = Vec::new();
        let mut tails: Vec<(f64, f64)> = Vec::new();
        for opt in opts {
            let mut cfg = base_config(model);
            tune_for(&mut cfg, opt);
            cfg.epochs = 1;
            cfg.steps_per_epoch = if fast() { 6 } else { 15 };
            cfg.dataset_size = cfg.steps_per_epoch * 64;
            cfg.precond_every = 50; // paper Table 1 setting
            let mut trainer = Trainer::new(cfg, engine.clone())?;
            let r = trainer.run()?;
            // mean_iter_s already excludes the first (compile-heavy)
            // iteration; the percentiles expose refresh-step spikes
            times.push(r.mean_iter_s);
            tails.push((r.iter_p50_s, r.iter_p95_s));
        }
        table.row(&[
            model.to_string(),
            format!("{:.4}", times[0]),
            format!("{:.4}", times[1]),
            format!("{:.4}", times[2]),
            format!("{:.4}", times[3]),
            format!("{:.2}x", times[2] / times[0]),
            format!("{:.2}x", times[3] / times[0]),
        ]);
        let mut cells: Vec<(String, f64)> =
            opts.iter().copied().map(String::from).zip(times.iter().copied()).collect();
        for (opt, &(p50, p95)) in opts.iter().zip(&tails) {
            cells.push((format!("{opt}_p50"), p50));
            cells.push((format!("{opt}_p95"), p95));
        }
        let cell_refs: Vec<(&str, f64)> = cells.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        rows.push(json_row(model, &cell_refs));
    }
    table.print();
    Ok(rows)
}

fn measured_native_times() -> Vec<Json> {
    let mut table = Table::new(
        "Table 1b (measured): native optimizer step on paper layer inventories, ms/iter (precond every 50)",
        &["network", "sgd", "adamw", "jorge", "shampoo"],
    );
    let mut rows = Vec::new();
    let nets = if fast() { vec!["resnet18"] } else { vec!["resnet18", "resnet50", "deeplabv3"] };
    for net_name in nets {
        let net = models::by_name(net_name).unwrap().blocked(256);
        let shapes: Vec<(usize, usize)> = net.layers.iter().map(|l| (l.m, l.n)).collect();
        let mut rng = Rng::new(0);
        let grads: Vec<Matrix> = shapes
            .iter()
            .map(|&(m, n)| Matrix::randn(m, n, 0.01, &mut rng))
            .collect();
        let mut cells = vec![net_name.to_string()];
        let mut json_cells: Vec<(&str, f64)> = Vec::new();
        for opt_name in ["sgd", "adamw", "jorge", "shampoo"] {
            let mut params: Vec<Matrix> = shapes
                .iter()
                .map(|&(m, n)| Matrix::randn(m, n, 0.1, &mut rng))
                .collect();
            let mut opt = build(opt_name.parse().unwrap(), &shapes, Hyper::default());
            // steady state: one update step then amortised skips; measure
            // the 50-step cycle mean
            let mut step_i = 0usize;
            let iters = if fast() { 1 } else { 2 };
            let r = bench_n(opt_name, iters, || {
                let ctx = StepCtx {
                    lr: 0.1,
                    weight_decay: 1e-4,
                    update_precond: step_i % 50 == 0,
                };
                opt.step(&mut params, &grads, ctx);
                step_i += 1;
            });
            cells.push(format!("{:.1}", r.mean_s * 1e3));
            json_cells.push((opt_name, r.mean_s * 1e3));
        }
        table.row(&cells);
        rows.push(json_row(net_name, &json_cells));
    }
    table.print();
    rows
}

fn projected_a100() {
    let gpu = GpuModel::a100();
    let comm = CommCostModel::nvlink_a100();
    let mut table = Table::new(
        "Table 1c (projected A100) vs paper's reported numbers",
        &["network", "bs", "gpus", "optimizer", "projected s/iter", "paper s/iter"],
    );
    let paper: &[(&str, &str, usize, usize, f64, f64)] = &[
        // net, anchor-desc, gpus, precond_every, fwd_bwd anchor, paper value
        ("resnet50", "1024", 16, 50, 0.085, 0.09),
        ("deeplabv3", "64", 4, 50, 0.315, 0.33),
    ];
    for &(net_name, bs, gpus, every, anchor, _) in paper {
        let net = models::by_name(net_name).unwrap().blocked(1024);
        let rows: &[(OptKind, f64)] = match net_name {
            "resnet50" => &[(OptKind::Sgd, 0.09), (OptKind::Jorge, 0.09), (OptKind::Shampoo, 0.12)],
            _ => &[(OptKind::Sgd, 0.33), (OptKind::Jorge, 0.37), (OptKind::Shampoo, 0.47)],
        };
        for &(opt, paper_val) in rows {
            let t = project_iteration(&gpu, &comm, &net, opt, every, anchor, gpus).total();
            table.row(&[
                net_name.into(),
                bs.into(),
                gpus.to_string(),
                opt.name().into(),
                format!("{t:.3}"),
                format!("{paper_val:.2}"),
            ]);
        }
    }
    table.print();
    println!("\nShape check: Jorge ~ SGD (within 10%), Shampoo clearly slower — both measured and projected.");
}

fn main() -> anyhow::Result<()> {
    let artifact_rows = measured_artifact_times()?;
    let native_rows = measured_native_times();
    projected_a100();

    // machine-readable copy for CI artifacts / future perf-PR diffing
    let mut results = std::collections::BTreeMap::new();
    results.insert("train_step_s".to_string(), Json::Arr(artifact_rows));
    results.insert("optimizer_step_ms".to_string(), Json::Arr(native_rows));
    let payload = bench_envelope("table1", Json::Obj(results));
    let path = write_bench_json("table1", &payload)?;
    println!("\nwrote {path}");
    Ok(())
}
