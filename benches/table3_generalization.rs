//! Table 3: maximum validation metric (mean ± std over trials) for
//! SGD, AdamW and Jorge at the full epoch budget, across the synthetic
//! benchmark suite.
//!
//! Expected shape (paper): Jorge >= SGD on most benchmarks; AdamW behind
//! SGD on the vision-style tasks. All Jorge cells use the single-shot
//! bootstrap — no per-task tuning.

use jorge::benchrun::{base_config, engine, fast, n_seeds, pm, run, tune_for};
use jorge::benchx::Table;

fn main() -> anyhow::Result<()> {
    let engine = engine()?;
    let models = if fast() { vec!["mlp"] } else { vec!["mlp", "cnn", "segnet"] };
    let opts = ["sgd", "adamw", "jorge"];
    let seeds: Vec<u64> = (0..n_seeds() as u64).map(|s| 100 + s).collect();

    let mut table = Table::new(
        "Table 3: max validation metric (mean ± std), full epoch budget",
        &["benchmark", "trials", "epochs", "sgd", "adamw", "jorge"],
    );
    for model in models {
        let mut cells = vec![String::new(); 3];
        let mut epochs = 0;
        for (oi, opt) in opts.iter().enumerate() {
            let mut bests = Vec::new();
            for &seed in &seeds {
                let mut cfg = base_config(model);
                tune_for(&mut cfg, opt);
                cfg.seed = seed;
                epochs = cfg.epochs;
                let r = run(cfg, engine.clone())?;
                bests.push(r.best_val_metric);
            }
            cells[oi] = pm(&bests);
        }
        table.row(&[
            model.to_string(),
            seeds.len().to_string(),
            epochs.to_string(),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
        ]);
    }
    table.print();
    println!("\nPaper reference (Table 3): ResNet-50 bs256 — SGD 75.97, AdamW 76.56, Jorge 76.85;");
    println!("DeepLabv3 — SGD 67.19, AdamW 66.26, Jorge 67.12; Mask-RCNN — SGD 38.30, AdamW 36.58, Jorge 38.92.");
    println!("Shape check: Jorge matches or beats SGD; gaps are within noise on at most one task.");
    Ok(())
}
