//! Table 4: total training time to the target validation metric for the
//! small-batch benchmarks (SGD vs AdamW vs Jorge).
//!
//! MEASURED on this host (CPU-PJRT) for the synthetic suite, plus a
//! PROJECTED paper-scale table: measured epochs-to-target x projected
//! A100 per-iteration times from the perf model.

use jorge::benchrun::{base_config, engine, fast, n_seeds, run, target_for, tune_for};
use jorge::benchx::Table;
use jorge::collectives::CommCostModel;
use jorge::models;
use jorge::optim::memory::OptKind;
use jorge::perfmodel::{project_iteration, GpuModel};

fn main() -> anyhow::Result<()> {
    let engine = engine()?;
    let models_list = if fast() { vec!["mlp"] } else { vec!["mlp", "cnn", "segnet"] };
    let opts = ["sgd", "adamw", "jorge"];
    let seeds: Vec<u64> = (0..n_seeds() as u64).map(|s| 200 + s).collect();

    let mut table = Table::new(
        "Table 4 (measured): seconds to target validation metric, small batch",
        &["benchmark", "target", "sgd", "adamw", "jorge", "jorge/sgd"],
    );
    // collect epochs-to-target for the projection below
    let mut epochs_to_target: Vec<(String, [f64; 3])> = Vec::new();

    for model in &models_list {
        let target = target_for(model);
        let mut cells = Vec::new();
        let mut epochs_row = [f64::NAN; 3];
        for (oi, opt) in opts.iter().enumerate() {
            let mut times = Vec::new();
            let mut epochs = Vec::new();
            for &seed in &seeds {
                let mut cfg = base_config(model);
                tune_for(&mut cfg, opt);
                cfg.seed = seed;
                cfg.target_metric = target;
                cfg.epochs *= 2; // allow headroom to reach target
                let r = run(cfg, engine.clone())?;
                match (r.time_to_target_s, r.epochs_to_target) {
                    (Some(t), Some(e)) => {
                        times.push(t);
                        epochs.push(e as f64);
                    }
                    _ => {} // did not converge (AdamW does this in the paper too)
                }
            }
            if times.is_empty() {
                cells.push("did not reach".to_string());
            } else {
                let mean = times.iter().sum::<f64>() / times.len() as f64;
                cells.push(format!("{mean:.1}"));
                epochs_row[oi] = epochs.iter().sum::<f64>() / epochs.len() as f64;
            }
        }
        let ratio = match (cells[0].parse::<f64>(), cells[2].parse::<f64>()) {
            (Ok(s), Ok(j)) => format!("{:.2}x", j / s),
            _ => "—".into(),
        };
        table.row(&[
            model.to_string(),
            format!("{target:.2}"),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
            ratio,
        ]);
        epochs_to_target.push((model.to_string(), epochs_row));
    }
    table.print();

    // projected paper-scale: epochs ratio x A100 iteration time
    let gpu = GpuModel::a100();
    let comm = CommCostModel::nvlink_a100();
    let mut proj = Table::new(
        "Table 4 (projected A100, 4 GPUs): relative total train time (sgd = 1.0)",
        &["benchmark slot", "sgd", "adamw", "jorge", "paper jorge/sgd"],
    );
    for (model, epochs) in &epochs_to_target {
        let (net_name, anchor, paper_ratio) = match model.as_str() {
            "cnn" => ("resnet50", 0.085, 0.78),    // paper: 781/1005
            "segnet" => ("deeplabv3", 0.315, 0.66), // paper: 144/217
            _ => ("resnet50", 0.085, 0.78),
        };
        let net = models::by_name(net_name).unwrap().blocked(1024);
        let iter = |opt| project_iteration(&gpu, &comm, &net, opt, 50, anchor, 4).total();
        let sgd_total = epochs[0] * iter(OptKind::Sgd);
        let cell = |e: f64, t: f64| {
            if e.is_nan() {
                "did not reach".to_string()
            } else {
                format!("{:.2}", e * t / sgd_total)
            }
        };
        proj.row(&[
            model.clone(),
            cell(epochs[0], iter(OptKind::Sgd)),
            cell(epochs[1], iter(OptKind::AdamW)),
            cell(epochs[2], iter(OptKind::Jorge)),
            format!("{paper_ratio:.2}"),
        ]);
    }
    proj.print();
    println!("\nShape check (paper Table 4): Jorge cuts total train time 23-45% vs SGD.");
    Ok(())
}
