//! End-to-end driver: train the decoder transformer LM on the synthetic
//! Markov corpus for a few hundred steps with Jorge, exercising every
//! layer of the stack at once:
//!
//!   L1 jorge-update kernels (Pallas inside the HLO artifacts, or the
//!      `tensor` mirrors on the native backend)
//!   L2 fused fwd/bwd + optimizer train step
//!   L3 coordinator: schedule, update-interval policy, eval, checkpoints
//!
//! Logs the loss curve to CSV; the run recorded in EXPERIMENTS.md §E2E
//! was produced by exactly this binary.
//!
//!     cargo run --release --example e2e_transformer [-- --steps N]

use jorge::config::{ScheduleKind, TrainConfig};
use jorge::coordinator::Trainer;
use jorge::runtime::backend_for;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let steps_per_epoch = 25;
    let epochs = steps.div_ceil(steps_per_epoch);

    let cfg = TrainConfig {
        model: "transformer".into(),
        optimizer: "jorge".parse().unwrap(),
        epochs,
        steps_per_epoch,
        lr: 0.02,
        weight_decay: 1e-3,
        schedule: ScheduleKind::Step,
        decay_at: vec![1.0 / 3.0, 2.0 / 3.0],
        precond_every: 25, // keeps iter time within ~10% of SGD's (§4)
        dataset_size: 8 * steps_per_epoch,
        seed: 1,
        out_dir: "runs".into(),
        ..Default::default()
    };

    let engine = backend_for("artifacts", "auto")?;
    println!(
        "e2e transformer LM: {} params, {} steps, jorge precond_every={} (backend {})",
        engine.manifest().models["transformer"].param_count,
        steps,
        cfg.precond_every,
        engine.platform()
    );
    let mut trainer = Trainer::new(cfg, engine)?;
    let result = trainer.run()?;
    std::fs::create_dir_all("runs")?;
    result.write_csv("runs/e2e_transformer_jorge.csv")?;
    trainer.save_checkpoint("runs/e2e_transformer_jorge.ckpt")?;

    println!("\n== loss curve (per-epoch means) ==");
    println!("{:<6} {:>10} {:>10} {:>10}", "epoch", "train loss", "token acc", "wall s");
    for e in &result.epochs {
        println!(
            "{:<6} {:>10.4} {:>10.4} {:>10.1}",
            e.epoch, e.train_loss, e.val_metric, e.wall_s
        );
    }
    let first = result.step_losses.first().copied().unwrap_or(f32::NAN) as f64;
    let last = result.epochs.last().map(|e| e.train_loss).unwrap_or(f64::NAN);
    println!(
        "\nloss {first:.3} -> {last:.3} over {} steps ({:.2} s/iter mean); csv: runs/e2e_transformer_jorge.csv",
        result.step_losses.len(),
        result.mean_iter_s
    );
    // The Markov corpus's entropy floor (~2 bits/token at the planted
    // 90/10 transition mix) needs a few thousand steps to approach on this
    // host; the e2e bar is steady, significant learning below the uniform
    // baseline (ln 512 = 6.24) — proof that L1/L2/L3 compose correctly.
    assert!(
        last < first - 0.4 && last < 6.2,
        "e2e training failed to learn ({first} -> {last})"
    );
    println!("e2e OK: all three layers compose (loss {first:.2} -> {last:.2}).");
    Ok(())
}
