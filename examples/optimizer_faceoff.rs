//! Optimizer face-off on the synth-CIFAR CNN (the ResNet-50/ImageNet
//! stand-in): SGD vs AdamW vs Shampoo vs Jorge, sample efficiency to a
//! target validation accuracy — the workload the paper's intro motivates.
//!
//!     cargo run --release --example optimizer_faceoff [-- --fast]

use jorge::benchx::Table;
use jorge::config::{ScheduleKind, TrainConfig};
use jorge::coordinator::Trainer;
use jorge::runtime::backend_for;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let (epochs, steps) = if fast { (6, 20) } else { (15, 40) };
    let engine = backend_for("artifacts", "auto")?;

    let base = TrainConfig {
        model: "cnn".into(),
        epochs,
        steps_per_epoch: steps,
        lr: 0.1,
        weight_decay: 1e-4,
        dataset_size: 32 * steps,
        target_metric: 0.60,
        seed: 11,
        eval_every_epochs: 2,
        ..Default::default()
    };

    let mut table = Table::new(
        "Optimizer face-off: synth-CIFAR CNN (target 60% val acc)",
        &["optimizer", "best val", "epochs→target", "s/iter", "total s"],
    );

    for opt in ["sgd", "adamw", "shampoo", "jorge"] {
        let mut cfg = base.clone();
        cfg.optimizer = opt.parse().unwrap();
        match opt {
            "sgd" => cfg.schedule = ScheduleKind::Step,
            "adamw" => {
                cfg.schedule = ScheduleKind::Cosine;
                cfg.lr = 1e-3; // AdamW's own tuned range (paper Table 7)
                cfg.weight_decay = 1e-2;
            }
            // second-order methods: single-shot bootstrap from SGD (§4)
            "shampoo" => {
                cfg.schedule = ScheduleKind::Step;
                cfg.precond_every = 4;
            }
            "jorge" => {
                cfg = TrainConfig::bootstrap_jorge_from_sgd(&base, 0.9);
                cfg.optimizer = "jorge".parse().unwrap();
                cfg.precond_every = 4;
            }
            _ => unreachable!(),
        }
        let result = Trainer::new(cfg, engine.clone())?.run()?;
        table.row(&[
            opt.to_string(),
            format!("{:.4}", result.best_val_metric),
            result
                .epochs_to_target
                .map(|e| e.to_string())
                .unwrap_or_else(|| "—".into()),
            format!("{:.4}", result.mean_iter_s),
            format!("{:.1}", result.total_time_s),
        ]);
    }
    table.print();
    println!("\nExpected shape (paper Fig. 3): Jorge ≈ Shampoo < SGD ≤ AdamW epochs-to-target,");
    println!("with Jorge's s/iter close to SGD's and Shampoo's visibly higher.");
    Ok(())
}
