//! Quickstart: train a small classifier with Jorge, bootstrapped from a
//! well-tuned SGD config exactly as §4 of the paper prescribes.
//!
//!     cargo run --release --example quickstart
//!
//! Demonstrates the public API end to end: config -> single-shot Jorge
//! bootstrap -> Trainer (backend-fused steps) -> metrics. Runs on the
//! native backend out of the box; with `--features pjrt` and
//! `make artifacts` the same code runs through PJRT.

use jorge::config::{ScheduleKind, TrainConfig};
use jorge::coordinator::Trainer;
use jorge::runtime::backend_for;

fn main() -> anyhow::Result<()> {
    // 1. The "well-tuned SGD baseline" for the synthetic MLP benchmark.
    let mut sgd_cfg = TrainConfig {
        model: "mlp".into(),
        optimizer: "sgd".parse().unwrap(),
        epochs: 10,
        steps_per_epoch: 40,
        lr: 0.01,
        weight_decay: 1e-4,
        schedule: ScheduleKind::Cosine, // SGD's own default schedule
        dataset_size: 64 * 40 * 10,     // fresh data every epoch
        seed: 3,
        ..Default::default()
    };

    // 2. Single-shot bootstrap (§4): grafting carries SGD's lr; weight
    //    decay x10 (1/(1-momentum)); schedule switched to step decay at
    //    1/3 and 2/3 of the budget; update interval keeps iteration time
    //    within ~10% of SGD's.
    let mut jorge_cfg = TrainConfig::bootstrap_jorge_from_sgd(&sgd_cfg, 0.9);
    jorge_cfg.precond_every = 10;

    let engine = backend_for("artifacts", "auto")?;
    println!("backend: {}", engine.platform());

    sgd_cfg.target_metric = 0.0; // run the full budget
    let sgd_result = Trainer::new(sgd_cfg, engine.clone())?.run()?;
    let jorge_result = Trainer::new(jorge_cfg, engine)?.run()?;

    println!("\n== quickstart: SGD vs single-shot-tuned Jorge (synthetic MLP) ==");
    println!("{:<10} {:>12} {:>12} {:>12}", "optimizer", "best val", "mean s/iter", "total s");
    for r in [&sgd_result, &jorge_result] {
        println!(
            "{:<10} {:>12.4} {:>12.4} {:>12.1}",
            r.optimizer, r.best_val_metric, r.mean_iter_s, r.total_time_s
        );
    }
    println!(
        "\nJorge reaches {:.1}% vs SGD {:.1}% with per-iteration cost {:.0}% of SGD's.",
        100.0 * jorge_result.best_val_metric,
        100.0 * sgd_result.best_val_metric,
        100.0 * jorge_result.mean_iter_s / sgd_result.mean_iter_s,
    );
    Ok(())
}
