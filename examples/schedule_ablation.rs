//! Learning-rate-schedule ablation for Jorge (paper Fig. 1 / Fig. 4 /
//! App. A.4): cosine and polynomial schedules — the SGD defaults — leave
//! Jorge's sample efficiency on the table; step decay at 1/3 and 2/3
//! recovers it. Runs the same Jorge config under all three schedules on
//! the synth-seg task (the DeepLabv3 slot) and prints the val-metric
//! trajectories plus the overfitting signature (train loss vs val).
//!
//!     cargo run --release --example schedule_ablation

use jorge::benchx::Table;
use jorge::config::{ScheduleKind, TrainConfig};
use jorge::coordinator::Trainer;
use jorge::runtime::backend_for;

fn main() -> anyhow::Result<()> {
    let engine = backend_for("artifacts", "auto")?;
    let schedules = [ScheduleKind::Cosine, ScheduleKind::Poly, ScheduleKind::Step];
    let epochs = 12;

    let mut rows: Vec<(String, jorge::coordinator::RunResult)> = Vec::new();
    for kind in schedules {
        let cfg = TrainConfig {
            model: "segnet".into(),
            optimizer: "jorge".parse().unwrap(),
            epochs,
            steps_per_epoch: 30,
            lr: 0.1,            // the tuned SGD lr for the seg task
            weight_decay: 1e-3, // 10x SGD's 1e-4 (§4)
            schedule: kind,
            precond_every: 4, // paper Table 6 for DeepLabv3
            dataset_size: 16 * 30 * epochs,
            seed: 5,
            ..Default::default()
        };
        let result = Trainer::new(cfg, engine.clone())?.run()?;
        rows.push((kind.name().to_string(), result));
    }

    let mut table = Table::new(
        "Jorge schedule ablation on synth-seg (paper Fig. 1-right)",
        &["epoch", "cosine val", "poly val", "step val"],
    );
    for e in 0..epochs {
        let cells: Vec<String> = std::iter::once(e.to_string())
            .chain(rows.iter().map(|(_, r)| {
                r.epochs
                    .get(e)
                    .map(|rec| format!("{:.4}", rec.val_metric))
                    .unwrap_or_default()
            }))
            .collect();
        table.row(&cells);
    }
    table.print();

    let mut over = Table::new(
        "Overfitting signature (paper Fig. 4): final train loss vs best val",
        &["schedule", "final train loss", "best val"],
    );
    for (name, r) in &rows {
        over.row(&[
            name.clone(),
            format!("{:.4}", r.epochs.last().map(|e| e.train_loss).unwrap_or(f64::NAN)),
            format!("{:.4}", r.best_val_metric),
        ]);
    }
    over.print();
    println!("\nExpected shape: step decay matches/beats cosine & poly on val metric even when");
    println!("they reach a lower train loss — the overfitting pattern of App. A.4.");
    Ok(())
}
