"""AOT lowering driver: JAX/Pallas -> HLO text artifacts + manifest.

Run once at build time (``make artifacts``); the Rust coordinator is
self-contained afterwards. For every (model x optimizer) pair we lower a
full fused train step

    train_step(params..., opt_state..., x, y, lr, wd)
        -> (params'..., opt_state'..., loss, metric)

plus ``_skip`` variants for the second-order optimizers (reuse stale
preconditioners; the Rust coordinator implements the paper's
update-interval hyperparameter by choosing between the two executables
per step), an eval step per model, and standalone kernel artifacts used
by the Rust test-suite for cross-validation of its native mirrors.

Interchange format is HLO *text*: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the ``xla``
crate binds) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import MODELS, ModelDef
from .optim_jax import OPTIMIZERS, Hyper, OptimizerDef

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Init metadata (replicated by the Rust coordinator from the manifest)
# ---------------------------------------------------------------------------

def param_init_meta(model: ModelDef, name: str, shape) -> dict:
    """Initialisation rule for a parameter, recorded in the manifest."""
    if "ln" in name:
        return {"kind": "ones"}
    if name in ("embed", "pos"):
        return {"kind": "normal", "std": 0.02}
    if name.endswith(".b") or (name.startswith("b") and name[1:].isdigit()):
        return {"kind": "zeros"}
    scale = 0.5 if model.name == "transformer" else 1.0
    return {"kind": "he", "fan_in": int(shape[0]), "scale": scale}


def state_init_meta(name: str, hyper: Hyper) -> dict:
    eps = hyper.precond_eps
    if name.endswith((".Lhat", ".Rhat", ".PL", ".PR")):
        return {"kind": "eye", "scale": float(eps ** -0.25)}
    if name.endswith((".Lstat", ".Rstat")):
        return {"kind": "eye", "scale": float(eps)}
    return {"kind": "zeros"}


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(model: ModelDef, opt: OptimizerDef, update_precond: bool):
    n_params = len(model.param_specs)

    def step_fn(*args):
        params = list(args[:n_params])
        state = list(args[n_params:-4])
        x, y, lr, wd = args[-4:]

        def loss_fn(ps):
            return model.loss_and_metric(ps, x, y)

        (loss, metric), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_p, new_s = opt.step(params, state, grads, lr, wd, update_precond)
        return (*new_p, *new_s, loss, metric)

    return step_fn


def make_grad_step(model: ModelDef):
    """Gradient-only step for data-parallel workers: the coordinator
    all-reduces the returned grads, then applies the optimizer via the
    ``apply_*`` artifact (or the native mirror)."""
    n_params = len(model.param_specs)

    def grad_fn(*args):
        params = list(args[:n_params])
        x, y = args[-2:]

        def loss_fn(ps):
            return model.loss_and_metric(ps, x, y)

        (loss, metric), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return (*grads, loss, metric)

    return grad_fn


def make_apply_step(model: ModelDef, opt: OptimizerDef, update_precond: bool):
    """Optimizer-only step: consumes (already reduced) gradients."""
    n_params = len(model.param_specs)

    def apply_fn(*args):
        params = list(args[:n_params])
        rest = args[n_params:]
        grads = list(rest[:n_params])
        state = list(rest[n_params:-2])
        lr, wd = rest[-2:]
        new_p, new_s = opt.step(params, state, grads, lr, wd, update_precond)
        return (*new_p, *new_s)

    return apply_fn


def make_eval_step(model: ModelDef):
    n_params = len(model.param_specs)

    def eval_fn(*args):
        params = list(args[:n_params])
        x, y = args[-2:]
        loss, metric = model.loss_and_metric(params, x, y)
        return (loss, metric)

    return eval_fn


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), DTYPES[dtype])


def _io_entry(name, shape, dtype, role, init=None):
    d = {"name": name, "shape": [int(s) for s in shape], "dtype": dtype, "role": role}
    if init is not None:
        d["init"] = init
    return d


def _batch_specs(model: ModelDef, eval_batch: bool = False):
    xs = list(model.x_shape)
    ys = list(model.y_shape)
    if eval_batch:
        xs[0] = model.eval_batch
        ys[0] = model.eval_batch
    return (xs, model.x_dtype), (ys, model.y_dtype)


def lower_train(model: ModelDef, opt: OptimizerDef, update_precond: bool, out_dir: str):
    suffix = "" if (update_precond or not opt.has_precond) else "_skip"
    art_name = f"train_{model.name}_{opt.name}{suffix}"
    fname = art_name + ".hlo.txt"

    param_specs = list(model.param_specs)
    state_specs = opt.state_spec(param_specs)
    (xs, xd), (ys, yd) = _batch_specs(model)

    inputs = []
    arg_structs = []
    for name, shape in param_specs:
        init = param_init_meta(model, name, shape)
        inputs.append(_io_entry(name, shape, "f32", "param", init))
        arg_structs.append(_spec(shape))
    for name, shape in state_specs:
        init = state_init_meta(name, opt.hyper)
        inputs.append(_io_entry(name, shape, "f32", "state", init))
        arg_structs.append(_spec(shape))
    inputs.append(_io_entry("x", xs, xd, "x"))
    arg_structs.append(_spec(xs, xd))
    inputs.append(_io_entry("y", ys, yd, "y"))
    arg_structs.append(_spec(ys, yd))
    inputs.append(_io_entry("lr", [], "f32", "lr"))
    arg_structs.append(_spec([], "f32"))
    inputs.append(_io_entry("wd", [], "f32", "wd"))
    arg_structs.append(_spec([], "f32"))

    outputs = (
        [_io_entry(n, s, "f32", "param") for n, s in param_specs]
        + [_io_entry(n, s, "f32", "state") for n, s in state_specs]
        + [_io_entry("loss", [], "f32", "loss"), _io_entry("metric", [], "f32", "metric")]
    )

    step_fn = make_train_step(model, opt, update_precond)
    t0 = time.time()
    lowered = jax.jit(step_fn).lower(*arg_structs)
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    dt = time.time() - t0
    print(f"  {fname:44s} {len(text)/1e6:7.2f} MB  {dt:6.1f}s")

    return art_name, {
        "file": fname,
        "kind": "train",
        "model": model.name,
        "optimizer": opt.name,
        "update_precond": bool(update_precond or not opt.has_precond),
        "inputs": inputs,
        "outputs": outputs,
    }


def lower_grad(model: ModelDef, out_dir: str):
    art_name = f"grad_{model.name}"
    fname = art_name + ".hlo.txt"
    (xs, xd), (ys, yd) = _batch_specs(model)

    inputs = []
    arg_structs = []
    for name, shape in model.param_specs:
        inputs.append(_io_entry(name, shape, "f32", "param"))
        arg_structs.append(_spec(shape))
    inputs.append(_io_entry("x", xs, xd, "x"))
    arg_structs.append(_spec(xs, xd))
    inputs.append(_io_entry("y", ys, yd, "y"))
    arg_structs.append(_spec(ys, yd))

    outputs = (
        [_io_entry(f"{n}.grad", s, "f32", "grad") for n, s in model.param_specs]
        + [_io_entry("loss", [], "f32", "loss"), _io_entry("metric", [], "f32", "metric")]
    )

    lowered = jax.jit(make_grad_step(model)).lower(*arg_structs)
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {fname:44s} {len(text)/1e6:7.2f} MB")
    return art_name, {
        "file": fname,
        "kind": "grad",
        "model": model.name,
        "inputs": inputs,
        "outputs": outputs,
    }


def lower_apply(model: ModelDef, opt: OptimizerDef, update_precond: bool, out_dir: str):
    suffix = "" if (update_precond or not opt.has_precond) else "_skip"
    art_name = f"apply_{model.name}_{opt.name}{suffix}"
    fname = art_name + ".hlo.txt"

    param_specs = list(model.param_specs)
    state_specs = opt.state_spec(param_specs)

    inputs = []
    arg_structs = []
    for name, shape in param_specs:
        inputs.append(_io_entry(name, shape, "f32", "param", param_init_meta(model, name, shape)))
        arg_structs.append(_spec(shape))
    for name, shape in param_specs:
        inputs.append(_io_entry(f"{name}.grad", shape, "f32", "grad"))
        arg_structs.append(_spec(shape))
    for name, shape in state_specs:
        inputs.append(_io_entry(name, shape, "f32", "state", state_init_meta(name, opt.hyper)))
        arg_structs.append(_spec(shape))
    inputs.append(_io_entry("lr", [], "f32", "lr"))
    arg_structs.append(_spec([], "f32"))
    inputs.append(_io_entry("wd", [], "f32", "wd"))
    arg_structs.append(_spec([], "f32"))

    outputs = [_io_entry(n, s, "f32", "param") for n, s in param_specs] + [
        _io_entry(n, s, "f32", "state") for n, s in state_specs
    ]

    lowered = jax.jit(make_apply_step(model, opt, update_precond)).lower(*arg_structs)
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {fname:44s} {len(text)/1e6:7.2f} MB")
    return art_name, {
        "file": fname,
        "kind": "apply",
        "model": model.name,
        "optimizer": opt.name,
        "update_precond": bool(update_precond or not opt.has_precond),
        "inputs": inputs,
        "outputs": outputs,
    }


def lower_eval(model: ModelDef, out_dir: str):
    art_name = f"eval_{model.name}"
    fname = art_name + ".hlo.txt"
    (xs, xd), (ys, yd) = _batch_specs(model, eval_batch=True)

    inputs = []
    arg_structs = []
    for name, shape in model.param_specs:
        inputs.append(_io_entry(name, shape, "f32", "param"))
        arg_structs.append(_spec(shape))
    inputs.append(_io_entry("x", xs, xd, "x"))
    arg_structs.append(_spec(xs, xd))
    inputs.append(_io_entry("y", ys, yd, "y"))
    arg_structs.append(_spec(ys, yd))

    outputs = [
        _io_entry("loss", [], "f32", "loss"),
        _io_entry("metric", [], "f32", "metric"),
    ]

    eval_fn = make_eval_step(model)
    lowered = jax.jit(eval_fn).lower(*arg_structs)
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {fname:44s} {len(text)/1e6:7.2f} MB")

    return art_name, {
        "file": fname,
        "kind": "eval",
        "model": model.name,
        "inputs": inputs,
        "outputs": outputs,
    }


def lower_kernels(out_dir: str, hyper: Hyper):
    """Standalone kernel artifacts for Rust-side cross-validation."""
    from .kernels import jorge_update, matmul, precondition
    from .optim_jax import inv_fourth_root_newton

    entries = {}

    def emit(name, fn, arg_structs, inputs, outputs):
        fname = name + ".hlo.txt"
        lowered = jax.jit(fn).lower(*arg_structs)
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        print(f"  {fname:44s} {len(text)/1e6:7.2f} MB")
        entries[name] = {
            "file": fname,
            "kind": "kernel",
            "inputs": inputs,
            "outputs": outputs,
        }

    emit(
        "kernel_matmul",
        lambda a, b: (matmul(a, b, block_m=32, block_n=32, block_k=32),),
        [_spec([48, 32]), _spec([32, 56])],
        [_io_entry("a", [48, 32], "f32", "in"), _io_entry("b", [32, 56], "f32", "in")],
        [_io_entry("out", [48, 56], "f32", "out")],
    )
    emit(
        "kernel_jorge_update",
        lambda p, s: (jorge_update(p, s, block=32),),
        [_spec([64, 64]), _spec([64, 64])],
        [_io_entry("p", [64, 64], "f32", "in"), _io_entry("s", [64, 64], "f32", "in")],
        [_io_entry("out", [64, 64], "f32", "out")],
    )
    emit(
        "kernel_precondition",
        lambda l, g, r: (precondition(l, g, r, block=32),),
        [_spec([64, 64]), _spec([64, 32]), _spec([32, 32])],
        [
            _io_entry("l", [64, 64], "f32", "in"),
            _io_entry("g", [64, 32], "f32", "in"),
            _io_entry("r", [32, 32], "f32", "in"),
        ],
        [_io_entry("out", [64, 32], "f32", "out")],
    )
    emit(
        "kernel_newton_root",
        lambda a: (inv_fourth_root_newton(a, hyper.newton_iters, hyper.precond_eps),),
        [_spec([32, 32])],
        [_io_entry("a", [32, 32], "f32", "in")],
        [_io_entry("out", [32, 32], "f32", "out")],
    )
    return entries


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser(description="Lower Jorge train/eval steps to HLO text")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="mlp,cnn,segnet,transformer")
    ap.add_argument("--optimizers", default="sgd,adamw,shampoo,jorge")
    ap.add_argument("--no-kernels", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    hyper = Hyper()
    model_names = [m for m in args.models.split(",") if m]
    opt_names = [o for o in args.optimizers.split(",") if o]

    manifest = {
        "version": 1,
        "hyper": {
            "beta1": hyper.beta1,
            "sgd_momentum": hyper.sgd_momentum,
            "shampoo_beta2": hyper.shampoo_beta2,
            "precond_eps": hyper.precond_eps,
            "newton_iters": hyper.newton_iters,
            "adam_beta1": hyper.adam_beta1,
            "adam_beta2": hyper.adam_beta2,
            "adam_eps": hyper.adam_eps,
        },
        "models": {},
        "artifacts": {},
    }

    t_start = time.time()
    for mname in model_names:
        model = MODELS[mname]()
        manifest["models"][mname] = {
            "metric": model.metric_name,
            "batch": int(model.x_shape[0]),
            "eval_batch": int(model.eval_batch),
            "x_shape": [int(s) for s in model.x_shape],
            "x_dtype": model.x_dtype,
            "y_shape": [int(s) for s in model.y_shape],
            "y_dtype": model.y_dtype,
            "param_count": int(model.param_count()),
            "params": [
                {"name": n, "shape": [int(a) for a in s]} for n, s in model.param_specs
            ],
        }
        print(f"[{mname}] params={model.param_count():,}")
        for oname in opt_names:
            opt = OPTIMIZERS[oname](hyper)
            name, entry = lower_train(model, opt, True, args.out)
            manifest["artifacts"][name] = entry
            name, entry = lower_apply(model, opt, True, args.out)
            manifest["artifacts"][name] = entry
            if opt.has_precond:
                name, entry = lower_train(model, opt, False, args.out)
                manifest["artifacts"][name] = entry
                name, entry = lower_apply(model, opt, False, args.out)
                manifest["artifacts"][name] = entry
        name, entry = lower_grad(model, args.out)
        manifest["artifacts"][name] = entry
        name, entry = lower_eval(model, args.out)
        manifest["artifacts"][name] = entry

    if not args.no_kernels:
        print("[kernels]")
        manifest["artifacts"].update(lower_kernels(args.out, hyper))

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    n = len(manifest["artifacts"])
    print(f"wrote {n} artifacts + manifest.json in {time.time()-t_start:.1f}s")


if __name__ == "__main__":
    main()
