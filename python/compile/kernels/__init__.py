"""Layer-1 Pallas kernels for the Jorge optimizer (build-time only)."""

from .matmul import matmul, gram_left, gram_right, DEFAULT_BLOCK
from .elementwise import frobenius_sq, poly_m
from .jorge_update import jorge_update, jorge_beta2
from .precondition import precondition

__all__ = [
    "matmul",
    "gram_left",
    "gram_right",
    "frobenius_sq",
    "poly_m",
    "jorge_update",
    "jorge_beta2",
    "precondition",
    "DEFAULT_BLOCK",
]
