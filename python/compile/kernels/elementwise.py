"""Elementwise / reduction Pallas kernels used by the Jorge update.

Two small kernels accompany the GEMMs of ``jorge_update``:

* ``frobenius_sq`` — tiled reduction computing ``sum(X * X)``; the square
  root of this (plus the +1 shift) drives the *dynamic beta2* rule of
  Appendix A.1 (``beta2 = ||X|| / (||X|| + 1)``).
* ``poly_m`` — builds the truncated binomial-series factor
  ``M = I - a*X + b*X^2`` of Algorithm 2 line 6 in one pass, synthesising
  the identity from the global tile coordinates instead of materialising
  an ``I`` matrix in HBM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pad2, _pick_block, _round_up, DEFAULT_BLOCK


def _frob_kernel(x_ref, o_ref):
    # Sequential grid: first block initialises the (1,1) accumulator, every
    # block adds its partial sum. On TPU this is the standard scalar
    # cross-block reduction pattern (accumulator stays in SMEM/VMEM).
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _init():
        o_ref[0, 0] = jnp.zeros((), o_ref.dtype)

    x = x_ref[...].astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(x * x).astype(o_ref.dtype)


def frobenius_sq(
    x: jnp.ndarray, *, block: int = DEFAULT_BLOCK
) -> jnp.ndarray:
    """``sum(x*x)`` over a 2-D array as a tiled Pallas reduction (f32 scalar)."""
    if x.ndim != 2:
        raise ValueError(f"frobenius_sq expects 2-D input, got {x.shape}")
    m, n = x.shape
    bm = _pick_block(m, block)
    bn = _pick_block(n, block)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    x_p = _pad2(x, mp, np_)  # zero padding does not change the sum

    out = pl.pallas_call(
        _frob_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(x_p)
    return out[0, 0]


def _poly_m_kernel(x_ref, x2_ref, ab_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    bm, bn = o_ref.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0) + i * bm
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) + j * bn
    eye = (rows == cols).astype(o_ref.dtype)
    a = ab_ref[0, 0]
    b = ab_ref[0, 1]
    o_ref[...] = eye - a * x_ref[...] + b * x2_ref[...]


def poly_m(
    x: jnp.ndarray,
    x2: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block: int = DEFAULT_BLOCK,
) -> jnp.ndarray:
    """``I - a*x + b*x2`` for square ``x`` with scalars ``a``, ``b``.

    This is the degree-2 truncation of the binomial series
    ``(I + c X)^(-1/4)`` (Eq. 7/8 of the paper) with the dynamic-beta2
    normalisation already folded into ``a`` and ``b`` (Eq. 11).
    """
    if x.shape != x2.shape or x.ndim != 2 or x.shape[0] != x.shape[1]:
        raise ValueError(f"poly_m expects equal square inputs, got {x.shape}, {x2.shape}")
    n = x.shape[0]
    bn = _pick_block(n, block)
    np_ = _round_up(n, bn)
    x_p = _pad2(x, np_, np_)
    x2_p = _pad2(x2, np_, np_)
    ab = jnp.stack([jnp.asarray(a, x.dtype), jnp.asarray(b, x.dtype)]).reshape(1, 2)

    out = pl.pallas_call(
        _poly_m_kernel,
        grid=(np_ // bn, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), x.dtype),
        interpret=True,
    )(x_p, x2_p, ab)
    if np_ != n:
        out = out[:n, :n]
    return out
