"""The Jorge preconditioner update — the paper's compute hot-spot.

Implements Algorithm 2 lines 5-9 with the dynamic-beta2 rule of
Appendix A.1 folded in (Eq. 11):

    X      = P^4 S                    (P = previous inverse-root estimate,
                                       S = G G^T or G^T G gram statistic)
    nx     = ||X||_F
    beta2  = nx / (nx + 1)            (guarantees ||(1-b2)/b2 * X|| < 1)
    P_new  = ((nx+1)/nx)^(1/4) * P @ (I - X/(4 nx) + 5 X^2/(32 nx^2))

The chain is five GEMMs (P^2, P^4, X = P^4 S, X^2, P @ M) plus one tiled
reduction and one elementwise pass — exactly the "only matmuls and
additions" property the paper exploits. The trailing scalar
``((nx+1)/nx)^(1/4)`` is fused into the final GEMM epilogue.

Zero-gradient guard: when ``S`` is (numerically) zero the update is the
identity transformation on ``P`` (Shampoo's EMA with beta2 -> 1), which we
implement with a ``jnp.where`` on the scalar norm rather than a branch so
the lowered HLO stays branch-free.
"""

from __future__ import annotations

import jax.numpy as jnp

from .elementwise import frobenius_sq, poly_m
from .matmul import DEFAULT_BLOCK, matmul

# Below this Frobenius norm the statistic is treated as zero and the
# preconditioner is left untouched.
NORM_FLOOR = 1e-30


def jorge_update(
    p: jnp.ndarray,
    s: jnp.ndarray,
    *,
    block: int = DEFAULT_BLOCK,
) -> jnp.ndarray:
    """One Jorge inverse-root preconditioner update (Eq. 11).

    Args:
      p: current inverse-fourth-root estimate ``\\hat L_{t-1}`` (n x n).
      s: gram statistic ``G G^T`` (left) or ``G^T G`` (right), (n x n).
      block: GEMM tile edge.

    Returns:
      ``\\hat L_t`` (n x n), same dtype as ``p``.
    """
    if p.ndim != 2 or p.shape[0] != p.shape[1] or p.shape != s.shape:
        raise ValueError(f"jorge_update expects square equal shapes, got {p.shape}, {s.shape}")

    kw = dict(block_m=block, block_n=block, block_k=block)
    p2 = matmul(p, p, **kw)
    p4 = matmul(p2, p2, **kw)
    x = matmul(p4, s, **kw)

    nx2 = frobenius_sq(x, block=block)
    nx = jnp.sqrt(nx2)
    safe = nx > NORM_FLOOR
    nx_s = jnp.where(safe, nx, 1.0).astype(p.dtype)

    a = 1.0 / (4.0 * nx_s)
    b = 5.0 / (32.0 * nx_s * nx_s)
    # beta2 = nx/(nx+1)  =>  beta2^(-1/4) = ((nx+1)/nx)^(1/4)
    scale = jnp.power((nx_s + 1.0) / nx_s, 0.25)

    x2 = matmul(x, x, **kw)
    m = poly_m(x, x2, a, b, block=block)
    p_new = matmul(p, m, scale=scale, **kw)

    return jnp.where(safe, p_new, p)


def jorge_beta2(nx: jnp.ndarray) -> jnp.ndarray:
    """The dynamically adjusted beta2 for a statistic of Frobenius norm nx."""
    return nx / (nx + 1.0)
