"""Tiled Pallas matmul kernels — the GEMM substrate for Jorge.

The paper's core claim is that the Jorge preconditioner update is *only*
GEMMs + elementwise ops, which map perfectly onto matrix units (GPU tensor
cores in the paper; the TPU MXU here). These kernels express the paper's
CUDA-threadblock tiling as Pallas ``BlockSpec``s: the grid pipelines
HBM->VMEM tile loads, and the k-innermost grid dimension accumulates into
the output block (the standard Pallas matmul reduction pattern, which on a
real TPU keeps the accumulator resident in VMEM across the k loop).

All kernels are lowered with ``interpret=True``: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute (see DESIGN.md §5);
interpret mode lowers the same schedule to plain HLO loops so the artifacts
run anywhere. Correctness is checked against pure-jnp oracles in
``ref.py`` via pytest/hypothesis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile edge: 128 matches the MXU systolic array (128x128). For the
# small shapes used in tests we clamp tiles to the (padded) operand size.
DEFAULT_BLOCK = 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, requested: int) -> int:
    """Clamp a requested tile edge to the operand size (power-of-two-ish)."""
    if dim >= requested:
        return requested
    # smallest power of two >= dim, capped at requested
    b = 1
    while b < dim:
        b *= 2
    return min(b, requested)


def _mm_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] (+)= a[i,k] @ b[k,j].

    The k axis is the innermost grid dimension; Pallas revisits the same
    output block for every k, so we zero it on the first visit and
    accumulate afterwards. f32 accumulation via ``preferred_element_type``
    keeps bf16 inputs exact enough for the preconditioner chain.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _mm_scaled_kernel(a_ref, b_ref, s_ref, o_ref):
    """Like ``_mm_kernel`` but multiplies the finished block by a scalar.

    Fusing the scalar into the epilogue of the final k step avoids a second
    full pass over the output matrix (the ``beta2^{-1/4}`` factor of
    Algorithm 2 line 6 / Eq. 11).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _scale():
        o_ref[...] = o_ref[...] * s_ref[0, 0]


def _pad2(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK,
    block_n: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    scale: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """``a @ b`` (optionally ``scale * (a @ b)``) as a tiled Pallas kernel.

    Operands are zero-padded up to tile multiples and the result is sliced
    back, so arbitrary (m, k) x (k, n) shapes are accepted. ``scale`` is a
    scalar fused into the epilogue of the last k-step.
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {a.shape} @ {b.shape}")
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")

    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)

    a_p = _pad2(a, mp, kp)
    b_p = _pad2(b, kp, np_)

    grid = (mp // bm, np_ // bn, kp // bk)
    out_dtype = jnp.promote_types(a.dtype, b.dtype)

    if scale is None:
        out = pl.pallas_call(
            _mm_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
            interpret=True,
        )(a_p, b_p)
    else:
        s = jnp.asarray(scale, dtype=out_dtype).reshape(1, 1)
        out = pl.pallas_call(
            _mm_scaled_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
                pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
            interpret=True,
        )(a_p, b_p, s)

    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


def gram_left(g: jnp.ndarray, **kw) -> jnp.ndarray:
    """``G @ G^T`` — the left Shampoo statistic (m x m)."""
    return matmul(g, g.T, **kw)


def gram_right(g: jnp.ndarray, **kw) -> jnp.ndarray:
    """``G^T @ G`` — the right Shampoo statistic (n x n)."""
    return matmul(g.T, g, **kw)
