"""Gradient preconditioning: ``G~ = L^ @ G @ R^`` (Algorithm 2 line 11).

Unlike Shampoo, the stored preconditioners *are already* the inverse fourth
roots, so preconditioning is two plain GEMMs — no inverse anywhere on the
step path. We associate ``(L^ G) R^`` left-to-right: for an (m x n) layer
this costs ``m^2 n + m n^2`` MACs either way, but left-first keeps the
intermediate at (m x n), i.e. the same footprint as the gradient.
"""

from __future__ import annotations

import jax.numpy as jnp

from .matmul import DEFAULT_BLOCK, matmul


def precondition(
    l_hat: jnp.ndarray,
    g: jnp.ndarray,
    r_hat: jnp.ndarray,
    *,
    block: int = DEFAULT_BLOCK,
) -> jnp.ndarray:
    """``l_hat @ g @ r_hat`` as two tiled Pallas GEMMs."""
    m, n = g.shape
    if l_hat.shape != (m, m) or r_hat.shape != (n, n):
        raise ValueError(
            f"precondition shape mismatch: L{l_hat.shape} G{g.shape} R{r_hat.shape}"
        )
    kw = dict(block_m=block, block_n=block, block_k=block)
    lg = matmul(l_hat, g, **kw)
    return matmul(lg, r_hat, **kw)
