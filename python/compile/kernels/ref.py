"""Pure-jnp oracles for the Pallas kernels and the optimizer math.

Everything here is *build-time only*: the eigendecomposition-based
inverse-root is the gold reference the Pallas/Newton paths are validated
against in pytest; nothing in this module is lowered into artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp

NORM_FLOOR = 1e-30


# ---------------------------------------------------------------------------
# Plain linear algebra oracles
# ---------------------------------------------------------------------------

def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(
        jnp.promote_types(a.dtype, b.dtype)
    )


def frobenius_sq_ref(x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    return jnp.sum(xf * xf)


def poly_m_ref(x: jnp.ndarray, x2: jnp.ndarray, a, b) -> jnp.ndarray:
    n = x.shape[0]
    return jnp.eye(n, dtype=x.dtype) - a * x + b * x2


def inv_pth_root_eigh(a: jnp.ndarray, p: int, eps: float = 1e-12) -> jnp.ndarray:
    """``A^{-1/p}`` for symmetric PSD ``A`` via eigendecomposition (oracle)."""
    w, v = jnp.linalg.eigh(a)
    w = jnp.clip(w, eps, None)
    return (v * jnp.power(w, -1.0 / p)[None, :]) @ v.T


# ---------------------------------------------------------------------------
# Jorge update oracle (Eq. 11, degree-2 binomial truncation)
# ---------------------------------------------------------------------------

def jorge_update_ref(p: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """Reference Jorge preconditioner update, identical math to the kernel."""
    p4 = p @ p @ p @ p
    x = p4 @ s
    nx = jnp.sqrt(frobenius_sq_ref(x))
    safe = nx > NORM_FLOOR
    nx_s = jnp.where(safe, nx, 1.0).astype(p.dtype)
    n = p.shape[0]
    m = (
        jnp.eye(n, dtype=p.dtype)
        - x / (4.0 * nx_s)
        + 5.0 * (x @ x) / (32.0 * nx_s * nx_s)
    )
    scale = jnp.power((nx_s + 1.0) / nx_s, 0.25)
    return jnp.where(safe, scale * (p @ m), p)


def precondition_ref(l_hat, g, r_hat) -> jnp.ndarray:
    return l_hat @ g @ r_hat


# ---------------------------------------------------------------------------
# Shampoo oracle: exact EMA statistics + eigh inverse roots
# ---------------------------------------------------------------------------

def shampoo_stats_update(stat: jnp.ndarray, gram: jnp.ndarray, beta2: float):
    """``L_t = beta2 L_{t-1} + (1-beta2) G G^T`` (Alg. 1 lines 5-8)."""
    return beta2 * stat + (1.0 - beta2) * gram


def shampoo_precondition_ref(l_stat, g, r_stat, eps: float = 1e-12):
    """``L^{-1/4} G R^{-1/4}`` with eigh roots — Shampoo's line 11 oracle."""
    li = inv_pth_root_eigh(l_stat, 4, eps)
    ri = inv_pth_root_eigh(r_stat, 4, eps)
    return li @ g @ ri


# ---------------------------------------------------------------------------
# Exact one-step Jorge-vs-Shampoo correspondence oracle
# ---------------------------------------------------------------------------

def exact_inverse_root_update(p_hat: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """The *untruncated* counterpart of jorge_update_ref.

    Computes ``(beta2 * p_hat^{-4} + (1 - beta2) s)^{-1/4}`` with the same
    dynamic beta2 as Jorge but via an exact eigh root; the kernel's result
    should approach this as the statistic norm grows (series terms decay
    as ~1/nx).
    """
    p4 = p_hat @ p_hat @ p_hat @ p_hat
    x = p4 @ s
    nx = jnp.sqrt(frobenius_sq_ref(x))
    beta2 = nx / (nx + 1.0)
    l_exact = beta2 * jnp.linalg.inv(p4) + (1.0 - beta2) * s
    return inv_pth_root_eigh(l_exact, 4)
