"""Layer-2 model definitions (build-time only).

Four workloads mirror the paper's evaluation matrix at simulator scale
(see DESIGN.md §3 for the substitution table):

* ``mlp``         — gaussian-mixture feature classification; the
                    "third benchmark" stand-in (Mask-RCNN slot).
* ``cnn``         — 32x32x3 image classification; the ResNet-50/ImageNet
                    stand-in.
* ``segnet``      — 16x16 dense 8-class segmentation with a mean-IoU
                    metric; the DeepLabv3/MS-COCO stand-in.
* ``transformer`` — causal decoder LM; the end-to-end driver workload
                    (examples/e2e_transformer.rs).

Every parameter is a 2-D matrix (conv kernels collapsed to
``(kh*kw*cin, cout)``, biases/gains to ``(n, 1)``) — the layout §3 of the
paper prescribes for Shampoo-style two-sided preconditioning. Models
reshape internally for their forward pass.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """A workload: parameter inventory + loss/metric function."""

    name: str
    # (name, (m, n)) for every parameter, in flat order.
    param_specs: Tuple[Tuple[str, Tuple[int, int]], ...]
    # x/y example shapes + dtypes for the *train* batch.
    x_shape: Tuple[int, ...]
    x_dtype: str
    y_shape: Tuple[int, ...]
    y_dtype: str
    eval_batch: int
    # loss_and_metric(params, x, y) -> (scalar loss, scalar metric)
    loss_and_metric: Callable[[List[Array], Array, Array], Tuple[Array, Array]]
    init_params: Callable[[jax.Array], List[Array]]
    # Human-readable metric name ("accuracy", "iou", "token_acc").
    metric_name: str = "accuracy"

    def batch_size(self) -> int:
        return self.x_shape[0]

    def param_count(self) -> int:
        return sum(m * n for _, (m, n) in self.param_specs)


def _he(key, shape):
    fan_in = shape[0]
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def _xent(logits: Array, labels: Array) -> Array:
    """Mean softmax cross-entropy; labels int32, last axis = classes."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logz, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def _accuracy(logits: Array, labels: Array) -> Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

MLP_IN, MLP_H1, MLP_H2, MLP_CLASSES = 128, 256, 128, 10


def _mlp_specs():
    return (
        ("w1", (MLP_IN, MLP_H1)),
        ("b1", (MLP_H1, 1)),
        ("w2", (MLP_H1, MLP_H2)),
        ("b2", (MLP_H2, 1)),
        ("w3", (MLP_H2, MLP_CLASSES)),
        ("b3", (MLP_CLASSES, 1)),
    )


def _mlp_init(key):
    specs = _mlp_specs()
    keys = jax.random.split(key, len(specs))
    out = []
    for k, (name, shape) in zip(keys, specs):
        if name.startswith("b"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(_he(k, shape))
    return out


def _mlp_forward(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h = jax.nn.relu(x @ w1 + b1[:, 0])
    h = jax.nn.relu(h @ w2 + b2[:, 0])
    return h @ w3 + b3[:, 0]


def _mlp_loss(params, x, y):
    logits = _mlp_forward(params, x)
    return _xent(logits, y), _accuracy(logits, y)


def make_mlp(batch: int = 64) -> ModelDef:
    return ModelDef(
        name="mlp",
        param_specs=_mlp_specs(),
        x_shape=(batch, MLP_IN),
        x_dtype="f32",
        y_shape=(batch,),
        y_dtype="i32",
        eval_batch=256,
        loss_and_metric=_mlp_loss,
        init_params=_mlp_init,
        metric_name="accuracy",
    )


# ---------------------------------------------------------------------------
# CNN (ResNet-50 stand-in, synth-CIFAR)
# ---------------------------------------------------------------------------

CNN_HW, CNN_CIN, CNN_CLASSES = 32, 3, 10
# (kh, kw, cin, cout) per conv, collapsed to (kh*kw*cin, cout) for optim.
_CNN_CONVS = (
    ("conv1", (3, 3, 3, 8)),
    ("conv2", (3, 3, 8, 16)),
    ("conv3", (3, 3, 16, 32)),
)


def _cnn_specs():
    specs = []
    for name, (kh, kw, ci, co) in _CNN_CONVS:
        specs.append((f"{name}.w", (kh * kw * ci, co)))
        specs.append((f"{name}.b", (co, 1)))
    specs.append(("fc1.w", (32 * 4 * 4, 64)))
    specs.append(("fc1.b", (64, 1)))
    specs.append(("fc2.w", (64, CNN_CLASSES)))
    specs.append(("fc2.b", (CNN_CLASSES, 1)))
    return tuple(specs)


def _cnn_init(key):
    specs = _cnn_specs()
    keys = jax.random.split(key, len(specs))
    out = []
    for k, (name, shape) in zip(keys, specs):
        if name.endswith(".b"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(_he(k, shape))
    return out


def _conv2d(x, w2d, kdims, bias):
    kh, kw, ci, co = kdims
    w = w2d.reshape(kh, kw, ci, co)
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + bias[:, 0]


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _cnn_forward(params, x):
    i = 0
    for _, kdims in _CNN_CONVS:
        x = jax.nn.relu(_conv2d(x, params[i], kdims, params[i + 1]))
        x = _maxpool2(x)
        i += 2
    b = x.shape[0]
    h = x.reshape(b, -1)
    h = jax.nn.relu(h @ params[i] + params[i + 1][:, 0])
    return h @ params[i + 2] + params[i + 3][:, 0]


def _cnn_loss(params, x, y):
    logits = _cnn_forward(params, x)
    return _xent(logits, y), _accuracy(logits, y)


def make_cnn(batch: int = 32) -> ModelDef:
    return ModelDef(
        name="cnn",
        param_specs=_cnn_specs(),
        x_shape=(batch, CNN_HW, CNN_HW, CNN_CIN),
        x_dtype="f32",
        y_shape=(batch,),
        y_dtype="i32",
        eval_batch=128,
        loss_and_metric=_cnn_loss,
        init_params=_cnn_init,
        metric_name="accuracy",
    )


# ---------------------------------------------------------------------------
# SegNet (DeepLabv3 stand-in): dense 8-class prediction + mean IoU
# ---------------------------------------------------------------------------

SEG_HW, SEG_CIN, SEG_CLASSES = 16, 3, 8
_SEG_CONVS = (
    ("conv1", (3, 3, 3, 16)),
    ("conv2", (3, 3, 16, 16)),
    ("head", (1, 1, 16, SEG_CLASSES)),
)


def _seg_specs():
    specs = []
    for name, (kh, kw, ci, co) in _SEG_CONVS:
        specs.append((f"{name}.w", (kh * kw * ci, co)))
        specs.append((f"{name}.b", (co, 1)))
    return tuple(specs)


def _seg_init(key):
    specs = _seg_specs()
    keys = jax.random.split(key, len(specs))
    out = []
    for k, (name, shape) in zip(keys, specs):
        if name.endswith(".b"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(_he(k, shape))
    return out


def _seg_forward(params, x):
    i = 0
    for idx, (_, kdims) in enumerate(_SEG_CONVS):
        x = _conv2d(x, params[i], kdims, params[i + 1])
        if idx < len(_SEG_CONVS) - 1:
            x = jax.nn.relu(x)
        i += 2
    return x  # (B, H, W, C) logits


def mean_iou(pred: Array, labels: Array, classes: int) -> Array:
    """Mean IoU over classes with non-empty union (the paper's seg metric)."""
    ious = []
    weights = []
    for c in range(classes):
        pc = pred == c
        lc = labels == c
        inter = jnp.sum(jnp.logical_and(pc, lc).astype(jnp.float32))
        union = jnp.sum(jnp.logical_or(pc, lc).astype(jnp.float32))
        ious.append(inter / jnp.maximum(union, 1.0))
        weights.append((union > 0).astype(jnp.float32))
    ious = jnp.stack(ious)
    weights = jnp.stack(weights)
    return jnp.sum(ious * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def _seg_loss(params, x, y):
    logits = _seg_forward(params, x)
    loss = _xent(logits, y)
    pred = jnp.argmax(logits, axis=-1)
    return loss, mean_iou(pred, y, SEG_CLASSES)


def make_segnet(batch: int = 16) -> ModelDef:
    return ModelDef(
        name="segnet",
        param_specs=_seg_specs(),
        x_shape=(batch, SEG_HW, SEG_HW, SEG_CIN),
        x_dtype="f32",
        y_shape=(batch, SEG_HW, SEG_HW),
        y_dtype="i32",
        eval_batch=64,
        loss_and_metric=_seg_loss,
        init_params=_seg_init,
        metric_name="iou",
    )


# ---------------------------------------------------------------------------
# Transformer LM (end-to-end driver workload)
# ---------------------------------------------------------------------------

TFM_VOCAB, TFM_D, TFM_LAYERS, TFM_HEADS, TFM_FF, TFM_SEQ = 512, 256, 4, 4, 1024, 64


def _tfm_specs():
    specs = [("embed", (TFM_VOCAB, TFM_D)), ("pos", (TFM_SEQ, TFM_D))]
    for l in range(TFM_LAYERS):
        specs += [
            (f"l{l}.ln1_g", (TFM_D, 1)),
            (f"l{l}.wq", (TFM_D, TFM_D)),
            (f"l{l}.wk", (TFM_D, TFM_D)),
            (f"l{l}.wv", (TFM_D, TFM_D)),
            (f"l{l}.wo", (TFM_D, TFM_D)),
            (f"l{l}.ln2_g", (TFM_D, 1)),
            (f"l{l}.w1", (TFM_D, TFM_FF)),
            (f"l{l}.w2", (TFM_FF, TFM_D)),
        ]
    specs += [("lnf_g", (TFM_D, 1)), ("head", (TFM_D, TFM_VOCAB))]
    return tuple(specs)


def _tfm_init(key):
    specs = _tfm_specs()
    keys = jax.random.split(key, len(specs))
    out = []
    for k, (name, shape) in zip(keys, specs):
        if "ln" in name:
            out.append(jnp.ones(shape, jnp.float32))
        elif name in ("embed", "pos"):
            out.append(jax.random.normal(k, shape, jnp.float32) * 0.02)
        else:
            out.append(_he(k, shape) * 0.5)
    return out


def _layernorm(x, g):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g[:, 0]


def _tfm_forward(params, tokens):
    it = iter(params)
    embed = next(it)
    pos = next(it)
    b, s = tokens.shape
    x = embed[tokens] + pos[None, :s, :]
    dh = TFM_D // TFM_HEADS
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.finfo(jnp.float32).min
    for _ in range(TFM_LAYERS):
        ln1 = next(it)
        wq, wk, wv, wo = next(it), next(it), next(it), next(it)
        ln2 = next(it)
        w1, w2 = next(it), next(it)
        h = _layernorm(x, ln1)
        q = (h @ wq).reshape(b, s, TFM_HEADS, dh).transpose(0, 2, 1, 3)
        k = (h @ wk).reshape(b, s, TFM_HEADS, dh).transpose(0, 2, 1, 3)
        v = (h @ wv).reshape(b, s, TFM_HEADS, dh).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, TFM_D)
        x = x + o @ wo
        h2 = _layernorm(x, ln2)
        x = x + jax.nn.gelu(h2 @ w1) @ w2
    lnf = next(it)
    head = next(it)
    x = _layernorm(x, lnf)
    return x @ head  # (B, S, V)


def _tfm_loss(params, x, y):
    logits = _tfm_forward(params, x)
    return _xent(logits, y), _accuracy(logits, y)


def make_transformer(batch: int = 8) -> ModelDef:
    return ModelDef(
        name="transformer",
        param_specs=_tfm_specs(),
        x_shape=(batch, TFM_SEQ),
        x_dtype="i32",
        y_shape=(batch, TFM_SEQ),
        y_dtype="i32",
        eval_batch=16,
        loss_and_metric=_tfm_loss,
        init_params=_tfm_init,
        metric_name="token_acc",
    )


MODELS = {
    "mlp": make_mlp,
    "cnn": make_cnn,
    "segnet": make_segnet,
    "transformer": make_transformer,
}
