"""Layer-2 optimizer implementations in JAX (build-time only).

Four optimizers, mirroring the paper's evaluation matrix:

* ``sgd``      — heavy-ball SGD with coupled L2 weight decay (the
                 torchvision baseline the paper tunes against).
* ``adamw``    — AdamW with decoupled weight decay.
* ``shampoo``  — Shampoo (Alg. 1) with EMA gram statistics and the
                 inverse-fourth-root computed by a *coupled Newton
                 iteration* (pure GEMMs, so it lowers to plain HLO —
                 the ``eigh`` root lives only in ``kernels.ref`` as the
                 build-time oracle). SGD grafting per Shi et al. 2023.
* ``jorge``    — the paper's contribution (Alg. 2 + App. A.1/A.2):
                 inverse-free preconditioner updates via the Pallas
                 kernels, dynamic beta2, SGD grafting, decoupled weight
                 decay bootstrapped at 10x SGD's.

All optimizers operate on a flat list of 2-D parameter matrices
(N-D tensors are collapsed by the model definitions, exactly as §3 of the
paper prescribes). Parameters with ``min(m, n) == 1`` (biases, layernorm
gains) are not preconditioned — they take the grafted momentum-SGD update
directly; this matches common Shampoo practice for tiny/1-D tensors and is
recorded in DESIGN.md.

The learning rate and weight decay are *runtime scalars*: the Rust
coordinator owns schedules, warmup and the update-interval policy. The
preconditioner update interval is realised as two lowered artifacts per
second-order optimizer (``update_precond`` True/False) selected per step
by the coordinator.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import gram_left, gram_right, jorge_update, precondition
from .kernels.matmul import matmul as pallas_matmul

Array = jnp.ndarray
Params = List[Array]
State = List[Array]

# Norm floor shared by grafting/preconditioning guards.
_EPS = 1e-16


@dataclasses.dataclass(frozen=True)
class Hyper:
    """Static hyperparameters baked into the lowered artifacts.

    lr / weight-decay are runtime inputs; everything here is the paper's
    "universal" set (§4): beta1 = momentum = 0.9, Shampoo beta2 = 0.95,
    epsilon for preconditioner init 1e-6, 15 coupled-Newton iterations.
    """

    beta1: float = 0.9
    sgd_momentum: float = 0.9
    shampoo_beta2: float = 0.95
    precond_eps: float = 1e-6
    newton_iters: int = 15
    adam_beta1: float = 0.9
    adam_beta2: float = 0.999
    adam_eps: float = 1e-8
    # GEMM tile edge for the Pallas kernels inside the Jorge update.
    # Perf pass (EXPERIMENTS.md §Perf): 128 -> 512 is 20x faster under
    # interpret-mode lowering (fewer grid-loop iterations, dots large
    # enough for the CPU backend to thread); 512^2 x 3 tiles = 3 MB still
    # fits a TPU core's 16 MB VMEM, so the schedule remains TPU-valid.
    block: int = 512
    # If False, the Jorge update uses plain jnp matmuls instead of the
    # Pallas kernels (ablation artifacts; numerics identical).
    use_pallas: bool = True


def _is_preconditioned(shape: Tuple[int, int]) -> bool:
    return shape[0] > 1 and shape[1] > 1


def _fnorm(x: Array) -> Array:
    return jnp.sqrt(jnp.sum(x.astype(jnp.float32) ** 2))


# ---------------------------------------------------------------------------
# Coupled Newton inverse-pth-root (used by Shampoo; GEMMs only)
# ---------------------------------------------------------------------------

def inv_fourth_root_newton(a: Array, iters: int, ridge: float) -> Array:
    """``(A + ridge I)^{-1/4}`` via the coupled Newton iteration.

    The iteration from Gupta et al. (2018) App. / Anil et al. (2021):
        z   = (1+p) / (2 ||A||_F),  M0 = z A,  H0 = z^{1/p} I
        Mi  = (1-alpha) I + alpha M_k          (alpha = -1/p)
        M'  = Mi^p M_k,   H' = H_k Mi
    converges with M -> I and H -> A^{-1/p}. Entirely GEMMs, so Shampoo's
    root stays on the GPU/MXU fast path — but note it is *iterative*
    (15 chained GEMM rounds), which is exactly the cost Jorge eliminates.
    """
    n = a.shape[0]
    p = 4
    eye = jnp.eye(n, dtype=a.dtype)
    a = a + ridge * eye
    z = (1.0 + p) / (2.0 * jnp.maximum(_fnorm(a), _EPS))
    alpha = -1.0 / p

    def body(_, carry):
        m, h = carry
        mi = (1.0 - alpha) * eye + alpha * m
        mi2 = mi @ mi
        m_new = (mi2 @ mi2) @ m
        h_new = h @ mi
        return (m_new, h_new)

    m0 = (z * a).astype(a.dtype)
    h0 = (z ** (1.0 / p)) * eye
    _, h = jax.lax.fori_loop(0, iters, body, (m0, h0))
    return h


# ---------------------------------------------------------------------------
# Optimizer definitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OptimizerDef:
    """A named optimizer with explicit flat-state layout.

    ``state_spec`` returns ``(name, shape)`` for every state array, in the
    exact order ``init_state``/``step`` produce them — the AOT manifest
    exposes this layout to the Rust coordinator.
    """

    name: str
    hyper: Hyper
    init_state: Callable[[Params], State]
    state_spec: Callable[[Sequence[Tuple[str, Tuple[int, int]]]], list]
    step: Callable[..., Tuple[Params, State]]
    # True if the optimizer distinguishes precond-update vs skip steps.
    has_precond: bool = False


# -- SGD --------------------------------------------------------------------

def make_sgd(hyper: Hyper = Hyper()) -> OptimizerDef:
    def init_state(params: Params) -> State:
        return [jnp.zeros_like(p) for p in params]

    def state_spec(param_specs):
        return [(f"{n}.mom", s) for n, s in param_specs]

    def step(params, state, grads, lr, wd, update_precond=True):
        new_p, new_s = [], []
        for p, mom, g in zip(params, state, grads):
            g = g + wd * p  # coupled L2 (torchvision SGD)
            mom = hyper.sgd_momentum * mom + g
            new_p.append(p - lr * mom)
            new_s.append(mom)
        return new_p, new_s

    return OptimizerDef("sgd", hyper, init_state, state_spec, step)


# -- AdamW ------------------------------------------------------------------

def make_adamw(hyper: Hyper = Hyper()) -> OptimizerDef:
    def init_state(params: Params) -> State:
        st: State = []
        for p in params:
            st.append(jnp.zeros_like(p))  # exp_avg
            st.append(jnp.zeros_like(p))  # exp_avg_sq
        st.append(jnp.zeros((1, 1), jnp.float32))  # step count
        return st

    def state_spec(param_specs):
        st = []
        for n, s in param_specs:
            st.append((f"{n}.exp_avg", s))
            st.append((f"{n}.exp_avg_sq", s))
        st.append(("adam.t", (1, 1)))
        return st

    def step(params, state, grads, lr, wd, update_precond=True):
        b1, b2, eps = hyper.adam_beta1, hyper.adam_beta2, hyper.adam_eps
        t = state[-1] + 1.0
        bc1 = 1.0 - b1 ** t[0, 0]
        bc2 = 1.0 - b2 ** t[0, 0]
        new_p, new_s = [], []
        for i, (p, g) in enumerate(zip(params, grads)):
            m = state[2 * i]
            v = state[2 * i + 1]
            m = b1 * m + (1.0 - b1) * g
            v = b2 * v + (1.0 - b2) * g * g
            m_hat = m / bc1
            v_hat = v / bc2
            upd = m_hat / (jnp.sqrt(v_hat) + eps)
            new_p.append(p - lr * upd - lr * wd * p)  # decoupled WD
            new_s.extend([m, v])
        new_s.append(t)
        return new_p, new_s

    return OptimizerDef("adamw", hyper, init_state, state_spec, step)


# -- shared grafted weight update (Alg. 3) -----------------------------------

def _grafted_update(p, g, gtilde, mom, gmom, lr, wd, hyper: Hyper, decoupled: bool):
    """Momentum + SGD grafting + weight decay; returns (p', mom', gmom').

    Direction comes from the preconditioned momentum, magnitude from the
    heavy-ball SGD momentum (App. A.2). ``decoupled`` selects Jorge-style
    decoupled weight decay vs Shampoo/SGD-style coupled L2.
    """
    g_sgd = g if decoupled else g + wd * p
    mom = hyper.beta1 * mom + (1.0 - hyper.beta1) * gtilde
    gmom = hyper.sgd_momentum * gmom + g_sgd
    step_dir = mom * (_fnorm(gmom) / jnp.maximum(_fnorm(mom), _EPS)).astype(p.dtype)
    p_new = p - lr * step_dir
    if decoupled:
        p_new = p_new - lr * wd * p
    return p_new, mom, gmom


# -- Shampoo ------------------------------------------------------------------

def make_shampoo(hyper: Hyper = Hyper()) -> OptimizerDef:
    def init_state(params: Params) -> State:
        eps = hyper.precond_eps
        st: State = []
        for p in params:
            m, n = p.shape
            if _is_preconditioned(p.shape):
                st.append(eps * jnp.eye(m, dtype=p.dtype))  # L stat
                st.append(eps * jnp.eye(n, dtype=p.dtype))  # R stat
                st.append(eps ** (-0.25) * jnp.eye(m, dtype=p.dtype))  # L^{-1/4}
                st.append(eps ** (-0.25) * jnp.eye(n, dtype=p.dtype))  # R^{-1/4}
            st.append(jnp.zeros_like(p))  # momentum
            st.append(jnp.zeros_like(p))  # sgd (grafting) momentum
        return st

    def state_spec(param_specs):
        st = []
        for nme, s in param_specs:
            m, n = s
            if _is_preconditioned(s):
                st.append((f"{nme}.Lstat", (m, m)))
                st.append((f"{nme}.Rstat", (n, n)))
                st.append((f"{nme}.PL", (m, m)))
                st.append((f"{nme}.PR", (n, n)))
            st.append((f"{nme}.mom", s))
            st.append((f"{nme}.gmom", s))
        return st

    def step(params, state, grads, lr, wd, update_precond=True):
        b2 = hyper.shampoo_beta2
        new_p, new_s = [], []
        si = 0
        for p, g in zip(params, grads):
            if _is_preconditioned(p.shape):
                lstat, rstat, pl_, pr_ = state[si : si + 4]
                mom, gmom = state[si + 4 : si + 6]
                si += 6
                lstat = b2 * lstat + (1.0 - b2) * (g @ g.T)
                rstat = b2 * rstat + (1.0 - b2) * (g.T @ g)
                if update_precond:
                    # `+ 0.0 * old` keeps the stale roots alive in the
                    # jaxpr so jax does not DCE the corresponding entry
                    # parameters — the artifact signature must match the
                    # manifest for both the full and skip variants.
                    pl_ = inv_fourth_root_newton(
                        lstat, hyper.newton_iters, hyper.precond_eps
                    ) + 0.0 * pl_
                    pr_ = inv_fourth_root_newton(
                        rstat, hyper.newton_iters, hyper.precond_eps
                    ) + 0.0 * pr_
                gtilde = pl_ @ g @ pr_
                p_new, mom, gmom = _grafted_update(
                    p, g, gtilde, mom, gmom, lr, wd, hyper, decoupled=False
                )
                new_s.extend([lstat, rstat, pl_, pr_, mom, gmom])
            else:
                mom, gmom = state[si : si + 2]
                si += 2
                p_new, mom, gmom = _grafted_update(
                    p, g, g, mom, gmom, lr, wd, hyper, decoupled=False
                )
                new_s.extend([mom, gmom])
            new_p.append(p_new)
        return new_p, new_s

    return OptimizerDef("shampoo", hyper, init_state, state_spec, step, has_precond=True)


# -- Jorge --------------------------------------------------------------------

def make_jorge(hyper: Hyper = Hyper()) -> OptimizerDef:
    """The paper's optimizer: Algorithm 2 + dynamic beta2 + grafting."""

    def _jorge_upd(p_hat, g, left: bool):
        if hyper.use_pallas:
            s = gram_left(g, block_m=hyper.block, block_n=hyper.block, block_k=hyper.block) if left else gram_right(
                g, block_m=hyper.block, block_n=hyper.block, block_k=hyper.block
            )
            return jorge_update(p_hat, s, block=hyper.block)
        # jnp ablation path (same math, XLA-native GEMMs)
        from .kernels import ref

        s = g @ g.T if left else g.T @ g
        return ref.jorge_update_ref(p_hat, s)

    def _precondition(l_hat, g, r_hat):
        if hyper.use_pallas:
            return precondition(l_hat, g, r_hat, block=hyper.block)
        return l_hat @ g @ r_hat

    def init_state(params: Params) -> State:
        eps = hyper.precond_eps
        st: State = []
        for p in params:
            m, n = p.shape
            if _is_preconditioned(p.shape):
                st.append(eps ** (-0.25) * jnp.eye(m, dtype=p.dtype))  # L^
                st.append(eps ** (-0.25) * jnp.eye(n, dtype=p.dtype))  # R^
            st.append(jnp.zeros_like(p))  # momentum
            st.append(jnp.zeros_like(p))  # sgd (grafting) momentum
        return st

    def state_spec(param_specs):
        st = []
        for nme, s in param_specs:
            m, n = s
            if _is_preconditioned(s):
                st.append((f"{nme}.Lhat", (m, m)))
                st.append((f"{nme}.Rhat", (n, n)))
            st.append((f"{nme}.mom", s))
            st.append((f"{nme}.gmom", s))
        return st

    def step(params, state, grads, lr, wd, update_precond=True):
        new_p, new_s = [], []
        si = 0
        for p, g in zip(params, grads):
            if _is_preconditioned(p.shape):
                l_hat, r_hat = state[si : si + 2]
                mom, gmom = state[si + 2 : si + 4]
                si += 4
                if update_precond:
                    l_hat = _jorge_upd(l_hat, g, left=True)
                    r_hat = _jorge_upd(r_hat, g, left=False)
                gtilde = _precondition(l_hat, g, r_hat)
                p_new, mom, gmom = _grafted_update(
                    p, g, gtilde, mom, gmom, lr, wd, hyper, decoupled=True
                )
                new_s.extend([l_hat, r_hat, mom, gmom])
            else:
                mom, gmom = state[si : si + 2]
                si += 2
                p_new, mom, gmom = _grafted_update(
                    p, g, g, mom, gmom, lr, wd, hyper, decoupled=True
                )
                new_s.extend([mom, gmom])
            new_p.append(p_new)
        return new_p, new_s

    return OptimizerDef("jorge", hyper, init_state, state_spec, step, has_precond=True)


OPTIMIZERS = {
    "sgd": make_sgd,
    "adamw": make_adamw,
    "shampoo": make_shampoo,
    "jorge": make_jorge,
}
