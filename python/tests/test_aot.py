"""AOT pipeline contracts: lowering works, manifest describes the HLO."""

import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot
from compile.model import MODELS
from compile.optim_jax import OPTIMIZERS, Hyper

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ARTIFACTS = os.path.exists(os.path.join(ART, "manifest.json"))

needs_artifacts = pytest.mark.skipif(
    not HAVE_ARTIFACTS, reason="run `make artifacts` first"
)


def _load_manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_train_step_runs_and_matches_composed_semantics():
    """The fused train step == value_and_grad + opt.step composed by hand."""
    model = MODELS["mlp"](batch=8)
    opt = OPTIMIZERS["sgd"](Hyper())
    step_fn = aot.make_train_step(model, opt, True)

    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    state = opt.init_state(params)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, size=(8,)), jnp.int32)

    out = step_fn(*params, *state, x, y, jnp.float32(0.1), jnp.float32(1e-4))
    n = len(params)
    new_p = out[:n]
    loss, metric = out[-2], out[-1]

    (loss2, metric2), grads = jax.value_and_grad(
        lambda ps: model.loss_and_metric(ps, x, y), has_aux=True
    )(params)
    exp_p, _ = opt.step(params, state, grads, 0.1, 1e-4)
    np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-6)
    np.testing.assert_allclose(float(metric), float(metric2), rtol=1e-6)
    for a, b in zip(new_p, exp_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_lowering_produces_parseable_hlo_text(tmp_path):
    model = MODELS["mlp"](batch=4)
    opt = OPTIMIZERS["sgd"](Hyper())
    name, entry = aot.lower_train(model, opt, True, str(tmp_path))
    text = (tmp_path / entry["file"]).read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    n_inputs = len(entry["inputs"])
    assert f"parameter({n_inputs - 1})" in text


def test_init_meta_rules():
    model = MODELS["transformer"]()
    meta = {n: aot.param_init_meta(model, n, s) for n, s in model.param_specs}
    assert meta["embed"]["kind"] == "normal"
    assert meta["l0.ln1_g"]["kind"] == "ones"
    assert meta["l0.wq"]["kind"] == "he"
    assert meta["l0.wq"]["scale"] == 0.5
    mlp = MODELS["mlp"]()
    assert aot.param_init_meta(mlp, "b1", (256, 1))["kind"] == "zeros"
    assert aot.param_init_meta(mlp, "w1", (128, 256)) == {
        "kind": "he", "fan_in": 128, "scale": 1.0,
    }


def test_state_init_meta_rules():
    h = Hyper()
    assert aot.state_init_meta("w.Lhat", h)["kind"] == "eye"
    np.testing.assert_allclose(
        aot.state_init_meta("w.Lhat", h)["scale"], h.precond_eps ** -0.25
    )
    assert aot.state_init_meta("w.Lstat", h) == {"kind": "eye", "scale": h.precond_eps}
    assert aot.state_init_meta("w.mom", h) == {"kind": "zeros"}
    assert aot.state_init_meta("adam.t", h) == {"kind": "zeros"}


@needs_artifacts
def test_manifest_covers_full_matrix():
    man = _load_manifest()
    arts = man["artifacts"]
    for m in ["mlp", "cnn", "segnet", "transformer"]:
        for o in ["sgd", "adamw"]:
            assert f"train_{m}_{o}" in arts
        for o in ["shampoo", "jorge"]:
            assert f"train_{m}_{o}" in arts
            assert f"train_{m}_{o}_skip" in arts
        assert f"eval_{m}" in arts
    for k in ["kernel_matmul", "kernel_jorge_update", "kernel_precondition", "kernel_newton_root"]:
        assert k in arts


@needs_artifacts
def test_manifest_io_is_consistent():
    man = _load_manifest()
    for name, art in man["artifacts"].items():
        assert os.path.exists(os.path.join(ART, art["file"])), name
        if art["kind"] != "train":
            continue
        ins = art["inputs"]
        outs = art["outputs"]
        # outputs mirror inputs minus (x, y, lr, wd) plus (loss, metric)
        assert len(outs) == len(ins) - 4 + 2, name
        roles = [i["role"] for i in ins]
        assert roles[-4:] == ["x", "y", "lr", "wd"], name
        for i in ins:
            if i["role"] in ("param", "state"):
                assert "init" in i, f"{name}:{i['name']}"
        # params/state shapes appear identically in outputs
        for a, b in zip(ins[: len(outs) - 2], outs[:-2]):
            assert a["name"] == b["name"] and a["shape"] == b["shape"], name


@needs_artifacts
def test_hlo_parameter_count_matches_manifest():
    """jax DCEs unused args; every artifact's HLO entry must still carry
    exactly the parameters the manifest promises (the Rust runtime feeds
    one buffer per manifest input)."""
    import re

    man = _load_manifest()
    for name, art in man["artifacts"].items():
        text = open(os.path.join(ART, art["file"])).read()
        entry = text[text.index("ENTRY"):]
        params = set(re.findall(r"parameter\((\d+)\)", entry))
        assert len(params) == len(art["inputs"]), (
            f"{name}: HLO has {len(params)} params, manifest {len(art['inputs'])}"
        )


@needs_artifacts
def test_manifest_jorge_memory_factor():
    """App A.6 accounting: Jorge state = mom + gmom (2x params) plus the
    two square preconditioners per 2-D layer. The exact count must follow
    that formula; the paper's 1.5-2x-of-Adam band is reproduced on the
    ResNet-50 shape inventory by `cargo bench --bench a6_memory` (our
    transformer has square-ish layers, so its factor is larger)."""
    man = _load_manifest()
    for model in ["transformer", "mlp", "cnn", "segnet"]:
        art = man["artifacts"][f"train_{model}_jorge"]
        params = [i for i in art["inputs"] if i["role"] == "param"]
        pcount = sum(np.prod(i["shape"]) for i in params)
        scount = sum(
            np.prod(i["shape"]) for i in art["inputs"] if i["role"] == "state"
        )
        expected = 2 * pcount + sum(
            i["shape"][0] ** 2 + i["shape"][1] ** 2
            for i in params
            if i["shape"][0] > 1 and i["shape"][1] > 1
        )
        assert scount == expected, (model, scount, expected)
        adam = 2 * pcount
        assert scount > 1.2 * adam, model
