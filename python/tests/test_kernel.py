"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes (including non-tile-multiples), scales
(ill-conditioned statistics) and block sizes; every kernel must match its
``ref.py`` oracle to f32 tolerance.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    frobenius_sq,
    gram_left,
    gram_right,
    jorge_update,
    matmul,
    poly_m,
    precondition,
)
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=48)
BLOCKS = st.sampled_from([8, 16, 32])
SCALES = st.sampled_from([1e-3, 1.0, 1e3])


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def _allclose(a, b, rtol=1e-4, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, block=BLOCKS, scale=SCALES, seed=st.integers(0, 2**31))
def test_matmul_matches_ref(m, k, n, block, scale, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, m, k, scale=scale)
    b = _rand(rng, k, n)
    got = matmul(a, b, block_m=block, block_n=block, block_k=block)
    want = ref.matmul_ref(a, b)
    assert got.shape == (m, n)
    _allclose(got, want, rtol=1e-4, atol=1e-4 * scale)


def test_matmul_scaled_epilogue():
    rng = np.random.default_rng(0)
    a = _rand(rng, 33, 17)
    b = _rand(rng, 17, 29)
    got = matmul(a, b, block_m=16, block_n=16, block_k=16, scale=jnp.float32(2.5))
    _allclose(got, 2.5 * ref.matmul_ref(a, b), rtol=1e-4)


def test_matmul_rejects_bad_shapes():
    a = jnp.zeros((3, 4), jnp.float32)
    b = jnp.zeros((5, 6), jnp.float32)
    with pytest.raises(ValueError):
        matmul(a, b)
    with pytest.raises(ValueError):
        matmul(jnp.zeros((3,), jnp.float32), b)


def test_matmul_identity():
    rng = np.random.default_rng(1)
    a = _rand(rng, 20, 20)
    eye = jnp.eye(20, dtype=jnp.float32)
    _allclose(matmul(a, eye, block_m=8, block_n=8, block_k=8), a, rtol=1e-5)


def test_matmul_bf16_inputs_accumulate_in_f32():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(32, 64)), jnp.bfloat16)
    b = jnp.asarray(rng.normal(size=(64, 32)), jnp.bfloat16)
    got = matmul(a, b, block_m=16, block_n=16, block_k=16)
    want = jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=5e-2, atol=5e-2
    )


def test_gram_kernels():
    rng = np.random.default_rng(3)
    g = _rand(rng, 21, 13)
    _allclose(gram_left(g, block_m=8, block_n=8, block_k=8), g @ g.T, rtol=1e-4)
    _allclose(gram_right(g, block_m=8, block_n=8, block_k=8), g.T @ g, rtol=1e-4)


def test_gram_left_symmetric_psd():
    rng = np.random.default_rng(4)
    g = _rand(rng, 24, 9)
    s = np.asarray(gram_left(g, block_m=8, block_n=8, block_k=8))
    np.testing.assert_allclose(s, s.T, rtol=1e-5, atol=1e-5)
    w = np.linalg.eigvalsh(0.5 * (s + s.T))
    assert w.min() >= -1e-3


# ---------------------------------------------------------------------------
# frobenius / poly_m
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(m=DIMS, n=DIMS, block=BLOCKS, scale=SCALES, seed=st.integers(0, 2**31))
def test_frobenius_matches_ref(m, n, block, scale, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, n, scale=scale)
    got = frobenius_sq(x, block=block)
    want = ref.frobenius_sq_ref(x)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4)


def test_frobenius_zero():
    assert float(frobenius_sq(jnp.zeros((7, 5), jnp.float32), block=8)) == 0.0


@settings(max_examples=25, deadline=None)
@given(n=DIMS, block=BLOCKS, seed=st.integers(0, 2**31))
def test_poly_m_matches_ref(n, block, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, n, n)
    x2 = jnp.asarray(np.asarray(x) @ np.asarray(x))
    a, b = 0.25, 5.0 / 32.0
    got = poly_m(x, x2, a, b, block=block)
    want = ref.poly_m_ref(x, x2, a, b)
    _allclose(got, want, rtol=1e-4, atol=1e-5)


def test_poly_m_identity_at_zero():
    n = 17
    z = jnp.zeros((n, n), jnp.float32)
    got = poly_m(z, z, 0.25, 0.15, block=8)
    _allclose(got, jnp.eye(n), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# jorge_update
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(2, 32),
    n=st.integers(2, 32),
    block=BLOCKS,
    scale=SCALES,
    seed=st.integers(0, 2**31),
)
def test_jorge_update_matches_ref(m, n, block, scale, seed):
    rng = np.random.default_rng(seed)
    g = _rand(rng, m, n, scale=scale)
    p = jnp.asarray((1e-6) ** -0.25 * np.eye(m), jnp.float32)
    s = jnp.asarray(np.asarray(g) @ np.asarray(g).T)
    got = jorge_update(p, s, block=block)
    want = ref.jorge_update_ref(p, s)
    # relative comparison — entries are O(eps^-1/4) ~ 31.6
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4 * float(np.abs(want).max())
    )


def test_jorge_update_zero_gradient_is_identity():
    p = jnp.asarray(5.0 * np.eye(12), jnp.float32)
    s = jnp.zeros((12, 12), jnp.float32)
    got = jorge_update(p, s, block=8)
    _allclose(got, p, rtol=0, atol=0)


def test_jorge_update_preserves_symmetry():
    rng = np.random.default_rng(7)
    g = _rand(rng, 16, 8)
    p = jnp.asarray((1e-6) ** -0.25 * np.eye(16), jnp.float32)
    s = jnp.asarray(np.asarray(g) @ np.asarray(g).T)
    out = np.asarray(jorge_update(p, s, block=8))
    np.testing.assert_allclose(out, out.T, rtol=1e-3, atol=1e-2)


def test_jorge_update_approaches_exact_root_after_burn_in():
    """After several updates on a fixed statistic, P ~ (EMA limit)^{-1/4}.

    With a constant gram statistic S and dynamic beta2, the fixed point of
    the exact recursion is P = S^{-1/4}-ish; the truncated series tracks
    the exact inverse-root update to O(1/nx) per step. We check the kernel
    stays within a few percent of the exact-root recursion run in
    parallel.
    """
    rng = np.random.default_rng(11)
    g = _rand(rng, 12, 12)
    s = jnp.asarray(np.asarray(g) @ np.asarray(g).T + 0.1 * np.eye(12), jnp.float32)
    p_kernel = jnp.asarray((1e-2) ** -0.25 * np.eye(12), jnp.float32)
    for _ in range(8):
        p_exact = ref.exact_inverse_root_update(p_kernel, s)
        p_kernel = jorge_update(p_kernel, s, block=8)
        rel = float(
            np.abs(np.asarray(p_kernel) - np.asarray(p_exact)).max()
            / np.abs(np.asarray(p_exact)).max()
        )
        assert rel < 0.2, f"kernel diverged from exact root: rel={rel}"


def test_jorge_dynamic_beta2_keeps_series_valid():
    """beta2 = nx/(nx+1) implies ||(1-b2)/b2 * X||_F = 1 exactly at the
    boundary; the normalised series argument X/nx has Frobenius norm 1, so
    the spectral norm is <= 1 and the binomial expansion is valid."""
    rng = np.random.default_rng(13)
    g = _rand(rng, 10, 6, scale=100.0)
    s = np.asarray(g) @ np.asarray(g).T
    p = (1e-6) ** -0.25 * np.eye(10)
    x = np.linalg.matrix_power(p, 4) @ s
    nx = np.sqrt((x * x).sum())
    beta2 = nx / (nx + 1.0)
    arg = (1 - beta2) / beta2 * x
    assert np.sqrt((arg * arg).sum()) <= 1.0 + 1e-5
    # spectral norm <= frobenius norm
    assert np.linalg.norm(arg, 2) <= 1.0 + 1e-5


# ---------------------------------------------------------------------------
# precondition
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 40), n=st.integers(1, 40), block=BLOCKS, seed=st.integers(0, 2**31))
def test_precondition_matches_ref(m, n, block, seed):
    rng = np.random.default_rng(seed)
    l = _rand(rng, m, m)
    g = _rand(rng, m, n)
    r = _rand(rng, n, n)
    got = precondition(l, g, r, block=block)
    want = ref.precondition_ref(l, g, r)
    _allclose(got, want, rtol=1e-3, atol=1e-3)


def test_precondition_shape_mismatch():
    l = jnp.zeros((4, 4), jnp.float32)
    g = jnp.zeros((5, 3), jnp.float32)
    r = jnp.zeros((3, 3), jnp.float32)
    with pytest.raises(ValueError):
        precondition(l, g, r)


# ---------------------------------------------------------------------------
# Newton root (Shampoo's in-artifact inverse root) vs eigh oracle
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 24), seed=st.integers(0, 2**31), cond=st.sampled_from([1.0, 10.0, 1e3]))
def test_newton_root_matches_eigh(n, seed, cond):
    from compile.optim_jax import inv_fourth_root_newton

    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    w = np.linspace(1.0, cond, n)
    a = jnp.asarray(q @ np.diag(w) @ q.T, jnp.float32)
    got = inv_fourth_root_newton(a, iters=30, ridge=1e-9)
    want = ref.inv_pth_root_eigh(np.asarray(a, np.float64), 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-2, atol=1e-3)


def test_newton_root_identity():
    from compile.optim_jax import inv_fourth_root_newton

    eye = jnp.eye(8, dtype=jnp.float32)
    got = inv_fourth_root_newton(eye, iters=20, ridge=0.0)
    np.testing.assert_allclose(np.asarray(got), np.eye(8), rtol=1e-4, atol=1e-4)
