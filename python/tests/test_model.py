"""L2 model contracts: shapes, losses, metrics, trainability."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.model import MODELS, mean_iou
from compile.optim_jax import Hyper, make_sgd


def _batch(model, rng, batch=None):
    xs = list(model.x_shape)
    ys = list(model.y_shape)
    if batch is not None:
        xs[0] = batch
        ys[0] = batch
    if model.x_dtype == "f32":
        x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    else:
        x = jnp.asarray(rng.integers(0, 512, size=xs), jnp.int32)
    classes = {"mlp": 10, "cnn": 10, "segnet": 8, "transformer": 512}[model.name]
    y = jnp.asarray(rng.integers(0, classes, size=ys), jnp.int32)
    return x, y


@pytest.mark.parametrize("name", list(MODELS))
def test_param_specs_are_2d_and_counted(name):
    model = MODELS[name]()
    total = 0
    for pname, shape in model.param_specs:
        assert len(shape) == 2, f"{pname} not 2-D"
        assert shape[0] >= 1 and shape[1] >= 1
        total += shape[0] * shape[1]
    assert total == model.param_count()


@pytest.mark.parametrize("name", list(MODELS))
def test_init_matches_specs(name):
    model = MODELS[name]()
    params = model.init_params(jax.random.PRNGKey(0))
    assert len(params) == len(model.param_specs)
    for p, (_, shape) in zip(params, model.param_specs):
        assert tuple(p.shape) == tuple(shape)
        assert p.dtype == jnp.float32


@pytest.mark.parametrize("name", list(MODELS))
def test_loss_and_metric_are_finite_scalars(name):
    model = MODELS[name]()
    rng = np.random.default_rng(0)
    params = model.init_params(jax.random.PRNGKey(1))
    x, y = _batch(model, rng)
    loss, metric = model.loss_and_metric(params, x, y)
    assert loss.shape == () and metric.shape == ()
    assert np.isfinite(float(loss)) and np.isfinite(float(metric))
    assert 0.0 <= float(metric) <= 1.0


@pytest.mark.parametrize("name", list(MODELS))
def test_initial_loss_near_uniform(name):
    """Fresh models should be near chance level: loss ≈ log(C)."""
    model = MODELS[name]()
    classes = {"mlp": 10, "cnn": 10, "segnet": 8, "transformer": 512}[name]
    rng = np.random.default_rng(2)
    params = model.init_params(jax.random.PRNGKey(2))
    x, y = _batch(model, rng)
    loss, _ = model.loss_and_metric(params, x, y)
    # He-init on unnormalised synthetic inputs inflates logits a little for
    # the conv nets; "near chance" here means within a small factor.
    assert float(loss) < 5.0 * np.log(classes)


@pytest.mark.parametrize("name", list(MODELS))
def test_gradients_flow_to_all_params(name):
    model = MODELS[name]()
    rng = np.random.default_rng(3)
    params = model.init_params(jax.random.PRNGKey(3))
    x, y = _batch(model, rng)
    grads = jax.grad(lambda ps: model.loss_and_metric(ps, x, y)[0])(params)
    for g, (pname, _) in zip(grads, model.param_specs):
        assert float(jnp.abs(g).max()) > 0.0, f"dead gradient for {pname}"


# segnet memorises uniform-random per-pixel labels only partially (2.9k
# params vs 4096 labels), hence the looser factor and bigger budget.
@pytest.mark.parametrize(
    "name,lr,steps,factor", [("mlp", 0.05, 80, 0.7), ("segnet", 0.5, 200, 0.75)]
)
def test_few_sgd_steps_reduce_loss(name, lr, steps, factor):
    """Overfit a single fixed batch — the loss must drop fast."""
    model = MODELS[name]()
    rng = np.random.default_rng(4)
    params = model.init_params(jax.random.PRNGKey(4))
    x, y = _batch(model, rng)
    opt = make_sgd(Hyper())
    state = opt.init_state(params)
    loss0 = float(model.loss_and_metric(params, x, y)[0])
    step = jax.jit(
        lambda ps, st: (
            lambda g: opt.step(ps, st, g, lr, 0.0)
        )(jax.grad(lambda q: model.loss_and_metric(q, x, y)[0])(ps))
    )
    for _ in range(steps):
        params, state = step(params, state)
    loss1 = float(model.loss_and_metric(params, x, y)[0])
    assert loss1 < factor * loss0, f"{loss0} -> {loss1}"


def test_mean_iou_perfect_and_disjoint():
    y = jnp.asarray(np.random.default_rng(5).integers(0, 8, size=(4, 16, 16)), jnp.int32)
    assert float(mean_iou(y, y, 8)) == 1.0
    pred = (y + 1) % 8
    assert float(mean_iou(pred, y, 8)) == 0.0


def test_transformer_causality():
    """Changing a future token must not change past logits."""
    from compile.model import _tfm_forward

    model = MODELS["transformer"]()
    params = model.init_params(jax.random.PRNGKey(6))
    rng = np.random.default_rng(6)
    x1 = jnp.asarray(rng.integers(0, 512, size=(1, 64)), jnp.int32)
    x2 = x1.at[0, 40].set((int(x1[0, 40]) + 7) % 512)
    l1 = _tfm_forward(params, x1)
    l2 = _tfm_forward(params, x2)
    np.testing.assert_allclose(
        np.asarray(l1[0, :40]), np.asarray(l2[0, :40]), rtol=1e-4, atol=1e-4
    )
    assert float(jnp.abs(l1[0, 40:] - l2[0, 40:]).max()) > 1e-4
