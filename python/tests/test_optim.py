"""L2 optimizer correctness: step semantics, state layouts, trajectories."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.optim_jax import (
    Hyper,
    OPTIMIZERS,
    make_adamw,
    make_jorge,
    make_sgd,
    make_shampoo,
)
from compile.kernels import ref

HYPER = Hyper(block=16)


def _params(rng, specs):
    return [jnp.asarray(rng.normal(size=s), jnp.float32) for _, s in specs]


SPECS = [("w", (12, 8)), ("b", (8, 1))]


def _grads_like(rng, params, scale=0.1):
    return [jnp.asarray(rng.normal(size=p.shape) * scale, jnp.float32) for p in params]


# ---------------------------------------------------------------------------
# State layout contracts (what the manifest promises Rust)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["sgd", "adamw", "shampoo", "jorge"])
def test_state_spec_matches_init_state(name):
    rng = np.random.default_rng(0)
    opt = OPTIMIZERS[name](HYPER)
    params = _params(rng, SPECS)
    state = opt.init_state(params)
    spec = opt.state_spec(SPECS)
    assert len(state) == len(spec)
    for arr, (sname, sshape) in zip(state, spec):
        assert tuple(arr.shape) == tuple(sshape), f"{name}:{sname}"


@pytest.mark.parametrize("name", ["sgd", "adamw", "shampoo", "jorge"])
def test_step_preserves_layout(name):
    rng = np.random.default_rng(1)
    opt = OPTIMIZERS[name](HYPER)
    params = _params(rng, SPECS)
    state = opt.init_state(params)
    grads = _grads_like(rng, params)
    new_p, new_s = opt.step(params, state, grads, 0.1, 1e-4)
    assert len(new_p) == len(params)
    assert len(new_s) == len(state)
    for a, b in zip(new_s, state):
        assert a.shape == b.shape


def test_jorge_state_counts():
    """Preconditioned layers carry 4 states, 1-D layers carry 2 (App. A.6)."""
    opt = make_jorge(HYPER)
    spec = opt.state_spec(SPECS)
    names = [n for n, _ in spec]
    assert names == ["w.Lhat", "w.Rhat", "w.mom", "w.gmom", "b.mom", "b.gmom"]


def test_shampoo_state_counts():
    opt = make_shampoo(HYPER)
    names = [n for n, _ in opt.state_spec(SPECS)]
    assert names == [
        "w.Lstat", "w.Rstat", "w.PL", "w.PR", "w.mom", "w.gmom",
        "b.mom", "b.gmom",
    ]


# ---------------------------------------------------------------------------
# SGD semantics (the torchvision baseline we bootstrap from)
# ---------------------------------------------------------------------------

def test_sgd_first_step_direction():
    rng = np.random.default_rng(2)
    opt = make_sgd(HYPER)
    params = _params(rng, SPECS)
    grads = _grads_like(rng, params)
    new_p, new_s = opt.step(params, opt.init_state(params), grads, 0.1, 0.0)
    for p, g, np_ in zip(params, grads, new_p):
        np.testing.assert_allclose(np.asarray(np_), np.asarray(p - 0.1 * g), rtol=1e-6)


def test_sgd_coupled_weight_decay():
    rng = np.random.default_rng(3)
    opt = make_sgd(HYPER)
    params = _params(rng, SPECS)
    zero_g = [jnp.zeros_like(p) for p in params]
    new_p, _ = opt.step(params, opt.init_state(params), zero_g, 0.1, 1e-2)
    for p, np_ in zip(params, new_p):
        np.testing.assert_allclose(
            np.asarray(np_), np.asarray(p - 0.1 * 1e-2 * p), rtol=1e-6
        )


def test_sgd_momentum_accumulates():
    rng = np.random.default_rng(4)
    opt = make_sgd(HYPER)
    params = _params(rng, SPECS)
    g = _grads_like(rng, params)
    state = opt.init_state(params)
    p1, s1 = opt.step(params, state, g, 0.1, 0.0)
    p2, s2 = opt.step(p1, s1, g, 0.1, 0.0)
    # second step is larger: |Δ2| = lr*(1+β)|g| > lr*|g|
    d1 = np.abs(np.asarray(params[0] - p1[0])).mean()
    d2 = np.abs(np.asarray(p1[0] - p2[0])).mean()
    assert d2 > 1.5 * d1


# ---------------------------------------------------------------------------
# AdamW semantics
# ---------------------------------------------------------------------------

def test_adamw_first_step_is_lr_sized():
    rng = np.random.default_rng(5)
    opt = make_adamw(HYPER)
    params = _params(rng, SPECS)
    grads = _grads_like(rng, params)
    new_p, new_s = opt.step(params, opt.init_state(params), grads, 1e-3, 0.0)
    # bias-corrected first Adam step ≈ lr * sign(g)
    delta = np.abs(np.asarray(params[0] - new_p[0]))
    assert delta.max() <= 1.1e-3
    assert delta.mean() >= 0.5e-3


def test_adamw_decoupled_wd_shrinks_params_with_zero_grad():
    rng = np.random.default_rng(6)
    opt = make_adamw(HYPER)
    params = _params(rng, SPECS)
    zero_g = [jnp.zeros_like(p) for p in params]
    new_p, _ = opt.step(params, opt.init_state(params), zero_g, 1e-3, 0.1)
    np.testing.assert_allclose(
        np.asarray(new_p[0]), np.asarray(params[0]) * (1 - 1e-3 * 0.1), rtol=1e-5
    )


def test_adamw_step_counter_increments():
    rng = np.random.default_rng(7)
    opt = make_adamw(HYPER)
    params = _params(rng, SPECS)
    state = opt.init_state(params)
    g = _grads_like(rng, params)
    _, s1 = opt.step(params, state, g, 1e-3, 0.0)
    _, s2 = opt.step(params, s1, g, 1e-3, 0.0)
    assert float(s1[-1][0, 0]) == 1.0
    assert float(s2[-1][0, 0]) == 2.0


# ---------------------------------------------------------------------------
# Grafting property (App. A.2): step magnitude == SGD's, direction == Jorge's
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("maker", [make_jorge, make_shampoo])
def test_grafted_step_magnitude_matches_sgd(maker):
    rng = np.random.default_rng(8)
    opt = maker(HYPER)
    params = _params(rng, SPECS)
    grads = _grads_like(rng, params)
    new_p, new_s = opt.step(params, opt.init_state(params), grads, 0.05, 0.0)
    # first step: m_sgd = g, so ||Δ|| must equal lr * ||g|| per layer
    for p, g, np_ in zip(params, grads, new_p):
        step_norm = float(jnp.linalg.norm(np_ - p))
        g_norm = float(jnp.linalg.norm(g))
        np.testing.assert_allclose(step_norm, 0.05 * g_norm, rtol=1e-3)


def test_jorge_direction_comes_from_preconditioned_momentum():
    rng = np.random.default_rng(9)
    opt = make_jorge(HYPER)
    params = _params(rng, SPECS)
    grads = _grads_like(rng, params)
    state = opt.init_state(params)
    new_p, new_s = opt.step(params, state, grads, 0.05, 0.0)
    # reconstruct expected direction for the 2-D layer
    l_hat, r_hat = state[0], state[1]
    l_new = ref.jorge_update_ref(l_hat, grads[0] @ grads[0].T)
    r_new = ref.jorge_update_ref(r_hat, grads[0].T @ grads[0])
    gtilde = np.asarray(l_new @ grads[0] @ r_new)
    step = np.asarray(params[0] - new_p[0])
    cos = (step * gtilde).sum() / (
        np.linalg.norm(step) * np.linalg.norm(gtilde) + 1e-12
    )
    assert cos > 0.999, f"direction mismatch: cos={cos}"


# ---------------------------------------------------------------------------
# Jorge vs Shampoo trajectory: approximation should track the exact method
# ---------------------------------------------------------------------------

def test_jorge_tracks_shampoo_preconditioned_direction():
    """On a fixed quadratic, after burn-in, Jorge's preconditioned gradient
    should be positively aligned with Shampoo's (same curvature info)."""
    rng = np.random.default_rng(10)
    m, n = 10, 6
    g = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)

    # run 10 constant-gradient steps of both preconditioner updates
    l_hat = (1e-2) ** -0.25 * jnp.eye(m, dtype=jnp.float32)
    r_hat = (1e-2) ** -0.25 * jnp.eye(n, dtype=jnp.float32)
    lstat = 1e-2 * jnp.eye(m, dtype=jnp.float32)
    rstat = 1e-2 * jnp.eye(n, dtype=jnp.float32)
    for _ in range(10):
        l_hat = ref.jorge_update_ref(l_hat, g @ g.T)
        r_hat = ref.jorge_update_ref(r_hat, g.T @ g)
        lstat = ref.shampoo_stats_update(lstat, g @ g.T, 0.95)
        rstat = ref.shampoo_stats_update(rstat, g.T @ g, 0.95)

    jorge_dir = np.asarray(l_hat @ g @ r_hat)
    shampoo_dir = np.asarray(ref.shampoo_precondition_ref(lstat, g, rstat))
    cos = (jorge_dir * shampoo_dir).sum() / (
        np.linalg.norm(jorge_dir) * np.linalg.norm(shampoo_dir)
    )
    assert cos > 0.9, f"Jorge drifted from Shampoo: cos={cos}"


# ---------------------------------------------------------------------------
# Pallas path == jnp path at the full-step level
# ---------------------------------------------------------------------------

def test_jorge_pallas_and_jnp_paths_agree():
    rng = np.random.default_rng(11)
    params = _params(rng, SPECS)
    grads = _grads_like(rng, params)
    opt_pl = make_jorge(Hyper(block=16, use_pallas=True))
    opt_np = make_jorge(Hyper(block=16, use_pallas=False))
    state = opt_pl.init_state(params)
    p1, s1 = opt_pl.step(params, state, grads, 0.05, 1e-3)
    p2, s2 = opt_np.step(params, state, grads, 0.05, 1e-3)
    for a, b in zip(p1 + s1, p2 + s2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# skip-step (stale preconditioner) semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("maker", [make_jorge, make_shampoo])
def test_skip_step_does_not_touch_inverse_roots(maker):
    rng = np.random.default_rng(12)
    opt = maker(HYPER)
    params = _params(rng, SPECS)
    state = opt.init_state(params)
    grads = _grads_like(rng, params)
    _, s_skip = opt.step(params, state, grads, 0.05, 0.0, update_precond=False)
    if opt.name == "jorge":
        np.testing.assert_array_equal(np.asarray(s_skip[0]), np.asarray(state[0]))
        np.testing.assert_array_equal(np.asarray(s_skip[1]), np.asarray(state[1]))
    else:
        # shampoo: stats still accumulate, but PL/PR stay stale
        assert not np.allclose(np.asarray(s_skip[0]), np.asarray(state[0]))
        np.testing.assert_array_equal(np.asarray(s_skip[2]), np.asarray(state[2]))
        np.testing.assert_array_equal(np.asarray(s_skip[3]), np.asarray(state[3]))


# ---------------------------------------------------------------------------
# Convergence smoke: each optimizer minimises a quadratic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("adamw", 0.05), ("shampoo", 0.1), ("jorge", 0.1)])
def test_optimizers_minimise_quadratic(name, lr):
    rng = np.random.default_rng(13)
    target = jnp.asarray(rng.normal(size=(12, 8)), jnp.float32)
    opt = OPTIMIZERS[name](HYPER)
    params = [jnp.zeros((12, 8), jnp.float32), jnp.zeros((8, 1), jnp.float32)]
    state = opt.init_state(params)

    def loss(ps):
        return 0.5 * jnp.sum((ps[0] - target) ** 2) + 0.5 * jnp.sum(ps[1] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        grads = [params[0] - target, params[1]]
        params, state = opt.step(params, state, grads, lr, 0.0)
    l1 = float(loss(params))
    assert l1 < 0.1 * l0, f"{name}: {l0} -> {l1}"
