//! Shared helpers for the `benches/*` table/figure reproductions.
//!
//! Environment knobs:
//! * `JORGE_ARTIFACTS` — artifacts dir (default `artifacts`)
//! * `JORGE_BACKEND` — auto | native | pjrt (default `auto`)
//! * `JORGE_BENCH_SEEDS` — trials per cell (default 2)
//! * `JORGE_FAST=1` — shrink budgets for smoke runs
//! * `JORGE_BENCH_DIR` — where `BENCH_*.json` land (default cwd)

use crate::config::TrainConfig;
use crate::coordinator::{RunResult, Trainer};
use crate::jsonio::Json;
use crate::runtime::{backend_for, ExecBackend};
use std::collections::BTreeMap;
use std::sync::Arc;

pub fn artifacts_dir() -> String {
    std::env::var("JORGE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

pub fn backend_choice() -> String {
    std::env::var("JORGE_BACKEND").unwrap_or_else(|_| "auto".into())
}

pub fn engine() -> anyhow::Result<Arc<dyn ExecBackend>> {
    backend_for(&artifacts_dir(), &backend_choice())
}

pub fn fast() -> bool {
    std::env::var("JORGE_FAST").map(|v| v == "1").unwrap_or(false)
}

pub fn n_seeds() -> usize {
    std::env::var("JORGE_BENCH_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2)
}

pub fn run(cfg: TrainConfig, engine: Arc<dyn ExecBackend>) -> anyhow::Result<RunResult> {
    Trainer::new(cfg, engine)?.run()
}

pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() as f64 - 1.0);
    (mean, var.sqrt())
}

/// "0.7612 ± 0.0021" formatting used by the table benches.
pub fn pm(xs: &[f64]) -> String {
    let (m, s) = mean_std(xs);
    format!("{m:.4} ± {s:.4}")
}

// -- machine-readable bench output (`BENCH_*.json`) --------------------------
//
// Every table bench can drop its numbers next to the printed table so CI
// uploads them as artifacts and future perf PRs diff iteration times
// instead of eyeballing logs. Files are gitignored; EXPERIMENTS.md §Perf
// records the curated baselines.

/// Where `BENCH_*.json` files land (`JORGE_BENCH_DIR`, default cwd).
pub fn bench_dir() -> String {
    std::env::var("JORGE_BENCH_DIR").unwrap_or_else(|_| ".".into())
}

/// Standard envelope: bench id + host threading context around the
/// bench-specific `results` payload.
pub fn bench_envelope(bench: &str, results: Json) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str(bench.to_string()));
    obj.insert("threads".to_string(), Json::Num(crate::tensor::pool_size() as f64));
    obj.insert("fast".to_string(), Json::Bool(fast()));
    obj.insert("results".to_string(), results);
    Json::Obj(obj)
}

/// Write `BENCH_{name}.json`; returns the path written.
pub fn write_bench_json(name: &str, payload: &Json) -> std::io::Result<String> {
    let path = format!("{}/BENCH_{name}.json", bench_dir());
    std::fs::write(&path, payload.to_string_pretty())?;
    Ok(path)
}

/// Row helper for per-model tables: `{"name": ..., <key>: <value>, ...}`.
pub fn json_row(name: &str, cells: &[(&str, f64)]) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("name".to_string(), Json::Str(name.to_string()));
    for (k, v) in cells {
        obj.insert((*k).to_string(), Json::Num(*v));
    }
    Json::Obj(obj)
}

/// Baseline configs per benchmark slot, mirroring the paper's Table 5/6
/// defaults translated to the synthetic workloads.
pub fn base_config(model: &str) -> TrainConfig {
    let mut cfg = TrainConfig {
        model: model.into(),
        // "well-tuned SGD" baselines for the synthetic suite (lr sweep
        // recorded in EXPERIMENTS.md §Calibration)
        lr: match model {
            "mlp" | "cnn" => 0.01,
            "segnet" => 0.1,
            _ => 0.02,
        },
        weight_decay: 1e-4,
        eval_every_epochs: 1000, // benches print their own tables
        ..Default::default()
    };
    let (epochs, steps, batch) = match model {
        "mlp" => (12, 40, 64),
        "cnn" => (12, 25, 32),
        "segnet" => (14, 25, 16),
        "transformer" => (4, 25, 8),
        _ => (8, 25, 32),
    };
    cfg.epochs = epochs;
    cfg.steps_per_epoch = steps;
    if fast() {
        cfg.epochs = (cfg.epochs / 2).max(2);
        cfg.steps_per_epoch = (cfg.steps_per_epoch / 2).max(5);
    }
    // fresh-data regime: dataset much larger than one epoch's consumption
    // so sample efficiency measures optimization speed, not memorisation
    cfg.dataset_size = batch * cfg.steps_per_epoch * cfg.epochs;
    cfg
}

/// Apply the per-optimizer hyperparameter policy (§4 + Tables 5-7).
pub fn tune_for(cfg: &mut TrainConfig, opt: &str) {
    use crate::config::ScheduleKind;
    cfg.optimizer = opt.parse().expect("tune_for: unknown optimizer");
    match opt {
        "sgd" => cfg.schedule = ScheduleKind::Step,
        "adamw" => {
            cfg.schedule = ScheduleKind::Cosine;
            cfg.lr = 1e-3;
            cfg.weight_decay = 1e-2;
        }
        "shampoo" | "shampoo_sharded" => {
            // paper: same lr/wd/schedule as SGD + grafting
            cfg.schedule = ScheduleKind::Step;
            cfg.precond_every = 4;
        }
        "jorge" | "jorge_sharded" => {
            // single-shot bootstrap: lr inherited (grafting), wd x10,
            // step decay at 1/3 and 2/3
            cfg.schedule = ScheduleKind::Step;
            cfg.decay_at = vec![1.0 / 3.0, 2.0 / 3.0];
            cfg.weight_decay *= 10.0;
            cfg.precond_every = 4;
        }
        _ => {}
    }
}

/// Target validation metrics for the time/epochs-to-target tables —
/// the synthetic analogues of the paper's Table 2 targets.
pub fn target_for(model: &str) -> f64 {
    match model {
        "mlp" => 0.58,
        "cnn" => 0.85,
        "segnet" => 0.27,
        "transformer" => 0.30,
        _ => 0.5,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!((m1, s1), (5.0, 0.0));
    }

    #[test]
    fn base_configs_validate() {
        for m in ["mlp", "cnn", "segnet", "transformer"] {
            let mut cfg = base_config(m);
            for opt in ["sgd", "adamw", "shampoo", "jorge"] {
                tune_for(&mut cfg, opt);
                cfg.validate().unwrap();
            }
        }
    }

    #[test]
    fn bench_json_round_trips() {
        let row = json_row("mlp", &[("sgd", 0.5), ("jorge", 0.55)]);
        let env = bench_envelope("table1", Json::Arr(vec![row]));
        let parsed = Json::parse(&env.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("bench").and_then(Json::as_str), Some("table1"));
        assert!(parsed.get("threads").and_then(Json::as_f64).unwrap() >= 1.0);
        let rows = parsed.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(rows[0].get("name").and_then(Json::as_str), Some("mlp"));
        assert_eq!(rows[0].get("jorge").and_then(Json::as_f64), Some(0.55));
    }

    #[test]
    fn jorge_tuning_follows_bootstrap_rules() {
        let mut cfg = base_config("cnn");
        let sgd_wd = cfg.weight_decay;
        tune_for(&mut cfg, "jorge");
        assert!((cfg.weight_decay - 10.0 * sgd_wd).abs() < 1e-12);
        assert_eq!(cfg.lr, base_config("cnn").lr); // grafting keeps lr
    }
}
