//! Micro-benchmark harness (substrate — replaces criterion offline).
//!
//! Warmup + timed iterations with mean/std/p50/p99, adaptive iteration
//! counts targeting a wall-clock budget, and a tabular reporter used by
//! every `benches/*` target to print the paper's tables.

use crate::metricsio::Summary;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn per_iter_human(&self) -> String {
        human_time(self.mean_s)
    }
}

pub fn human_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark `f`, choosing an iteration count so total time ~ `budget_s`.
pub fn bench(name: &str, budget_s: f64, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / once) as usize).clamp(3, 10_000);

    let mut stats = Summary::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        stats.add(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats.mean(),
        std_s: stats.std(),
        p50_s: stats.median(),
        p99_s: stats.percentile(99.0),
        min_s: stats.min(),
    }
}

/// Fixed-iteration variant for expensive end-to-end runs.
pub fn bench_n(name: &str, iters: usize, mut f: impl FnMut()) -> BenchResult {
    let mut stats = Summary::new();
    f(); // warmup
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        stats.add(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: iters.max(1),
        mean_s: stats.mean(),
        std_s: stats.std(),
        p50_s: stats.median(),
        p99_s: stats.percentile(99.0),
        min_s: stats.min(),
    }
}

/// Simple fixed-width table printer for bench reports.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table row width");
        self.rows.push(cells.to_vec());
    }

    pub fn rows_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("\n== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 0.05, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 3);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s * 1.5);
        assert!(r.p50_s <= r.p99_s);
    }

    #[test]
    fn bench_n_runs_exact_iters() {
        let mut count = 0usize;
        let r = bench_n("counter", 5, || count += 1);
        assert_eq!(r.iters, 5);
        assert_eq!(count, 6); // warmup + 5
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2.0).ends_with(" s"));
        assert!(human_time(2e-3).ends_with(" ms"));
        assert!(human_time(2e-6).ends_with(" us"));
        assert!(human_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.rows_str(&["sgd", "0.09"]);
        t.rows_str(&["jorge-longer", "0.091"]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("jorge-longer"));
        let lines: Vec<&str> = s.trim().lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn table_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.rows_str(&["only-one"]);
    }
}
