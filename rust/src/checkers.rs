//! Mini property-testing framework (substrate — no proptest offline).
//!
//! Seeded random case generation with greedy shrinking on failure. Used
//! across the test suite for optimizer invariants (PSD/symmetry
//! preservation, series validity), collective correctness over arbitrary
//! topologies, scheduler monotonicity and parser round-trips.

use crate::rngx::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE, max_shrink_iters: 200 }
    }
}

/// A generator draws a case from randomness and can propose shrunk
/// variants of a failing case.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate simplifications, most aggressive first. Default: none.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Check `prop` over random cases; on failure, shrink and panic with the
/// minimal counterexample.
pub fn check<G: Gen>(name: &str, cfg: Config, gen: &G, prop: impl Fn(&G::Value) -> Result<(), String>) {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // shrink
            let mut best = value.clone();
            let mut best_msg = msg;
            let mut iters = 0;
            'outer: loop {
                for cand in gen.shrink(&best) {
                    iters += 1;
                    if iters > cfg.max_shrink_iters {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property {name:?} failed at case {case} (seed {}):\n  counterexample: {best:?}\n  error: {best_msg}",
                cfg.seed
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------------

/// Uniform usize in [lo, hi]; shrinks towards lo.
pub struct UsizeGen {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeGen {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        self.lo + rng.below((self.hi - self.lo + 1) as u64) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Random f32 matrix dims + data; shrinks dimensions towards 1.
pub struct MatrixGen {
    pub max_dim: usize,
    pub scale: f32,
}

#[derive(Clone, Debug)]
pub struct MatrixCase {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
    pub seed: u64,
}

impl MatrixCase {
    pub fn to_matrix(&self) -> crate::tensor::Matrix {
        crate::tensor::Matrix::from_vec(self.rows, self.cols, self.data.clone())
    }
}

impl Gen for MatrixGen {
    type Value = MatrixCase;
    fn generate(&self, rng: &mut Rng) -> MatrixCase {
        let rows = 1 + rng.below(self.max_dim as u64) as usize;
        let cols = 1 + rng.below(self.max_dim as u64) as usize;
        let seed = rng.next_u64();
        let mut r2 = Rng::new(seed);
        let mut data = vec![0.0f32; rows * cols];
        r2.fill_normal(&mut data, 0.0, self.scale);
        MatrixCase { rows, cols, data, seed }
    }
    fn shrink(&self, v: &MatrixCase) -> Vec<MatrixCase> {
        let mut out = Vec::new();
        for (nr, nc) in [(1, 1), (v.rows / 2, v.cols / 2), (v.rows, v.cols / 2), (v.rows / 2, v.cols)] {
            let (nr, nc) = (nr.max(1), nc.max(1));
            if (nr, nc) != (v.rows, v.cols) {
                let mut data = Vec::with_capacity(nr * nc);
                for i in 0..nr {
                    for j in 0..nc {
                        data.push(v.data[i * v.cols + j]);
                    }
                }
                out.push(MatrixCase { rows: nr, cols: nc, data, seed: v.seed });
            }
        }
        out
    }
}

/// Pair generator.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", Config::default(), &UsizeGen { lo: 0, hi: 100 }, |&n| {
            if n + 1 == 1 + n {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let result = std::panic::catch_unwind(|| {
            check(
                "all-below-50",
                Config { cases: 200, ..Default::default() },
                &UsizeGen { lo: 0, hi: 1000 },
                |&n| if n < 50 { Ok(()) } else { Err(format!("{n} >= 50")) },
            );
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        // shrinker should walk down to exactly 50
        assert!(msg.contains("counterexample: 50"), "{msg}");
    }

    #[test]
    fn matrix_gen_respects_bounds() {
        let g = MatrixGen { max_dim: 8, scale: 1.0 };
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let m = g.generate(&mut rng);
            assert!(m.rows >= 1 && m.rows <= 8);
            assert!(m.cols >= 1 && m.cols <= 8);
            assert_eq!(m.data.len(), m.rows * m.cols);
        }
    }

    #[test]
    fn matrix_shrink_prefers_smaller() {
        let g = MatrixGen { max_dim: 10, scale: 1.0 };
        let mut rng = Rng::new(2);
        let m = g.generate(&mut rng);
        for s in g.shrink(&m) {
            assert!(s.rows * s.cols <= m.rows * m.cols);
            assert_eq!(s.data.len(), s.rows * s.cols);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let g = UsizeGen { lo: 0, hi: 1_000_000 };
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..20 {
            assert_eq!(g.generate(&mut a), g.generate(&mut b));
        }
    }
}
