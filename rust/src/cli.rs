//! Command-line argument parsing (substrate — no clap offline).
//!
//! Grammar: `jorge <subcommand> [--flag value] [--switch] [positional...]`.
//! Flags accept `--key value` or `--key=value`. Unknown flags are errors
//! so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// true => boolean switch, no value
    pub is_switch: bool,
}

pub const fn flag(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec { name, help, is_switch: false }
}

pub const fn switch(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec { name, help, is_switch: true }
}

impl Args {
    /// Parse argv (without the binary name) against a flag specification.
    pub fn parse(argv: &[String], spec: &[FlagSpec]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let fs = spec
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name} (try --help)"))?;
                if fs.is_switch {
                    if inline_val.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    out.switches.push(name.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} needs a value"))?
                            .clone(),
                    };
                    out.flags.insert(name.to_string(), val);
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("--{name}: expected number, got {s:?}")),
        }
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("--{name}: expected integer, got {s:?}")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

pub fn render_help(program: &str, subcommands: &[(&str, &str)], spec: &[FlagSpec]) -> String {
    let mut s = format!("usage: {program} <command> [flags]\n\ncommands:\n");
    for (name, help) in subcommands {
        s.push_str(&format!("  {name:<16} {help}\n"));
    }
    s.push_str("\nflags:\n");
    for f in spec {
        let n = format!("--{}{}", f.name, if f.is_switch { "" } else { " <v>" });
        s.push_str(&format!("  {n:<24} {}\n", f.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<FlagSpec> {
        vec![
            flag("model", "model name"),
            flag("lr", "learning rate"),
            switch("native", "use native mirrors"),
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(
            &sv(&["train", "--model", "cnn", "--native", "--lr=0.4", "pos1"]),
            &spec(),
        )
        .unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("model"), Some("cnn"));
        assert_eq!(a.get_f64("lr").unwrap(), Some(0.4));
        assert!(a.has("native"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(Args::parse(&sv(&["train", "--nope", "x"]), &spec()).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["train", "--model"]), &spec()).is_err());
    }

    #[test]
    fn switch_with_value_is_error() {
        assert!(Args::parse(&sv(&["train", "--native=yes"]), &spec()).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&sv(&["x", "--lr", "fast"]), &spec()).unwrap();
        assert!(a.get_f64("lr").is_err());
    }

    #[test]
    fn help_renders() {
        let h = render_help("jorge", &[("train", "run training")], &spec());
        assert!(h.contains("--model"));
        assert!(h.contains("train"));
    }
}
