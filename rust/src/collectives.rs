//! Simulated multi-GPU collectives (substrate).
//!
//! The paper's distributed comparison (Fig. 2: serial Shampoo vs
//! Distributed Shampoo vs per-GPU Jorge) needs gradient all-reduce and
//! preconditioner all-gather. Workers here are threads sharing memory;
//! the *algorithms* are the real ring/tree schedules, and a latency/
//! bandwidth cost model reports what each collective would cost on the
//! paper's testbed (NVLink-connected A100s).

/// In-place sum-all-reduce over per-worker buffers, ring algorithm:
/// 2(N-1) chunk steps — reduce-scatter then all-gather. All buffers end
/// with the elementwise sum.
pub fn ring_all_reduce(buffers: &mut [Vec<f32>]) {
    let n = buffers.len();
    if n <= 1 {
        return;
    }
    let len = buffers[0].len();
    for b in buffers.iter() {
        assert_eq!(b.len(), len, "ragged all-reduce buffers");
    }
    if len == 0 {
        return;
    }
    // chunk boundaries (n chunks, last absorbs remainder)
    let chunk = len.div_ceil(n);
    let bounds: Vec<(usize, usize)> = (0..n)
        .map(|i| (i * chunk, ((i + 1) * chunk).min(len)))
        .collect();

    // reduce-scatter: after step s, rank r owns the full sum of chunk
    // (r - s - 1) mod n ... standard ring schedule
    for s in 0..n - 1 {
        for r in 0..n {
            let src = r;
            let dst = (r + 1) % n;
            let c = (r + n - s) % n;
            let (lo, hi) = bounds[c];
            if lo >= hi {
                continue;
            }
            // dst.chunk += src.chunk
            let (a, b) = two_mut(buffers, src, dst);
            for i in lo..hi {
                b[i] += a[i];
            }
        }
    }
    // all-gather: propagate the finished chunks around the ring
    for s in 0..n - 1 {
        for r in 0..n {
            let src = r;
            let dst = (r + 1) % n;
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = bounds[c];
            if lo >= hi {
                continue;
            }
            let (a, b) = two_mut(buffers, src, dst);
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
    }
}

/// Recursive-halving tree all-reduce (log2 N rounds + broadcast).
pub fn tree_all_reduce(buffers: &mut [Vec<f32>]) {
    let n = buffers.len();
    if n <= 1 {
        return;
    }
    let len = buffers[0].len();
    for b in buffers.iter() {
        assert_eq!(b.len(), len, "ragged all-reduce buffers");
    }
    // reduce up the tree to rank 0
    let mut stride = 1;
    while stride < n {
        let mut r = 0;
        while r + stride < n {
            let (src, dst) = two_mut(buffers, r + stride, r);
            for i in 0..len {
                dst[i] += src[i];
            }
            r += 2 * stride;
        }
        stride *= 2;
    }
    // broadcast
    let root = buffers[0].clone();
    for b in buffers.iter_mut().skip(1) {
        b.copy_from_slice(&root);
    }
}

/// Ragged ring all-gather: rank `r` contributes `chunks[r]` and every
/// rank ends with the concatenation of all chunks in rank order (the
/// sharded-preconditioner exchange: each owner contributes the
/// preconditioners it refreshed). n-1 forwarding steps; at step `s`,
/// rank `r` forwards chunk `(r + n - s) % n` — the one it received the
/// previous step — to rank `r + 1`.
pub fn ring_all_gather(chunks: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = chunks.len();
    let mut offsets = Vec::with_capacity(n + 1);
    let mut total = 0usize;
    offsets.push(0);
    for c in chunks {
        total += c.len();
        offsets.push(total);
    }
    let mut out: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0; total]).collect();
    for (r, c) in chunks.iter().enumerate() {
        out[r][offsets[r]..offsets[r + 1]].copy_from_slice(c);
    }
    if n <= 1 {
        return out;
    }
    for s in 0..n - 1 {
        for r in 0..n {
            let dst = (r + 1) % n;
            let c = (r + n - s) % n;
            let (lo, hi) = (offsets[c], offsets[c + 1]);
            if lo >= hi {
                continue;
            }
            let (a, b) = two_mut(&mut out, r, dst);
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
    }
    out
}

/// Binomial-tree broadcast from `root`: after ceil(log2 n) rounds every
/// buffer equals `buffers[root]`.
pub fn tree_broadcast(buffers: &mut [Vec<f32>], root: usize) {
    let n = buffers.len();
    if n <= 1 {
        return;
    }
    assert!(root < n, "broadcast root {root} out of range");
    let len = buffers[root].len();
    for b in buffers.iter() {
        assert_eq!(b.len(), len, "ragged broadcast buffers");
    }
    // relabel so the root is virtual rank 0, then the standard doubling
    // schedule: each round, ranks < stride send to rank + stride
    let mut stride = 1;
    while stride < n {
        for q in 0..stride {
            let p = q + stride;
            if p >= n {
                break;
            }
            let src = (q + root) % n;
            let dst = (p + root) % n;
            let (a, b) = two_mut(buffers, src, dst);
            b.copy_from_slice(a);
        }
        stride *= 2;
    }
}

/// Average instead of sum (DDP gradient semantics).
pub fn ring_all_reduce_mean(buffers: &mut [Vec<f32>]) {
    let n = buffers.len() as f32;
    ring_all_reduce(buffers);
    for b in buffers.iter_mut() {
        for v in b.iter_mut() {
            *v /= n;
        }
    }
}

fn two_mut(buffers: &mut [Vec<f32>], i: usize, j: usize) -> (&[f32], &mut [f32]) {
    assert_ne!(i, j);
    if i < j {
        let (a, b) = buffers.split_at_mut(j);
        (&a[i], &mut b[0])
    } else {
        let (a, b) = buffers.split_at_mut(i);
        (&b[0], &mut a[j]) // (src=i, dst=j)
    }
}

// ---------------------------------------------------------------------------
// Communication cost model (paper testbed: NVLink A100 nodes)
// ---------------------------------------------------------------------------

/// alpha-beta model: time = alpha * steps + bytes_on_wire / bandwidth.
#[derive(Clone, Copy, Debug)]
pub struct CommCostModel {
    /// per-message latency (s); NVLink ~ 5 us, IB cross-node ~ 15 us
    pub alpha: f64,
    /// link bandwidth (B/s); NVLink3 ~ 200 GB/s effective per direction
    pub beta: f64,
}

impl CommCostModel {
    pub fn nvlink_a100() -> Self {
        CommCostModel { alpha: 5e-6, beta: 200e9 }
    }

    pub fn ib_cluster() -> Self {
        CommCostModel { alpha: 15e-6, beta: 25e9 }
    }

    /// Ring all-reduce of `bytes` over `n` ranks:
    /// 2(n-1) steps, each moving bytes/n.
    pub fn ring_all_reduce_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2 * (n - 1);
        steps as f64 * self.alpha + (2.0 * (n - 1) as f64 / n as f64) * bytes as f64 / self.beta
    }

    /// All-gather of `bytes` total (each rank contributes bytes/n).
    pub fn all_gather_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n - 1) as f64 * self.alpha + ((n - 1) as f64 / n as f64) * bytes as f64 / self.beta
    }

    /// Ragged ring all-gather ([`ring_all_gather`]): n-1 forwarding
    /// steps, each paced by the largest chunk on the wire. For uniform
    /// chunks this reduces exactly to [`all_gather_time`](Self::all_gather_time)
    /// of the total payload.
    pub fn all_gather_ragged_time(&self, chunk_bytes: &[usize]) -> f64 {
        let n = chunk_bytes.len();
        if n <= 1 {
            return 0.0;
        }
        let max_chunk = chunk_bytes.iter().copied().max().unwrap_or(0);
        (n - 1) as f64 * (self.alpha + max_chunk as f64 / self.beta)
    }

    /// Binomial-tree broadcast ([`tree_broadcast`]): ceil(log2 n) rounds
    /// of the full payload.
    pub fn broadcast_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let rounds = ((n - 1).ilog2() + 1) as f64;
        rounds * (self.alpha + bytes as f64 / self.beta)
    }

    /// Point-to-point send.
    pub fn send_time(&self, bytes: usize) -> f64 {
        self.alpha + bytes as f64 / self.beta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn make_buffers(n: usize, len: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let mut want = vec![0.0f32; len];
        for b in &bufs {
            for (w, v) in want.iter_mut().zip(b) {
                *w += v;
            }
        }
        (bufs, want)
    }

    #[test]
    fn ring_matches_sequential_sum() {
        for &(n, len) in &[(2usize, 10usize), (3, 7), (4, 100), (5, 1), (8, 1000), (7, 13)] {
            let (mut bufs, want) = make_buffers(n, len, n as u64);
            ring_all_reduce(&mut bufs);
            for (r, b) in bufs.iter().enumerate() {
                for i in 0..len {
                    assert!(
                        (b[i] - want[i]).abs() < 1e-3 * want[i].abs().max(1.0),
                        "n={n} len={len} rank={r} i={i}: {} vs {}",
                        b[i],
                        want[i]
                    );
                }
            }
        }
    }

    #[test]
    fn tree_matches_sequential_sum() {
        for &(n, len) in &[(2usize, 16usize), (3, 5), (6, 64), (8, 128)] {
            let (mut bufs, want) = make_buffers(n, len, 100 + n as u64);
            tree_all_reduce(&mut bufs);
            for b in &bufs {
                for i in 0..len {
                    assert!((b[i] - want[i]).abs() < 1e-3 * want[i].abs().max(1.0));
                }
            }
        }
    }

    #[test]
    fn mean_divides_by_n() {
        let (mut bufs, want) = make_buffers(4, 32, 9);
        ring_all_reduce_mean(&mut bufs);
        for b in &bufs {
            for i in 0..32 {
                assert!((b[i] - want[i] / 4.0).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn single_rank_is_noop() {
        let mut bufs = vec![vec![1.0f32, 2.0]];
        ring_all_reduce(&mut bufs);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
    }

    #[test]
    fn empty_buffers_ok() {
        let mut bufs = vec![vec![], vec![]];
        ring_all_reduce(&mut bufs);
    }

    #[test]
    fn all_gather_assembles_ragged_chunks() {
        // varied chunk sizes, including an empty contribution
        for &n in &[2usize, 3, 4, 7] {
            let mut rng = Rng::new(40 + n as u64);
            let chunks: Vec<Vec<f32>> = (0..n)
                .map(|r| {
                    let len = if r == 1 { 0 } else { 3 * r + 1 };
                    (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
                })
                .collect();
            let want: Vec<f32> = chunks.iter().flatten().copied().collect();
            let out = ring_all_gather(&chunks);
            assert_eq!(out.len(), n);
            for (r, b) in out.iter().enumerate() {
                assert_eq!(b, &want, "n={n} rank={r}");
            }
        }
    }

    #[test]
    fn all_gather_single_rank_returns_own_chunk() {
        let out = ring_all_gather(&[vec![1.0, 2.0, 3.0]]);
        assert_eq!(out, vec![vec![1.0, 2.0, 3.0]]);
        assert!(ring_all_gather(&[]).is_empty());
    }

    #[test]
    fn all_gather_cost_accounting() {
        let m = CommCostModel::nvlink_a100();
        // uniform ragged chunks cost exactly the uniform all-gather
        for &n in &[2usize, 3, 4, 7] {
            let b = 1 << 20;
            let ragged = m.all_gather_ragged_time(&vec![b; n]);
            let uniform = m.all_gather_time(n * b, n);
            assert!((ragged - uniform).abs() < 1e-12 * uniform, "n={n}: {ragged} vs {uniform}");
        }
        // the largest chunk paces every step
        let skewed = m.all_gather_ragged_time(&[1 << 20, 8 << 20, 1 << 20]);
        let flat = m.all_gather_ragged_time(&[8 << 20, 8 << 20, 8 << 20]);
        assert_eq!(skewed, flat);
        // degenerate cases are free
        assert_eq!(m.all_gather_ragged_time(&[1 << 20]), 0.0);
        assert_eq!(m.all_gather_ragged_time(&[]), 0.0);
        // broadcast: log2 rounds
        let b1 = m.broadcast_time(1 << 20, 2);
        let b2 = m.broadcast_time(1 << 20, 8);
        assert!((b2 - 3.0 * b1).abs() < 1e-12, "{b1} {b2}");
        assert_eq!(m.broadcast_time(1 << 20, 1), 0.0);
    }

    #[test]
    fn broadcast_from_any_root() {
        for &n in &[2usize, 3, 5, 8] {
            for root in [0, n - 1, n / 2] {
                let mut bufs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; 6]).collect();
                let want = bufs[root].clone();
                tree_broadcast(&mut bufs, root);
                for (r, b) in bufs.iter().enumerate() {
                    assert_eq!(b, &want, "n={n} root={root} rank={r}");
                }
            }
        }
    }

    #[test]
    fn cost_model_scales_sanely() {
        let m = CommCostModel::nvlink_a100();
        // bigger payload costs more; more ranks cost more latency
        let t1 = m.ring_all_reduce_time(100 << 20, 4);
        let t2 = m.ring_all_reduce_time(200 << 20, 4);
        let t3 = m.ring_all_reduce_time(100 << 20, 16);
        assert!(t2 > t1);
        assert!(t3 > t1);
        assert_eq!(m.ring_all_reduce_time(100 << 20, 1), 0.0);
        // ResNet-50 grads (100 MB) over 16 NVLink GPUs: ~1 ms — sanity band
        assert!(t3 > 5e-4 && t3 < 5e-2, "{t3}");
    }
}
