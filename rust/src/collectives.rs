//! Simulated multi-GPU collectives (substrate) with fault injection.
//!
//! The paper's distributed comparison (Fig. 2: serial Shampoo vs
//! Distributed Shampoo vs per-GPU Jorge) needs gradient all-reduce and
//! preconditioner all-gather. Workers here are threads sharing memory;
//! the *algorithms* are the real ring/tree schedules, and a latency/
//! bandwidth cost model reports what each collective would cost on the
//! paper's testbed (NVLink-connected A100s).
//!
//! Entry points return a typed [`CollectiveError`] instead of asserting,
//! and a deterministic seeded [`FaultPlan`] (env/CLI-configurable) can
//! drop a worker, delay it (straggler, with modeled retry/backoff), or
//! corrupt its buffer at a chosen training step. Faults are strictly
//! opt-in: with no plan the collectives are byte-for-byte the plain
//! schedules.
#![warn(clippy::unwrap_used, clippy::expect_used)]

use std::fmt;

use crate::rngx::Rng;

// ---------------------------------------------------------------------------
// Typed errors
// ---------------------------------------------------------------------------

/// Which collective a fault targets (and where an error surfaced).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOp {
    /// The per-step gradient ring all-reduce.
    GradReduce,
    /// The sharded-preconditioner ring all-gather.
    PrecondGather,
    /// The eval-result tree broadcast (leader distributes val metrics).
    EvalBcast,
}

impl fmt::Display for FaultOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultOp::GradReduce => write!(f, "grad"),
            FaultOp::PrecondGather => write!(f, "precond"),
            FaultOp::EvalBcast => write!(f, "eval"),
        }
    }
}

/// Typed failure modes of the collectives substrate. Implements
/// `std::error::Error`, so `?` lifts it into `anyhow::Result` at the
/// coordinator layer.
#[derive(Clone, Debug, PartialEq)]
pub enum CollectiveError {
    /// Buffers that must be uniform length were ragged.
    Ragged { op: &'static str, lens: Vec<usize> },
    /// Broadcast root outside the worker set.
    RootOutOfRange { root: usize, world: usize },
    /// A worker left the collective (injected drop); the rank is dead
    /// for the rest of the run and survivors must re-form the ring.
    WorkerDropped { rank: usize, step: usize, op: FaultOp },
    /// A straggler exhausted the retry budget; treated like a drop.
    Timeout { rank: usize, step: usize, op: FaultOp, attempts: u32 },
}

impl fmt::Display for CollectiveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CollectiveError::Ragged { op, lens } => {
                write!(f, "ragged {op} buffers: lens {lens:?}")
            }
            CollectiveError::RootOutOfRange { root, world } => {
                write!(f, "broadcast root {root} out of range for world size {world}")
            }
            CollectiveError::WorkerDropped { rank, step, op } => {
                write!(f, "worker r{rank} dropped during {op} collective at step {step}")
            }
            CollectiveError::Timeout { rank, step, op, attempts } => {
                write!(
                    f,
                    "worker r{rank} timed out during {op} collective at step {step} \
                     after {attempts} attempts"
                )
            }
        }
    }
}

impl std::error::Error for CollectiveError {}

fn check_uniform(buffers: &[Vec<f32>], op: &'static str) -> Result<usize, CollectiveError> {
    let len = buffers.first().map_or(0, Vec::len);
    if buffers.iter().any(|b| b.len() != len) {
        return Err(CollectiveError::Ragged {
            op,
            lens: buffers.iter().map(Vec::len).collect(),
        });
    }
    Ok(len)
}

// ---------------------------------------------------------------------------
// Core schedules
// ---------------------------------------------------------------------------

/// In-place sum-all-reduce over per-worker buffers, ring algorithm:
/// 2(N-1) chunk steps — reduce-scatter then all-gather. All buffers end
/// with the elementwise sum. Empty worker sets and single ranks are
/// no-ops; ragged buffers are a typed error (buffers untouched).
pub fn ring_all_reduce(buffers: &mut [Vec<f32>]) -> Result<(), CollectiveError> {
    let n = buffers.len();
    let len = check_uniform(buffers, "all-reduce")?;
    if n <= 1 || len == 0 {
        return Ok(());
    }
    // chunk boundaries (n chunks, last absorbs remainder)
    let chunk = len.div_ceil(n);
    let bounds: Vec<(usize, usize)> = (0..n)
        .map(|i| (i * chunk, ((i + 1) * chunk).min(len)))
        .collect();

    // reduce-scatter: after step s, rank r owns the full sum of chunk
    // (r - s - 1) mod n ... standard ring schedule
    for s in 0..n - 1 {
        for r in 0..n {
            let src = r;
            let dst = (r + 1) % n;
            let c = (r + n - s) % n;
            let (lo, hi) = bounds[c];
            if lo >= hi {
                continue;
            }
            // dst.chunk += src.chunk
            let (a, b) = two_mut(buffers, src, dst);
            for i in lo..hi {
                b[i] += a[i];
            }
        }
    }
    // all-gather: propagate the finished chunks around the ring
    for s in 0..n - 1 {
        for r in 0..n {
            let src = r;
            let dst = (r + 1) % n;
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = bounds[c];
            if lo >= hi {
                continue;
            }
            let (a, b) = two_mut(buffers, src, dst);
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
    }
    Ok(())
}

/// Recursive-halving tree all-reduce (log2 N rounds + broadcast).
pub fn tree_all_reduce(buffers: &mut [Vec<f32>]) -> Result<(), CollectiveError> {
    let n = buffers.len();
    let len = check_uniform(buffers, "all-reduce")?;
    if n <= 1 {
        return Ok(());
    }
    // reduce up the tree to rank 0
    let mut stride = 1;
    while stride < n {
        let mut r = 0;
        while r + stride < n {
            let (src, dst) = two_mut(buffers, r + stride, r);
            for i in 0..len {
                dst[i] += src[i];
            }
            r += 2 * stride;
        }
        stride *= 2;
    }
    // broadcast
    let root = buffers[0].clone();
    for b in buffers.iter_mut().skip(1) {
        b.copy_from_slice(&root);
    }
    Ok(())
}

/// Ragged ring all-gather: rank `r` contributes `chunks[r]` and every
/// rank ends with the concatenation of all chunks in rank order (the
/// sharded-preconditioner exchange: each owner contributes the
/// preconditioners it refreshed). n-1 forwarding steps; at step `s`,
/// rank `r` forwards chunk `(r + n - s) % n` — the one it received the
/// previous step — to rank `r + 1`. Ragged chunks are the point, so
/// this schedule has no intrinsic failure mode today, but it returns
/// the typed `Result` every other collective does so fault-aware
/// callers ([`FaultSession::all_gather`]) thread one error type and
/// `--faults` events against the gather are never silently unroutable.
pub fn ring_all_gather(chunks: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, CollectiveError> {
    let n = chunks.len();
    let mut offsets = Vec::with_capacity(n + 1);
    let mut total = 0usize;
    offsets.push(0);
    for c in chunks {
        total += c.len();
        offsets.push(total);
    }
    let mut out: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0; total]).collect();
    for (r, c) in chunks.iter().enumerate() {
        out[r][offsets[r]..offsets[r + 1]].copy_from_slice(c);
    }
    if n <= 1 {
        return Ok(out);
    }
    for s in 0..n - 1 {
        for r in 0..n {
            let dst = (r + 1) % n;
            let c = (r + n - s) % n;
            let (lo, hi) = (offsets[c], offsets[c + 1]);
            if lo >= hi {
                continue;
            }
            let (a, b) = two_mut(&mut out, r, dst);
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
    }
    Ok(out)
}

/// Binomial-tree broadcast from `root`: after ceil(log2 n) rounds every
/// buffer equals `buffers[root]`.
pub fn tree_broadcast(buffers: &mut [Vec<f32>], root: usize) -> Result<(), CollectiveError> {
    let n = buffers.len();
    if n <= 1 {
        if root >= n.max(1) {
            return Err(CollectiveError::RootOutOfRange { root, world: n });
        }
        return Ok(());
    }
    if root >= n {
        return Err(CollectiveError::RootOutOfRange { root, world: n });
    }
    check_uniform(buffers, "broadcast")?;
    // relabel so the root is virtual rank 0, then the standard doubling
    // schedule: each round, ranks < stride send to rank + stride
    let mut stride = 1;
    while stride < n {
        for q in 0..stride {
            let p = q + stride;
            if p >= n {
                break;
            }
            let src = (q + root) % n;
            let dst = (p + root) % n;
            let (a, b) = two_mut(buffers, src, dst);
            b.copy_from_slice(a);
        }
        stride *= 2;
    }
    Ok(())
}

/// Average instead of sum (DDP gradient semantics).
pub fn ring_all_reduce_mean(buffers: &mut [Vec<f32>]) -> Result<(), CollectiveError> {
    let n = buffers.len() as f32;
    ring_all_reduce(buffers)?;
    for b in buffers.iter_mut() {
        for v in b.iter_mut() {
            *v /= n;
        }
    }
    Ok(())
}

fn two_mut(buffers: &mut [Vec<f32>], i: usize, j: usize) -> (&[f32], &mut [f32]) {
    debug_assert_ne!(i, j);
    if i < j {
        let (a, b) = buffers.split_at_mut(j);
        (&a[i], &mut b[0])
    } else {
        let (a, b) = buffers.split_at_mut(i);
        (&b[0], &mut a[j]) // (src=i, dst=j)
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// What an injected fault does to its target rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The rank leaves the job permanently.
    Drop,
    /// Straggler: the collective is retried `attempts` times (modeled
    /// exponential backoff) before succeeding — or timing out if the
    /// retry budget is exhausted.
    Delay { attempts: u32 },
    /// The rank's contribution is poisoned with NaNs at seeded
    /// positions before the collective runs (silent data corruption —
    /// the numerical guardrails downstream must catch it).
    Corrupt,
    /// A previously-dropped rank comes back. It is readmitted at the
    /// step boundary (never mid-collective): the leader broadcasts the
    /// full training state and the survivors re-run owner assignment.
    Rejoin,
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::Drop => write!(f, "drop"),
            FaultKind::Delay { .. } => write!(f, "delay"),
            FaultKind::Corrupt => write!(f, "corrupt"),
            FaultKind::Rejoin => write!(f, "rejoin"),
        }
    }
}

/// One scheduled fault: at global training step `step`, rank `rank`
/// misbehaves during collective `op`. (`rejoin` events carry the
/// default `op` — they fire at the step boundary, not in a collective.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub step: usize,
    pub rank: usize,
    pub op: FaultOp,
    pub kind: FaultKind,
}

impl fmt::Display for FaultEvent {
    /// Canonical grammar form; [`FaultPlan::parse`] round-trips it.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            FaultKind::Rejoin => write!(f, "rejoin@{}:r{}", self.step, self.rank),
            FaultKind::Delay { attempts } => {
                write!(f, "delay@{}:r{}:{}:x{}", self.step, self.rank, self.op, attempts)
            }
            _ => write!(f, "{}@{}:r{}:{}", self.kind, self.step, self.rank, self.op),
        }
    }
}

impl std::str::FromStr for FaultEvent {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        parse_event(s.trim())
    }
}

/// Parse one `kind@step:rank[:op][:xN]` clause.
fn parse_event(tok: &str) -> Result<FaultEvent, String> {
    let (kind_s, rest) = tok
        .split_once('@')
        .ok_or_else(|| format!("fault `{tok}`: expected kind@step:rank[:op][:xN]"))?;
    let mut parts = rest.split(':');
    let step: usize = parts
        .next()
        .ok_or_else(|| format!("fault `{tok}`: missing step"))?
        .trim()
        .parse()
        .map_err(|_| format!("fault `{tok}`: bad step"))?;
    let rank_s = parts.next().ok_or_else(|| format!("fault `{tok}`: missing rank"))?;
    let rank: usize = rank_s
        .trim()
        .trim_start_matches('r')
        .parse()
        .map_err(|_| format!("fault `{tok}`: bad rank `{rank_s}`"))?;
    let mut op = FaultOp::GradReduce;
    let mut attempts: Option<u32> = None;
    for extra in parts {
        let extra = extra.trim();
        match extra {
            "grad" => op = FaultOp::GradReduce,
            "precond" => op = FaultOp::PrecondGather,
            "eval" => op = FaultOp::EvalBcast,
            _ if extra.starts_with('x') => {
                attempts = Some(
                    extra[1..]
                        .parse()
                        .map_err(|_| format!("fault `{tok}`: bad retry count `{extra}`"))?,
                );
            }
            _ => return Err(format!("fault `{tok}`: unknown field `{extra}`")),
        }
    }
    let kind = match kind_s.trim() {
        "drop" => FaultKind::Drop,
        "delay" => FaultKind::Delay { attempts: attempts.unwrap_or(1) },
        "corrupt" => FaultKind::Corrupt,
        "rejoin" => FaultKind::Rejoin,
        other => return Err(format!("fault `{tok}`: unknown kind `{other}`")),
    };
    if attempts.is_some() && !matches!(kind, FaultKind::Delay { .. }) {
        return Err(format!("fault `{tok}`: retry count `xN` only applies to delay"));
    }
    if matches!(kind, FaultKind::Rejoin) && rest.split(':').count() > 2 {
        return Err(format!("fault `{tok}`: rejoin takes no op or retry fields"));
    }
    Ok(FaultEvent { step, rank, op, kind })
}

/// A deterministic, seeded schedule of fault events.
///
/// Spec grammar (events separated by `;` or `,`):
///
/// ```text
/// kind@step:rank[:op][:xN]
/// kind = drop | delay | corrupt | rejoin
/// rank = r3 or 3
/// op   = grad (default) | precond | eval
/// xN   = delay retry count (delay only, default x1)
/// ```
///
/// e.g. `drop@3:r1:precond`, `delay@5:r0:grad:x2`, `corrupt@2:r1`,
/// `drop@2:r1:eval` (the eval-result broadcast). `rejoin@step:rank`
/// takes no op or retry fields: it readmits a previously-dropped rank
/// at the start of `step` (leader state broadcast + owner
/// re-assignment), so [`validate`](Self::validate) rejects a rejoin of
/// a rank the plan never drops.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    pub seed: u64,
}

impl fmt::Display for FaultPlan {
    /// Canonical spec form (events joined with `; `); parsing it back
    /// reproduces `events` exactly (the seed travels separately).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, ev) in self.events.iter().enumerate() {
            if i > 0 {
                write!(f, "; ")?;
            }
            write!(f, "{ev}")?;
        }
        Ok(())
    }
}

impl std::str::FromStr for FaultPlan {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        FaultPlan::parse(s, 0)
    }
}

impl FaultPlan {
    /// Parse a fault spec; `Err` carries a human-readable reason.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for raw in spec.split([';', ',']) {
            let tok = raw.trim();
            if tok.is_empty() {
                continue;
            }
            events.push(parse_event(tok)?);
        }
        Ok(FaultPlan { events, seed })
    }

    /// Static plan checks against a world size: every rank must exist,
    /// and every `rejoin` must target a rank that is dead at its step
    /// (killed earlier by a `drop` or a budget-exhausting `delay`).
    /// Rejoins at a step are ordered before kill events at the same
    /// step, mirroring the runtime (the readmission barrier runs at the
    /// step boundary, before the step's collectives).
    pub fn validate(&self, world: usize) -> Result<(), String> {
        for ev in &self.events {
            if ev.rank >= world {
                return Err(format!("`{ev}`: rank r{} out of range for workers={world}", ev.rank));
            }
        }
        let mut order: Vec<&FaultEvent> = self.events.iter().collect();
        order.sort_by_key(|e| (e.step, !matches!(e.kind, FaultKind::Rejoin)));
        let mut dead = std::collections::BTreeSet::new();
        let budget = RetryPolicy::default().max_attempts;
        for ev in order {
            match ev.kind {
                FaultKind::Drop => {
                    dead.insert(ev.rank);
                }
                FaultKind::Delay { attempts } if attempts >= budget => {
                    dead.insert(ev.rank);
                }
                FaultKind::Delay { .. } | FaultKind::Corrupt => {}
                FaultKind::Rejoin => {
                    if !dead.remove(&ev.rank) {
                        return Err(format!("`{ev}` readmits a rank that was never dropped"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Read `JORGE_FAULTS` / `JORGE_FAULT_SEED` from the environment.
    /// Returns `Ok(None)` when unset or empty.
    pub fn from_env() -> Result<Option<FaultPlan>, String> {
        let spec = match std::env::var("JORGE_FAULTS") {
            Ok(s) if !s.trim().is_empty() => s,
            _ => return Ok(None),
        };
        let seed = std::env::var("JORGE_FAULT_SEED")
            .ok()
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(0);
        FaultPlan::parse(&spec, seed).map(Some)
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Retry/backoff policy for straggler recovery. Backoff is *modeled*
/// (accounted in seconds, never slept): the simulated collectives run
/// in-process, so injected delays charge the cost model instead of
/// wall clock.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    pub max_attempts: u32,
    pub base_backoff_s: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, base_backoff_s: 50e-6 }
    }
}

impl RetryPolicy {
    /// Modeled backoff before retry attempt `i` (0-based): base * 2^i.
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        self.base_backoff_s * f64::from(1u32 << attempt.min(20))
    }
}

/// What the session actually did about a fault (for telemetry).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultRecord {
    pub step: usize,
    pub rank: usize,
    pub op: FaultOp,
    pub kind: FaultKind,
    /// e.g. "dropped", "recovered after 2 retries", "corrupted 8 values"
    pub action: String,
}

/// Stateful fault injector wrapping the collectives for one training
/// run. Owns the plan, the retry policy, per-rank liveness, and the
/// telemetry log. Deterministic: identical plan + seed ⇒ identical
/// injected bits and identical recovery sequence.
#[derive(Debug)]
pub struct FaultSession {
    plan: FaultPlan,
    policy: RetryPolicy,
    rng: Rng,
    fired: Vec<bool>,
    alive: Vec<bool>,
    records: Vec<FaultRecord>,
    retries: usize,
    modeled_backoff_s: f64,
    membership_epoch: usize,
    rejoins: usize,
    resync_bytes: usize,
    modeled_resync_s: f64,
}

impl FaultSession {
    pub fn new(plan: FaultPlan, world: usize) -> FaultSession {
        let rng = Rng::new(plan.seed ^ 0x6a6f_7267_655f_6674); // "jorge_ft"
        let fired = vec![false; plan.events.len()];
        FaultSession {
            plan,
            policy: RetryPolicy::default(),
            rng,
            fired,
            alive: vec![true; world],
            records: Vec::new(),
            retries: 0,
            modeled_backoff_s: 0.0,
            membership_epoch: 0,
            rejoins: 0,
            resync_bytes: 0,
            modeled_resync_s: 0.0,
        }
    }

    pub fn with_policy(mut self, policy: RetryPolicy) -> FaultSession {
        self.policy = policy;
        self
    }

    pub fn is_alive(&self, rank: usize) -> bool {
        self.alive.get(rank).copied().unwrap_or(false)
    }

    pub fn mark_dead(&mut self, rank: usize) {
        if let Some(a) = self.alive.get_mut(rank) {
            if *a {
                *a = false;
                self.membership_epoch += 1;
            }
        }
    }

    /// Readmit a rank; returns whether liveness actually flipped.
    pub fn mark_alive(&mut self, rank: usize) -> bool {
        if let Some(a) = self.alive.get_mut(rank) {
            if !*a {
                *a = true;
                self.membership_epoch += 1;
                return true;
            }
        }
        false
    }

    pub fn live_ranks(&self) -> Vec<usize> {
        (0..self.alive.len()).filter(|&r| self.alive[r]).collect()
    }

    pub fn records(&self) -> &[FaultRecord] {
        &self.records
    }

    pub fn retries(&self) -> usize {
        self.retries
    }

    pub fn modeled_backoff_s(&self) -> f64 {
        self.modeled_backoff_s
    }

    /// Bumped every time a rank leaves or rejoins the worker set.
    pub fn membership_epoch(&self) -> usize {
        self.membership_epoch
    }

    /// Ranks readmitted so far.
    pub fn rejoins(&self) -> usize {
        self.rejoins
    }

    /// Bytes of state broadcast to rejoining ranks so far.
    pub fn resync_bytes(&self) -> usize {
        self.resync_bytes
    }

    /// Modeled alpha-beta cost of the resync broadcasts so far.
    pub fn modeled_resync_s(&self) -> f64 {
        self.modeled_resync_s
    }

    /// Fire every `rejoin` event scheduled for `step`: flip the target
    /// ranks back to alive and return the readmitted ranks (the caller
    /// runs the resync broadcast + owner re-assignment). A rejoin whose
    /// target is already live — e.g. the paired drop never fired at
    /// runtime — is recorded as a no-op instead of erroring, keeping
    /// fuzzed plans panic-free.
    pub fn take_rejoins(&mut self, step: usize) -> Vec<usize> {
        let mut readmitted = Vec::new();
        for i in 0..self.plan.events.len() {
            let ev = self.plan.events[i];
            if self.fired[i] || ev.step != step || !matches!(ev.kind, FaultKind::Rejoin) {
                continue;
            }
            self.fired[i] = true;
            if !self.mark_alive(ev.rank) {
                self.records.push(FaultRecord {
                    step,
                    rank: ev.rank,
                    op: ev.op,
                    kind: ev.kind,
                    action: "already live; rejoin is a no-op".to_string(),
                });
                continue;
            }
            self.rejoins += 1;
            self.records.push(FaultRecord {
                step,
                rank: ev.rank,
                op: ev.op,
                kind: ev.kind,
                action: "readmitted; state resynced via leader broadcast".to_string(),
            });
            readmitted.push(ev.rank);
        }
        readmitted
    }

    /// Resync a rejoining rank: broadcast an opaque state blob (the
    /// checkpoint encoding) from world rank `root` to every rank in
    /// `ranks` over the real binomial-tree schedule, and return the
    /// copy received by world rank `recv` — byte-for-byte identical to
    /// the leader's blob (the schedule only memcpys, and the f32
    /// packing is a bit-level transmute). Charges `resync_bytes` and
    /// the modeled alpha-beta broadcast cost.
    pub fn resync_broadcast(
        &mut self,
        blob: &[u8],
        ranks: &[usize],
        root: usize,
        recv: usize,
        comm: &CommCostModel,
    ) -> Result<Vec<u8>, CollectiveError> {
        let world = ranks.len();
        let root_slot = ranks
            .iter()
            .position(|&r| r == root)
            .ok_or(CollectiveError::RootOutOfRange { root, world })?;
        let recv_slot = ranks
            .iter()
            .position(|&r| r == recv)
            .ok_or(CollectiveError::RootOutOfRange { root: recv, world })?;
        // pack bytes into f32 words (zero-pad the tail; lossless both
        // ways because from/to_le_bytes are bit transmutes)
        let words = blob.len().div_ceil(4);
        let mut payload = vec![0.0f32; words];
        for (i, chunk) in blob.chunks(4).enumerate() {
            let mut b = [0u8; 4];
            b[..chunk.len()].copy_from_slice(chunk);
            payload[i] = f32::from_le_bytes(b);
        }
        let mut bufs: Vec<Vec<f32>> = (0..world)
            .map(|s| if s == root_slot { payload.clone() } else { vec![0.0f32; words] })
            .collect();
        tree_broadcast(&mut bufs, root_slot)?;
        let mut out = Vec::with_capacity(words * 4);
        for w in &bufs[recv_slot] {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.truncate(blob.len());
        self.resync_bytes += blob.len();
        self.modeled_resync_s += comm.broadcast_time(blob.len(), world);
        Ok(out)
    }

    /// Next unfired event matching (step, op) whose target is in
    /// `ranks`, preferring drops so callers see membership changes
    /// before payload corruption. Rejoin events never fire here — they
    /// belong to the step-boundary barrier ([`take_rejoins`](Self::take_rejoins)),
    /// not to a collective.
    fn take_event(&mut self, step: usize, op: FaultOp, ranks: &[usize]) -> Option<usize> {
        let mut pick: Option<usize> = None;
        for (i, ev) in self.plan.events.iter().enumerate() {
            if self.fired[i]
                || ev.step != step
                || ev.op != op
                || !ranks.contains(&ev.rank)
                || matches!(ev.kind, FaultKind::Rejoin)
            {
                continue;
            }
            let is_drop = matches!(ev.kind, FaultKind::Drop);
            match pick {
                None => pick = Some(i),
                Some(j) => {
                    let picked_drop = matches!(self.plan.events[j].kind, FaultKind::Drop);
                    if is_drop && !picked_drop {
                        pick = Some(i);
                    }
                }
            }
        }
        if let Some(i) = pick {
            self.fired[i] = true;
        }
        pick
    }

    /// Poison up to 8 seeded positions of `buf` with NaN; returns how
    /// many were written.
    fn poison(&mut self, buf: &mut [f32]) -> usize {
        let n = buf.len().min(8);
        for _ in 0..n {
            let j = self.rng.below(buf.len() as u64) as usize;
            buf[j] = f32::NAN;
        }
        n
    }

    /// Resolve a drop or delay event: liveness, telemetry, and retry
    /// accounting. `Err` means the rank is gone (drop or exhausted
    /// retry budget); a recovered delay returns `Ok`.
    fn drop_or_delay(
        &mut self,
        step: usize,
        op: FaultOp,
        ev: FaultEvent,
    ) -> Result<(), CollectiveError> {
        match ev.kind {
            FaultKind::Drop => {
                self.mark_dead(ev.rank);
                self.records.push(FaultRecord {
                    step,
                    rank: ev.rank,
                    op,
                    kind: ev.kind,
                    action: "dropped; survivors re-form the ring".to_string(),
                });
                Err(CollectiveError::WorkerDropped { rank: ev.rank, step, op })
            }
            FaultKind::Delay { attempts } => {
                if attempts >= self.policy.max_attempts {
                    self.mark_dead(ev.rank);
                    self.records.push(FaultRecord {
                        step,
                        rank: ev.rank,
                        op,
                        kind: ev.kind,
                        action: format!(
                            "timed out after {} attempts; treated as dropped",
                            self.policy.max_attempts
                        ),
                    });
                    return Err(CollectiveError::Timeout {
                        rank: ev.rank,
                        step,
                        op,
                        attempts: self.policy.max_attempts,
                    });
                }
                for a in 0..attempts {
                    self.retries += 1;
                    self.modeled_backoff_s += self.policy.backoff_s(a);
                }
                self.records.push(FaultRecord {
                    step,
                    rank: ev.rank,
                    op,
                    kind: ev.kind,
                    action: format!("recovered after {attempts} retries"),
                });
                Ok(())
            }
            FaultKind::Corrupt | FaultKind::Rejoin => Ok(()),
        }
    }

    /// Apply every fault scheduled for (step, op) to `buffers` (one per
    /// entry of `ranks`, in the same order). Returns `Err` on a drop or
    /// timeout — buffers are then untouched for drops, and the caller
    /// must remove the dead rank and retry with the survivors.
    fn inject(
        &mut self,
        step: usize,
        op: FaultOp,
        buffers: &mut [Vec<f32>],
        ranks: &[usize],
    ) -> Result<(), CollectiveError> {
        debug_assert_eq!(buffers.len(), ranks.len());
        while let Some(i) = self.take_event(step, op, ranks) {
            let ev = self.plan.events[i];
            match ev.kind {
                FaultKind::Drop | FaultKind::Delay { .. } => self.drop_or_delay(step, op, ev)?,
                FaultKind::Corrupt => {
                    let slot = ranks.iter().position(|&r| r == ev.rank);
                    let poisoned = slot.map_or(0, |s| self.poison(&mut buffers[s]));
                    self.records.push(FaultRecord {
                        step,
                        rank: ev.rank,
                        op,
                        kind: ev.kind,
                        action: format!("poisoned {poisoned} values with NaN"),
                    });
                }
                FaultKind::Rejoin => {} // never yielded by take_event
            }
        }
        Ok(())
    }

    /// Fault-aware gradient all-reduce-mean over the live ranks.
    /// `ranks[i]` is the original rank owning `buffers[i]`.
    pub fn all_reduce_mean(
        &mut self,
        step: usize,
        buffers: &mut [Vec<f32>],
        ranks: &[usize],
    ) -> Result<(), CollectiveError> {
        self.inject(step, FaultOp::GradReduce, buffers, ranks)?;
        ring_all_reduce_mean(buffers)
    }

    /// Fault-aware ragged all-gather over the live ranks. `ranks[i]`
    /// owns `chunks[i]`.
    pub fn all_gather(
        &mut self,
        step: usize,
        chunks: &mut [Vec<f32>],
        ranks: &[usize],
    ) -> Result<Vec<Vec<f32>>, CollectiveError> {
        self.inject(step, FaultOp::PrecondGather, chunks, ranks)?;
        ring_all_gather(chunks)
    }

    /// Fault-aware tree broadcast from world rank `root` (the
    /// eval-result distribution). `ranks[i]` owns `buffers[i]`; `root`
    /// must be a member of `ranks`. `corrupt` on the root poisons the
    /// payload before it fans out (every rank receives NaNs); on a
    /// non-root rank it poisons that rank's received copy after the
    /// schedule runs — either way the event is recorded instead of
    /// silently ignored.
    pub fn broadcast(
        &mut self,
        step: usize,
        buffers: &mut [Vec<f32>],
        ranks: &[usize],
        root: usize,
    ) -> Result<(), CollectiveError> {
        debug_assert_eq!(buffers.len(), ranks.len());
        let root_slot = ranks
            .iter()
            .position(|&r| r == root)
            .ok_or(CollectiveError::RootOutOfRange { root, world: ranks.len() })?;
        let mut recv_corrupt: Vec<usize> = Vec::new();
        while let Some(i) = self.take_event(step, FaultOp::EvalBcast, ranks) {
            let ev = self.plan.events[i];
            match ev.kind {
                FaultKind::Drop | FaultKind::Delay { .. } => {
                    self.drop_or_delay(step, FaultOp::EvalBcast, ev)?;
                }
                FaultKind::Corrupt => {
                    let poisoned = if ev.rank == root {
                        self.poison(&mut buffers[root_slot])
                    } else {
                        // defer: the broadcast would overwrite it
                        if let Some(s) = ranks.iter().position(|&r| r == ev.rank) {
                            recv_corrupt.push(s);
                        }
                        buffers.get(root_slot).map_or(0, |b| b.len().min(8))
                    };
                    self.records.push(FaultRecord {
                        step,
                        rank: ev.rank,
                        op: FaultOp::EvalBcast,
                        kind: ev.kind,
                        action: format!("poisoned {poisoned} values with NaN"),
                    });
                }
                FaultKind::Rejoin => {} // never yielded by take_event
            }
        }
        tree_broadcast(buffers, root_slot)?;
        for s in recv_corrupt {
            let _ = self.poison(&mut buffers[s]);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Communication cost model (paper testbed: NVLink A100 nodes)
// ---------------------------------------------------------------------------

/// alpha-beta model: time = alpha * steps + bytes_on_wire / bandwidth.
#[derive(Clone, Copy, Debug)]
pub struct CommCostModel {
    /// per-message latency (s); NVLink ~ 5 us, IB cross-node ~ 15 us
    pub alpha: f64,
    /// link bandwidth (B/s); NVLink3 ~ 200 GB/s effective per direction
    pub beta: f64,
}

impl CommCostModel {
    pub fn nvlink_a100() -> Self {
        CommCostModel { alpha: 5e-6, beta: 200e9 }
    }

    pub fn ib_cluster() -> Self {
        CommCostModel { alpha: 15e-6, beta: 25e9 }
    }

    /// Ring all-reduce of `bytes` over `n` ranks:
    /// 2(n-1) steps, each moving bytes/n.
    pub fn ring_all_reduce_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2 * (n - 1);
        steps as f64 * self.alpha + (2.0 * (n - 1) as f64 / n as f64) * bytes as f64 / self.beta
    }

    /// All-gather of `bytes` total (each rank contributes bytes/n).
    pub fn all_gather_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n - 1) as f64 * self.alpha + ((n - 1) as f64 / n as f64) * bytes as f64 / self.beta
    }

    /// Ragged ring all-gather ([`ring_all_gather`]): n-1 forwarding
    /// steps, each paced by the largest chunk on the wire. For uniform
    /// chunks this reduces exactly to [`all_gather_time`](Self::all_gather_time)
    /// of the total payload.
    pub fn all_gather_ragged_time(&self, chunk_bytes: &[usize]) -> f64 {
        let n = chunk_bytes.len();
        if n <= 1 {
            return 0.0;
        }
        let max_chunk = chunk_bytes.iter().copied().max().unwrap_or(0);
        (n - 1) as f64 * (self.alpha + max_chunk as f64 / self.beta)
    }

    /// Binomial-tree broadcast ([`tree_broadcast`]): ceil(log2 n) rounds
    /// of the full payload.
    pub fn broadcast_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let rounds = ((n - 1).ilog2() + 1) as f64;
        rounds * (self.alpha + bytes as f64 / self.beta)
    }

    /// Point-to-point send.
    pub fn send_time(&self, bytes: usize) -> f64 {
        self.alpha + bytes as f64 / self.beta
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn make_buffers(n: usize, len: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let mut want = vec![0.0f32; len];
        for b in &bufs {
            for (w, v) in want.iter_mut().zip(b) {
                *w += v;
            }
        }
        (bufs, want)
    }

    #[test]
    fn ring_matches_sequential_sum() {
        for &(n, len) in &[(2usize, 10usize), (3, 7), (4, 100), (5, 1), (8, 1000), (7, 13)] {
            let (mut bufs, want) = make_buffers(n, len, n as u64);
            ring_all_reduce(&mut bufs).unwrap();
            for (r, b) in bufs.iter().enumerate() {
                for i in 0..len {
                    assert!(
                        (b[i] - want[i]).abs() < 1e-3 * want[i].abs().max(1.0),
                        "n={n} len={len} rank={r} i={i}: {} vs {}",
                        b[i],
                        want[i]
                    );
                }
            }
        }
    }

    #[test]
    fn tree_matches_sequential_sum() {
        for &(n, len) in &[(2usize, 16usize), (3, 5), (6, 64), (8, 128)] {
            let (mut bufs, want) = make_buffers(n, len, 100 + n as u64);
            tree_all_reduce(&mut bufs).unwrap();
            for b in &bufs {
                for i in 0..len {
                    assert!((b[i] - want[i]).abs() < 1e-3 * want[i].abs().max(1.0));
                }
            }
        }
    }

    #[test]
    fn mean_divides_by_n() {
        let (mut bufs, want) = make_buffers(4, 32, 9);
        ring_all_reduce_mean(&mut bufs).unwrap();
        for b in &bufs {
            for i in 0..32 {
                assert!((b[i] - want[i] / 4.0).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn single_rank_is_noop() {
        let mut bufs = vec![vec![1.0f32, 2.0]];
        ring_all_reduce(&mut bufs).unwrap();
        assert_eq!(bufs[0], vec![1.0, 2.0]);
    }

    #[test]
    fn empty_world_and_zero_length_ok() {
        let mut empty: Vec<Vec<f32>> = vec![];
        ring_all_reduce(&mut empty).unwrap();
        tree_all_reduce(&mut empty).unwrap();
        let mut bufs = vec![vec![], vec![]];
        ring_all_reduce(&mut bufs).unwrap();
        tree_all_reduce(&mut bufs).unwrap();
        ring_all_reduce_mean(&mut bufs).unwrap();
    }

    #[test]
    fn ragged_buffers_are_typed_errors() {
        let mut bufs = vec![vec![1.0f32, 2.0], vec![3.0f32]];
        let before = bufs.clone();
        match ring_all_reduce(&mut bufs) {
            Err(CollectiveError::Ragged { op, lens }) => {
                assert_eq!(op, "all-reduce");
                assert_eq!(lens, vec![2, 1]);
            }
            other => panic!("expected Ragged, got {other:?}"),
        }
        // buffers untouched on error
        assert_eq!(bufs, before);
        assert!(matches!(
            tree_all_reduce(&mut bufs),
            Err(CollectiveError::Ragged { .. })
        ));
        assert!(matches!(
            tree_broadcast(&mut bufs, 0),
            Err(CollectiveError::Ragged { .. })
        ));
    }

    #[test]
    fn broadcast_root_out_of_range_is_typed_error() {
        let mut bufs = vec![vec![1.0f32], vec![2.0f32]];
        match tree_broadcast(&mut bufs, 5) {
            Err(CollectiveError::RootOutOfRange { root, world }) => {
                assert_eq!((root, world), (5, 2));
            }
            other => panic!("expected RootOutOfRange, got {other:?}"),
        }
        let err = CollectiveError::RootOutOfRange { root: 5, world: 2 };
        assert!(err.to_string().contains("root 5"));
    }

    #[test]
    fn all_gather_assembles_ragged_chunks() {
        // varied chunk sizes, including an empty contribution
        for &n in &[2usize, 3, 4, 7] {
            let mut rng = Rng::new(40 + n as u64);
            let chunks: Vec<Vec<f32>> = (0..n)
                .map(|r| {
                    let len = if r == 1 { 0 } else { 3 * r + 1 };
                    (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
                })
                .collect();
            let want: Vec<f32> = chunks.iter().flatten().copied().collect();
            let out = ring_all_gather(&chunks).unwrap();
            assert_eq!(out.len(), n);
            for (r, b) in out.iter().enumerate() {
                assert_eq!(b, &want, "n={n} rank={r}");
            }
        }
    }

    #[test]
    fn all_gather_single_rank_returns_own_chunk() {
        let out = ring_all_gather(&[vec![1.0, 2.0, 3.0]]).unwrap();
        assert_eq!(out, vec![vec![1.0, 2.0, 3.0]]);
        assert!(ring_all_gather(&[]).unwrap().is_empty());
    }

    #[test]
    fn fault_plan_parses_grammar() {
        let plan =
            FaultPlan::parse("drop@3:r1:precond; delay@5:r0:grad:x2, corrupt@2:1", 7).unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(
            plan.events,
            vec![
                FaultEvent {
                    step: 3,
                    rank: 1,
                    op: FaultOp::PrecondGather,
                    kind: FaultKind::Drop
                },
                FaultEvent {
                    step: 5,
                    rank: 0,
                    op: FaultOp::GradReduce,
                    kind: FaultKind::Delay { attempts: 2 }
                },
                FaultEvent {
                    step: 2,
                    rank: 1,
                    op: FaultOp::GradReduce,
                    kind: FaultKind::Corrupt
                },
            ]
        );
        assert!(FaultPlan::parse("", 0).unwrap().is_empty());
        assert!(FaultPlan::parse("explode@1:r0", 0).is_err());
        assert!(FaultPlan::parse("drop@x:r0", 0).is_err());
        assert!(FaultPlan::parse("drop@1:r0:sideways", 0).is_err());
        // the eval-broadcast op is addressable
        let ev = FaultPlan::parse("drop@2:r1:eval", 0).unwrap().events[0];
        assert_eq!(ev.op, FaultOp::EvalBcast);
        assert_eq!(ev.op.to_string(), "eval");
    }

    #[test]
    fn fault_plan_parses_rejoin_and_rejects_extra_fields() {
        let plan = FaultPlan::parse("drop@2:r1:grad; rejoin@5:r1", 0).unwrap();
        assert_eq!(plan.events[1].kind, FaultKind::Rejoin);
        assert_eq!((plan.events[1].step, plan.events[1].rank), (5, 1));
        // rejoin is a step-boundary event: no op, no retry count
        assert!(FaultPlan::parse("rejoin@5:r1:grad", 0).is_err());
        assert!(FaultPlan::parse("rejoin@5:r1:precond", 0).is_err());
        assert!(FaultPlan::parse("rejoin@5:r1:x2", 0).is_err());
        // xN on non-delay kinds is an error too (it would silently
        // vanish on Display round-trip otherwise)
        assert!(FaultPlan::parse("drop@1:r0:grad:x2", 0).is_err());
        assert!(FaultPlan::parse("corrupt@1:r0:x3", 0).is_err());
    }

    #[test]
    fn fault_event_display_fromstr_round_trips_every_kind() {
        // exhaustive kind x op x attempts sweep
        let ops = [FaultOp::GradReduce, FaultOp::PrecondGather, FaultOp::EvalBcast];
        let mut events = Vec::new();
        for (i, &op) in ops.iter().enumerate() {
            events.push(FaultEvent { step: 3 + i, rank: i, op, kind: FaultKind::Drop });
            events.push(FaultEvent { step: 7 + i, rank: i, op, kind: FaultKind::Corrupt });
            for attempts in [1u32, 2, 9] {
                events.push(FaultEvent {
                    step: 11 + i,
                    rank: i,
                    op,
                    kind: FaultKind::Delay { attempts },
                });
            }
        }
        events.push(FaultEvent {
            step: 5,
            rank: 1,
            op: FaultOp::GradReduce,
            kind: FaultKind::Rejoin,
        });
        for ev in &events {
            let s = ev.to_string();
            let back: FaultEvent = s.parse().unwrap_or_else(|e| panic!("`{s}`: {e}"));
            assert_eq!(&back, ev, "display form `{s}` did not round-trip");
        }
        // whole-plan round-trip, including the `; ` joiner
        let plan = FaultPlan { events: events.clone(), seed: 9 };
        let respelled: FaultPlan = plan.to_string().parse().unwrap();
        assert_eq!(respelled.events, plan.events);
        // seeded random events round-trip too
        let mut rng = Rng::new(0xE1A5);
        for _ in 0..200 {
            let kind = match rng.below(4) {
                0 => FaultKind::Drop,
                1 => FaultKind::Delay { attempts: 1 + rng.below(9) as u32 },
                2 => FaultKind::Corrupt,
                _ => FaultKind::Rejoin,
            };
            // rejoin's canonical form carries no op, so its parse gets
            // the default
            let op = if matches!(kind, FaultKind::Rejoin) {
                FaultOp::GradReduce
            } else {
                ops[rng.below(3) as usize]
            };
            let ev = FaultEvent {
                step: rng.below(100) as usize,
                rank: rng.below(16) as usize,
                op,
                kind,
            };
            let back: FaultEvent = ev.to_string().parse().unwrap();
            assert_eq!(back, ev, "`{ev}` did not round-trip");
        }
    }

    #[test]
    fn fault_plan_validate_checks_ranks_and_rejoin_targets() {
        let ok = FaultPlan::parse("drop@2:r1; rejoin@5:r1", 0).unwrap();
        ok.validate(4).unwrap();
        // rank out of range
        assert!(ok.validate(1).is_err());
        // rejoin of a never-dropped rank
        let never = FaultPlan::parse("rejoin@5:r1", 0).unwrap();
        let err = never.validate(4).unwrap_err();
        assert!(err.contains("never dropped"), "{err}");
        // rejoin of a rank that was only delayed within budget
        let delayed = FaultPlan::parse("delay@2:r1:grad:x2; rejoin@5:r1", 0).unwrap();
        assert!(delayed.validate(4).is_err());
        // an exhausted delay is a drop, so its rejoin is legal
        let timed_out = FaultPlan::parse("delay@2:r1:grad:x9; rejoin@5:r1", 0).unwrap();
        timed_out.validate(4).unwrap();
        // double rejoin of the same drop is an error
        let twice = FaultPlan::parse("drop@2:r1; rejoin@5:r1; rejoin@7:r1", 0).unwrap();
        assert!(twice.validate(4).is_err());
        // drop -> rejoin -> drop -> rejoin is legal
        let cycle = FaultPlan::parse("drop@2:r1; rejoin@4:r1; drop@6:r1; rejoin@8:r1", 0).unwrap();
        cycle.validate(4).unwrap();
        // same-step ordering: the rejoin barrier runs before the step's
        // collectives, so rejoin@5 + drop@5 of the same rank is legal
        // only when a prior drop feeds the rejoin
        let same_step = FaultPlan::parse("drop@2:r1; rejoin@5:r1; drop@5:r1", 0).unwrap();
        same_step.validate(4).unwrap();
    }

    #[test]
    fn session_take_rejoins_flips_liveness_and_counts() {
        let plan = FaultPlan::parse("drop@2:r1; rejoin@5:r1", 0).unwrap();
        let mut sess = FaultSession::new(plan, 4);
        assert_eq!(sess.membership_epoch(), 0);
        // nothing scheduled at step 3
        assert!(sess.take_rejoins(3).is_empty());
        sess.mark_dead(1);
        assert_eq!(sess.membership_epoch(), 1);
        assert_eq!(sess.take_rejoins(5), vec![1]);
        assert!(sess.is_alive(1));
        assert_eq!(sess.membership_epoch(), 2);
        assert_eq!(sess.rejoins(), 1);
        assert_eq!(sess.live_ranks(), vec![0, 1, 2, 3]);
        // the event fired; it never fires again
        assert!(sess.take_rejoins(5).is_empty());
        let rec = sess.records().last().unwrap();
        assert_eq!(rec.kind, FaultKind::Rejoin);
        assert!(rec.action.contains("readmitted"), "{rec:?}");
    }

    #[test]
    fn session_rejoin_of_live_rank_is_recorded_noop() {
        // the paired drop targets a collective that never runs, so the
        // rank is still alive when the rejoin fires
        let plan = FaultPlan::parse("drop@2:r1:precond; rejoin@5:r1", 0).unwrap();
        let mut sess = FaultSession::new(plan, 4);
        assert!(sess.take_rejoins(5).is_empty());
        assert!(sess.is_alive(1));
        assert_eq!(sess.rejoins(), 0);
        let rec = sess.records().last().unwrap();
        assert!(rec.action.contains("no-op"), "{rec:?}");
    }

    #[test]
    fn rejoin_events_never_fire_inside_collectives() {
        let plan = FaultPlan::parse("rejoin@1:r0", 0).unwrap();
        let mut sess = FaultSession::new(plan, 2);
        let (mut a, _) = make_buffers(2, 16, 33);
        let mut b = a.clone();
        sess.all_reduce_mean(1, &mut a, &[0, 1]).unwrap();
        ring_all_reduce_mean(&mut b).unwrap();
        assert_eq!(a, b, "a rejoin event must not perturb a collective");
        assert!(sess.records().is_empty());
    }

    #[test]
    fn resync_broadcast_is_byte_exact_and_charged() {
        let comm = CommCostModel::nvlink_a100();
        let mut sess = FaultSession::new(FaultPlan::default(), 4);
        // arbitrary bytes, length not a multiple of 4 (exercises the
        // pad/truncate path), including NaN-pattern words
        let mut blob: Vec<u8> = (0..1037u32).map(|i| (i * 31 % 251) as u8).collect();
        blob[8..12].copy_from_slice(&f32::NAN.to_le_bytes());
        for recv in [1usize, 3] {
            let out = sess.resync_broadcast(&blob, &[0, 1, 2, 3], 0, recv, &comm).unwrap();
            assert_eq!(out, blob, "recv={recv}: resync must be byte-exact");
        }
        assert_eq!(sess.resync_bytes(), 2 * blob.len());
        assert!(sess.modeled_resync_s() > 0.0);
        let want = 2.0 * comm.broadcast_time(blob.len(), 4);
        assert!((sess.modeled_resync_s() - want).abs() < 1e-15, "{}", sess.modeled_resync_s());
        // root or receiver outside the rank set is a typed error
        assert!(matches!(
            sess.resync_broadcast(&blob, &[0, 2], 1, 0, &comm),
            Err(CollectiveError::RootOutOfRange { root: 1, .. })
        ));
        assert!(matches!(
            sess.resync_broadcast(&blob, &[0, 2], 0, 3, &comm),
            Err(CollectiveError::RootOutOfRange { root: 3, .. })
        ));
    }

    #[test]
    fn session_drop_errors_then_survivors_reduce() {
        let plan = FaultPlan::parse("drop@2:r1", 0).unwrap();
        let mut sess = FaultSession::new(plan, 3);
        let (mut bufs, want) = make_buffers(3, 16, 5);
        // steps without a scheduled fault behave exactly like the plain path
        sess.all_reduce_mean(0, &mut bufs, &[0, 1, 2]).unwrap();
        for b in &bufs {
            for i in 0..16 {
                assert!((b[i] - want[i] / 3.0).abs() < 1e-4);
            }
        }
        // step 2: rank 1 drops; the call reports it and buffers are intact
        let (mut bufs, _) = make_buffers(3, 16, 6);
        let before = bufs.clone();
        match sess.all_reduce_mean(2, &mut bufs, &[0, 1, 2]) {
            Err(CollectiveError::WorkerDropped { rank: 1, step: 2, op: FaultOp::GradReduce }) => {}
            other => panic!("expected drop, got {other:?}"),
        }
        assert_eq!(bufs, before);
        assert!(!sess.is_alive(1));
        assert_eq!(sess.live_ranks(), vec![0, 2]);
        // survivors retry with the dead rank removed and succeed
        let mut survivors = vec![bufs[0].clone(), bufs[2].clone()];
        sess.all_reduce_mean(2, &mut survivors, &[0, 2]).unwrap();
        let mut want2 = vec![0.0f32; 16];
        for b in [&before[0], &before[2]] {
            for (w, v) in want2.iter_mut().zip(b) {
                *w += v;
            }
        }
        for b in &survivors {
            for i in 0..16 {
                assert!((b[i] - want2[i] / 2.0).abs() < 1e-4);
            }
        }
        assert_eq!(sess.records().len(), 1);
    }

    #[test]
    fn session_delay_accounts_retries_and_preserves_result() {
        let plan = FaultPlan::parse("delay@1:r0:grad:x2", 0).unwrap();
        let mut sess = FaultSession::new(plan, 2);
        let (mut bufs, want) = make_buffers(2, 8, 11);
        sess.all_reduce_mean(1, &mut bufs, &[0, 1]).unwrap();
        for b in &bufs {
            for i in 0..8 {
                assert!((b[i] - want[i] / 2.0).abs() < 1e-4);
            }
        }
        assert_eq!(sess.retries(), 2);
        assert!(sess.modeled_backoff_s() > 0.0);
        assert!(sess.is_alive(0));
    }

    #[test]
    fn session_delay_beyond_budget_times_out() {
        let plan = FaultPlan::parse("delay@0:r1:grad:x9", 0).unwrap();
        let mut sess = FaultSession::new(plan, 2);
        let (mut bufs, _) = make_buffers(2, 8, 12);
        match sess.all_reduce_mean(0, &mut bufs, &[0, 1]) {
            Err(CollectiveError::Timeout { rank: 1, step: 0, attempts, .. }) => {
                assert_eq!(attempts, RetryPolicy::default().max_attempts);
            }
            other => panic!("expected timeout, got {other:?}"),
        }
        assert!(!sess.is_alive(1));
    }

    #[test]
    fn session_corrupt_is_deterministic_and_targeted() {
        let run = |seed| {
            let plan = FaultPlan::parse("corrupt@1:r1", seed).unwrap();
            let mut sess = FaultSession::new(plan, 2);
            let mut bufs = vec![vec![1.0f32; 32], vec![1.0f32; 32]];
            // corruption happens before the reduce, so NaN spreads — by design
            sess.all_reduce_mean(1, &mut bufs, &[0, 1]).unwrap();
            bufs
        };
        let a = run(3);
        let b = run(3);
        let c = run(4);
        assert_eq!(a, b, "same seed must corrupt the same bits");
        assert!(a[0].iter().any(|v| v.is_nan()), "corruption must propagate through the sum");
        // different seed picks (almost surely) different positions
        let nan_at = |bufs: &[Vec<f32>]| -> Vec<usize> {
            bufs[0].iter().enumerate().filter(|(_, v)| v.is_nan()).map(|(i, _)| i).collect()
        };
        assert_ne!(nan_at(&a), nan_at(&c));
    }

    #[test]
    fn session_gather_drop_then_survivor_gather() {
        let plan = FaultPlan::parse("drop@4:r1:precond", 0).unwrap();
        let mut sess = FaultSession::new(plan, 3);
        let mut chunks = vec![vec![1.0f32], vec![2.0f32, 2.5], vec![3.0f32]];
        match sess.all_gather(4, &mut chunks, &[0, 1, 2]) {
            Err(CollectiveError::WorkerDropped {
                rank: 1,
                step: 4,
                op: FaultOp::PrecondGather,
            }) => {}
            other => panic!("expected gather drop, got {other:?}"),
        }
        let mut survivors = vec![chunks[0].clone(), chunks[2].clone()];
        let out = sess.all_gather(4, &mut survivors, &[0, 2]).unwrap();
        assert_eq!(out, vec![vec![1.0, 3.0], vec![1.0, 3.0]]);
    }

    #[test]
    fn session_broadcast_routes_faults() {
        // drop during the eval broadcast surfaces as a typed error
        let plan = FaultPlan::parse("drop@3:r2:eval", 0).unwrap();
        let mut sess = FaultSession::new(plan, 4);
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|r| vec![r as f32; 2]).collect();
        match sess.broadcast(3, &mut bufs, &[0, 1, 2, 3], 0) {
            Err(CollectiveError::WorkerDropped { rank: 2, step: 3, op: FaultOp::EvalBcast }) => {}
            other => panic!("expected eval-broadcast drop, got {other:?}"),
        }
        assert!(!sess.is_alive(2));
        // survivors re-broadcast successfully
        let mut survivors = vec![vec![7.0f32, 8.0], vec![0.0; 2], vec![0.0; 2]];
        sess.broadcast(3, &mut survivors, &[0, 1, 3], 0).unwrap();
        assert!(survivors.iter().all(|b| b == &vec![7.0, 8.0]));
        assert_eq!(sess.records().len(), 1);
    }

    #[test]
    fn session_broadcast_corrupt_root_and_receiver() {
        // root corruption fans out to every rank
        let plan = FaultPlan::parse("corrupt@1:r0:eval", 5).unwrap();
        let mut sess = FaultSession::new(plan, 3);
        let mut bufs = vec![vec![1.0f32; 16], vec![0.0f32; 16], vec![0.0f32; 16]];
        sess.broadcast(1, &mut bufs, &[0, 1, 2], 0).unwrap();
        for b in &bufs {
            assert!(b.iter().any(|v| v.is_nan()), "root corruption must propagate");
        }
        // receiver corruption survives the overwrite (poisoned after)
        let plan = FaultPlan::parse("corrupt@1:r2:eval", 5).unwrap();
        let mut sess = FaultSession::new(plan, 3);
        let mut bufs = vec![vec![1.0f32; 16], vec![0.0f32; 16], vec![0.0f32; 16]];
        sess.broadcast(1, &mut bufs, &[0, 1, 2], 0).unwrap();
        assert!(bufs[0].iter().all(|v| v.is_finite()));
        assert!(bufs[1].iter().all(|v| v.is_finite()));
        assert!(bufs[2].iter().any(|v| v.is_nan()), "receiver copy must stay poisoned");
        assert_eq!(sess.records().len(), 1);
    }

    #[test]
    fn session_broadcast_no_fault_matches_plain_tree() {
        let mut sess = FaultSession::new(FaultPlan::default(), 4);
        let mut a: Vec<Vec<f32>> = (0..4).map(|r| vec![r as f32 + 0.5; 6]).collect();
        let mut b = a.clone();
        sess.broadcast(0, &mut a, &[0, 1, 2, 3], 1).unwrap();
        tree_broadcast(&mut b, 1).unwrap();
        assert_eq!(a, b);
        assert!(sess.records().is_empty());
        // root must be a member of the live set
        let mut bufs = vec![vec![0.0f32; 2], vec![0.0f32; 2]];
        assert!(matches!(
            sess.broadcast(0, &mut bufs, &[0, 2], 1),
            Err(CollectiveError::RootOutOfRange { root: 1, .. })
        ));
    }

    #[test]
    fn no_plan_is_bitwise_plain_path() {
        let mut sess = FaultSession::new(FaultPlan::default(), 4);
        let (mut a, _) = make_buffers(4, 64, 21);
        let mut b = a.clone();
        sess.all_reduce_mean(0, &mut a, &[0, 1, 2, 3]).unwrap();
        ring_all_reduce_mean(&mut b).unwrap();
        assert_eq!(a, b);
        assert!(sess.records().is_empty());
        assert_eq!(sess.retries(), 0);
    }

    #[test]
    fn all_gather_cost_accounting() {
        let m = CommCostModel::nvlink_a100();
        // uniform ragged chunks cost exactly the uniform all-gather
        for &n in &[2usize, 3, 4, 7] {
            let b = 1 << 20;
            let ragged = m.all_gather_ragged_time(&vec![b; n]);
            let uniform = m.all_gather_time(n * b, n);
            assert!((ragged - uniform).abs() < 1e-12 * uniform, "n={n}: {ragged} vs {uniform}");
        }
        // the largest chunk paces every step
        let skewed = m.all_gather_ragged_time(&[1 << 20, 8 << 20, 1 << 20]);
        let flat = m.all_gather_ragged_time(&[8 << 20, 8 << 20, 8 << 20]);
        assert_eq!(skewed, flat);
        // degenerate cases are free
        assert_eq!(m.all_gather_ragged_time(&[1 << 20]), 0.0);
        assert_eq!(m.all_gather_ragged_time(&[]), 0.0);
        // broadcast: log2 rounds
        let b1 = m.broadcast_time(1 << 20, 2);
        let b2 = m.broadcast_time(1 << 20, 8);
        assert!((b2 - 3.0 * b1).abs() < 1e-12, "{b1} {b2}");
        assert_eq!(m.broadcast_time(1 << 20, 1), 0.0);
    }

    #[test]
    fn broadcast_from_any_root() {
        for &n in &[2usize, 3, 5, 8] {
            for root in [0, n - 1, n / 2] {
                let mut bufs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; 6]).collect();
                let want = bufs[root].clone();
                tree_broadcast(&mut bufs, root).unwrap();
                for (r, b) in bufs.iter().enumerate() {
                    assert_eq!(b, &want, "n={n} root={root} rank={r}");
                }
            }
        }
    }

    #[test]
    fn cost_model_scales_sanely() {
        let m = CommCostModel::nvlink_a100();
        // bigger payload costs more; more ranks cost more latency
        let t1 = m.ring_all_reduce_time(100 << 20, 4);
        let t2 = m.ring_all_reduce_time(200 << 20, 4);
        let t3 = m.ring_all_reduce_time(100 << 20, 16);
        assert!(t2 > t1);
        assert!(t3 > t1);
        assert_eq!(m.ring_all_reduce_time(100 << 20, 1), 0.0);
        // ResNet-50 grads (100 MB) over 16 NVLink GPUs: ~1 ms — sanity band
        assert!(t3 > 5e-4 && t3 < 5e-2, "{t3}");
    }
}
