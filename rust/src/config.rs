//! Run configuration: a TOML-subset parser + the typed `TrainConfig`.
//!
//! The offline build has no serde/toml, so we carry a small parser that
//! covers what run configs need: `[section.sub]` tables, `key = value`
//! with strings, ints, floats, bools and flat arrays, plus `#` comments.
//! CLI flags (see `cli.rs`) override file values via `set_override`.

use crate::optim::OptimizerKind;
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat table: fully-qualified dotted keys -> values.
#[derive(Clone, Debug, Default)]
pub struct Toml {
    pub entries: BTreeMap<String, TomlValue>,
}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml, String> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(full, val);
        }
        Ok(Toml { entries })
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.as_i64())
            .map(|i| i as usize)
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn set_override(&mut self, key: &str, raw: &str) -> Result<(), String> {
        let val = parse_value(raw)?;
        self.entries.insert(key.to_string(), val);
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside of a string starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut out = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                out.push(parse_value(p)?);
            }
        }
        return Ok(TomlValue::Arr(out));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    // bare string (model names etc.)
    if s.chars().all(|c| c.is_alphanumeric() || matches!(c, '_' | '-' | '.')) {
        return Ok(TomlValue::Str(s.to_string()));
    }
    Err(format!("cannot parse value: {s:?}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '[' => depth += 1,
            ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

// ---------------------------------------------------------------------------
// Typed training configuration
// ---------------------------------------------------------------------------

/// Learning-rate schedule kinds (§4 / Fig. 1 of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    Constant,
    /// Step decay at fixed epoch boundaries, 10x decay each.
    Step,
    Cosine,
    /// Polynomial decay with power 0.9 (the torchvision DeepLabv3 default).
    Poly,
}

impl ScheduleKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "constant" => Ok(Self::Constant),
            "step" => Ok(Self::Step),
            "cosine" => Ok(Self::Cosine),
            "poly" => Ok(Self::Poly),
            other => Err(format!("unknown schedule {other:?}")),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Self::Constant => "constant",
            Self::Step => "step",
            Self::Cosine => "cosine",
            Self::Poly => "poly",
        }
    }
}

/// Owner-assignment policy for sharded preconditioner refreshes
/// (`shampoo_sharded` / `jorge_sharded`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Greedy longest-processing-time over per-layer refresh FLOPs —
    /// deterministic and balanced (default).
    #[default]
    Flops,
    /// Deal preconditioned layers round-robin in layer order.
    RoundRobin,
}

impl ShardPolicy {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "flops" => Ok(Self::Flops),
            "round_robin" => Ok(Self::RoundRobin),
            other => Err(format!(
                "unknown shard policy {other:?} (choose flops | round_robin)"
            )),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Self::Flops => "flops",
            Self::RoundRobin => "round_robin",
        }
    }
}

/// Everything a training run needs. Defaults follow §4's single-shot
/// bootstrapping rules applied to the synthetic benchmarks.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub optimizer: OptimizerKind,
    /// Owner assignment for sharded optimizers; ignored otherwise.
    pub shard_policy: ShardPolicy,
    pub epochs: usize,
    pub steps_per_epoch: usize,
    pub lr: f64,
    pub weight_decay: f64,
    pub schedule: ScheduleKind,
    /// Step-decay boundaries as epoch fractions (paper: 1/3 and 2/3).
    pub decay_at: Vec<f64>,
    pub warmup_epochs: f64,
    /// Preconditioner update interval in steps (paper Table 6: 50/4/8).
    pub precond_every: usize,
    pub seed: u64,
    /// Simulated data-parallel worker count ("GPUs").
    pub workers: usize,
    pub dataset_size: usize,
    pub eval_every_epochs: usize,
    pub target_metric: f64,
    pub artifacts_dir: String,
    pub out_dir: String,
    /// Use the native Rust optimizer mirrors instead of HLO artifacts
    /// (fast path for convergence studies; numerics cross-validated).
    pub native: bool,
    /// Execution backend: "auto" (PJRT when built+artifacts present,
    /// native otherwise), "native", or "pjrt".
    pub backend: String,
    pub log_every: usize,
    pub max_steps: usize,
    /// Fault-injection plan for the collectives substrate, in
    /// `FaultPlan::parse` grammar (`kind@step:rank[:op][:xN]`, `;`- or
    /// `,`-separated). Empty = no injection. `JORGE_FAULTS` in the
    /// environment is the fallback when this is empty.
    pub faults: String,
    /// Seed for the fault plan's deterministic corruption positions.
    pub fault_seed: u64,
    /// Write a crash-safe checkpoint every N optimizer steps (0 = off).
    pub checkpoint_every: usize,
    /// Directory for cadence checkpoints / auto-resume discovery;
    /// empty = a run-keyed default under `out_dir`.
    pub checkpoint_dir: String,
    /// Resume mode: "" (fresh), "auto" (newest valid checkpoint in
    /// `checkpoint_dir`, skipping corrupt files), or an explicit path.
    pub resume: String,
    /// Per-step phase-trace JSONL output path (empty = tracing off).
    pub trace_path: String,
    /// Run-summary metrics JSON output path (empty = off). Uses the
    /// `BENCH_*.json` envelope so `jorge bench-diff` can diff it.
    pub metrics_out: String,
    /// Defer the sharded preconditioner exchange by one step: owners
    /// refresh at step t, the gathered import lands at the t+1 step
    /// boundary, and step t applies one-refresh-stale preconditioners
    /// (async-Shampoo style). Sharded optimizers only.
    pub precond_overlap: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "mlp".into(),
            optimizer: OptimizerKind::JORGE,
            shard_policy: ShardPolicy::Flops,
            epochs: 12,
            steps_per_epoch: 50,
            lr: 0.1,
            weight_decay: 1e-4,
            schedule: ScheduleKind::Step,
            decay_at: vec![1.0 / 3.0, 2.0 / 3.0],
            warmup_epochs: 0.0,
            precond_every: 1,
            seed: 17,
            workers: 1,
            dataset_size: 3200,
            eval_every_epochs: 1,
            target_metric: 0.0,
            artifacts_dir: "artifacts".into(),
            out_dir: "runs".into(),
            native: false,
            backend: "auto".into(),
            log_every: 10,
            max_steps: usize::MAX,
            faults: String::new(),
            fault_seed: 0,
            checkpoint_every: 0,
            checkpoint_dir: String::new(),
            resume: String::new(),
            trace_path: String::new(),
            metrics_out: String::new(),
            precond_overlap: false,
        }
    }
}

impl TrainConfig {
    pub fn from_toml(t: &Toml) -> Result<Self, String> {
        let d = TrainConfig::default();
        let schedule = ScheduleKind::parse(&t.str_or("train.schedule", d.schedule.name()))?;
        let decay_at = match t.get("train.decay_at") {
            Some(TomlValue::Arr(a)) => a
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| "decay_at: non-number".to_string()))
                .collect::<Result<Vec<_>, _>>()?,
            _ => d.decay_at.clone(),
        };
        let optimizer =
            t.str_or("train.optimizer", &d.optimizer.to_string()).parse::<OptimizerKind>()?;
        let shard_policy =
            ShardPolicy::parse(&t.str_or("train.shard_policy", d.shard_policy.name()))?;
        let cfg = TrainConfig {
            model: t.str_or("train.model", &d.model),
            optimizer,
            shard_policy,
            epochs: t.usize_or("train.epochs", d.epochs),
            steps_per_epoch: t.usize_or("train.steps_per_epoch", d.steps_per_epoch),
            lr: t.f64_or("train.lr", d.lr),
            weight_decay: t.f64_or("train.weight_decay", d.weight_decay),
            schedule,
            decay_at,
            warmup_epochs: t.f64_or("train.warmup_epochs", d.warmup_epochs),
            precond_every: t.usize_or("train.precond_every", d.precond_every),
            seed: t.usize_or("train.seed", d.seed as usize) as u64,
            workers: t.usize_or("train.workers", d.workers),
            dataset_size: t.usize_or("data.size", d.dataset_size),
            eval_every_epochs: t.usize_or("train.eval_every_epochs", d.eval_every_epochs),
            target_metric: t.f64_or("train.target_metric", d.target_metric),
            artifacts_dir: t.str_or("paths.artifacts", &d.artifacts_dir),
            out_dir: t.str_or("paths.out", &d.out_dir),
            native: t.bool_or("train.native", d.native),
            backend: t.str_or("train.backend", &d.backend),
            log_every: t.usize_or("train.log_every", d.log_every),
            max_steps: t.usize_or("train.max_steps", d.max_steps),
            faults: t.str_or("train.faults", &d.faults),
            fault_seed: t.usize_or("train.fault_seed", d.fault_seed as usize) as u64,
            checkpoint_every: t.usize_or("train.checkpoint_every", d.checkpoint_every),
            checkpoint_dir: t.str_or("paths.checkpoints", &d.checkpoint_dir),
            resume: t.str_or("train.resume", &d.resume),
            trace_path: t.str_or("paths.trace", &d.trace_path),
            metrics_out: t.str_or("paths.metrics_out", &d.metrics_out),
            precond_overlap: t.bool_or("train.precond_overlap", d.precond_overlap),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        const MODELS: &[&str] = &["mlp", "cnn", "segnet", "transformer"];
        if !MODELS.contains(&self.model.as_str()) {
            return Err(format!("unknown model {:?} (choose {MODELS:?})", self.model));
        }
        let backends = crate::runtime::backend::BACKEND_CHOICES;
        if !backends.contains(&self.backend.as_str()) {
            return Err(format!("unknown backend {:?} (choose {backends:?})", self.backend));
        }
        if self.epochs == 0 || self.steps_per_epoch == 0 {
            return Err("epochs and steps_per_epoch must be > 0".into());
        }
        if self.precond_every == 0 {
            return Err("precond_every must be >= 1".into());
        }
        if self.workers == 0 || self.workers > 64 {
            return Err("workers must be in 1..=64".into());
        }
        if !(self.lr > 0.0) {
            return Err("lr must be positive".into());
        }
        for &f in &self.decay_at {
            if !(0.0..=1.0).contains(&f) {
                return Err("decay_at fractions must be in [0,1]".into());
            }
        }
        // Combinations the coordinator would silently ignore are errors;
        // the one documented downgrade (sharded optimizer, workers == 1)
        // is allowed and logged by the trainer instead.
        if self.native && self.workers == 1 {
            return Err("native = true has no effect with workers = 1 (the single-worker \
                 path already runs the fused native step); drop it or set workers > 1"
                .into());
        }
        if self.shard_policy != ShardPolicy::Flops && !self.optimizer.sharded {
            return Err(format!(
                "shard_policy = {} only applies to sharded optimizers ({} is not sharded)",
                self.shard_policy.name(),
                self.optimizer
            ));
        }
        if self.precond_overlap && !self.optimizer.sharded {
            return Err(format!(
                "precond_overlap only applies to sharded optimizers ({} has no \
                 preconditioner exchange to overlap)",
                self.optimizer
            ));
        }
        if !self.faults.is_empty() {
            // faults only bite where collectives run; a silently inert
            // plan is an error like the other ignored combinations
            if self.workers == 1 {
                return Err(
                    "faults only apply to the collectives path; set workers > 1".into()
                );
            }
            let plan = crate::collectives::FaultPlan::parse(&self.faults, self.fault_seed)
                .map_err(|e| format!("faults: {e}"))?;
            // static plan checks: ranks must exist at this world size,
            // and a rejoin must target a rank the plan actually drops
            plan.validate(self.workers).map_err(|e| format!("faults: {e}"))?;
        }
        Ok(())
    }

    /// §4's single-shot bootstrap: derive a Jorge config from an SGD one.
    /// lr is inherited via grafting, weight decay scaled by 1/(1-beta),
    /// schedule forced to step decay at 1/3 and 2/3 of the budget.
    pub fn bootstrap_jorge_from_sgd(sgd: &TrainConfig, sgd_momentum: f64) -> TrainConfig {
        let mut j = sgd.clone();
        j.optimizer = OptimizerKind::JORGE;
        j.weight_decay = sgd.weight_decay / (1.0 - sgd_momentum);
        j.schedule = ScheduleKind::Step;
        j.decay_at = vec![1.0 / 3.0, 2.0 / 3.0];
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run config
[train]
model = "cnn"
optimizer = jorge          # bare string accepted
epochs = 30
lr = 0.1
weight_decay = 1e-4
schedule = "step"
decay_at = [0.33, 0.66]
precond_every = 4
workers = 4

[data]
size = 6400

[paths]
artifacts = "artifacts"
"#;

    #[test]
    fn parses_sections_and_values() {
        let t = Toml::parse(SAMPLE).unwrap();
        assert_eq!(t.str_or("train.model", "?"), "cnn");
        assert_eq!(t.usize_or("train.epochs", 0), 30);
        assert_eq!(t.f64_or("train.weight_decay", 0.0), 1e-4);
        assert_eq!(t.usize_or("data.size", 0), 6400);
        match t.get("train.decay_at").unwrap() {
            TomlValue::Arr(a) => assert_eq!(a.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn typed_config_roundtrip() {
        let t = Toml::parse(SAMPLE).unwrap();
        let c = TrainConfig::from_toml(&t).unwrap();
        assert_eq!(c.model, "cnn");
        assert_eq!(c.optimizer, OptimizerKind::JORGE);
        assert_eq!(c.workers, 4);
        assert_eq!(c.precond_every, 4);
        assert_eq!(c.schedule, ScheduleKind::Step);
        assert_eq!(c.shard_policy, ShardPolicy::Flops);
    }

    #[test]
    fn sharded_optimizers_parse_from_toml() {
        let mut t = Toml::parse(SAMPLE).unwrap();
        t.set_override("train.optimizer", "jorge_sharded").unwrap();
        t.set_override("train.shard_policy", "round_robin").unwrap();
        let c = TrainConfig::from_toml(&t).unwrap();
        assert_eq!(c.optimizer, OptimizerKind::JORGE_SHARDED);
        assert_eq!(c.shard_policy, ShardPolicy::RoundRobin);
    }

    #[test]
    fn defaults_apply() {
        let t = Toml::parse("").unwrap();
        let c = TrainConfig::from_toml(&t).unwrap();
        assert_eq!(c.model, "mlp");
        assert_eq!(c.decay_at, vec![1.0 / 3.0, 2.0 / 3.0]);
    }

    #[test]
    fn overrides_win() {
        let mut t = Toml::parse(SAMPLE).unwrap();
        t.set_override("train.epochs", "90").unwrap();
        let c = TrainConfig::from_toml(&t).unwrap();
        assert_eq!(c.epochs, 90);
    }

    #[test]
    fn validation_rejects_bad_values() {
        let mut t = Toml::parse(SAMPLE).unwrap();
        t.set_override("train.model", "\"resnet900\"").unwrap();
        assert!(TrainConfig::from_toml(&t).is_err());

        let mut t2 = Toml::parse(SAMPLE).unwrap();
        t2.set_override("train.precond_every", "0").unwrap();
        assert!(TrainConfig::from_toml(&t2).is_err());

        let mut t3 = Toml::parse(SAMPLE).unwrap();
        t3.set_override("train.workers", "100").unwrap();
        assert!(TrainConfig::from_toml(&t3).is_err());

        // first-order optimizers cannot shard preconditioner work
        let mut t4 = Toml::parse(SAMPLE).unwrap();
        t4.set_override("train.optimizer", "sgd_sharded").unwrap();
        assert!(TrainConfig::from_toml(&t4).is_err());
    }

    #[test]
    fn validation_rejects_silently_ignored_combinations() {
        // native = true is a no-op at workers = 1 — reject, don't ignore
        let mut t = Toml::parse(SAMPLE).unwrap();
        t.set_override("train.native", "true").unwrap();
        t.set_override("train.workers", "1").unwrap();
        let err = TrainConfig::from_toml(&t).unwrap_err();
        assert!(err.contains("native"), "{err}");

        // ...but it is meaningful with workers > 1
        let mut t2 = Toml::parse(SAMPLE).unwrap();
        t2.set_override("train.native", "true").unwrap();
        assert!(TrainConfig::from_toml(&t2).is_ok());

        // a non-default shard policy without a sharded optimizer would be
        // silently ignored — reject
        let mut t3 = Toml::parse(SAMPLE).unwrap();
        t3.set_override("train.shard_policy", "round_robin").unwrap();
        let err = TrainConfig::from_toml(&t3).unwrap_err();
        assert!(err.contains("shard_policy"), "{err}");

        // sharded optimizer at workers = 1 stays valid (trainer downgrades
        // with a logged note)
        let mut t4 = Toml::parse(SAMPLE).unwrap();
        t4.set_override("train.optimizer", "shampoo_sharded").unwrap();
        t4.set_override("train.workers", "1").unwrap();
        assert!(TrainConfig::from_toml(&t4).is_ok());
    }

    #[test]
    fn precond_overlap_requires_sharded_optimizer() {
        // overlap on a serial optimizer would be silently inert — reject
        let mut t = Toml::parse(SAMPLE).unwrap();
        t.set_override("train.precond_overlap", "true").unwrap();
        let err = TrainConfig::from_toml(&t).unwrap_err();
        assert!(err.contains("precond_overlap"), "{err}");

        // sharded optimizer: valid at any worker count (workers = 1 rides
        // the documented sharded downgrade note, overlap included)
        let mut t2 = Toml::parse(SAMPLE).unwrap();
        t2.set_override("train.optimizer", "jorge_sharded").unwrap();
        t2.set_override("train.precond_overlap", "true").unwrap();
        let c = TrainConfig::from_toml(&t2).unwrap();
        assert!(c.precond_overlap);

        let mut t3 = Toml::parse(SAMPLE).unwrap();
        t3.set_override("train.optimizer", "jorge_sharded").unwrap();
        t3.set_override("train.precond_overlap", "true").unwrap();
        t3.set_override("train.workers", "1").unwrap();
        assert!(TrainConfig::from_toml(&t3).is_ok());
    }

    #[test]
    fn fault_and_checkpoint_fields_parse() {
        let mut t = Toml::parse(SAMPLE).unwrap();
        t.set_override("train.faults", "\"drop@3:1:precond\"").unwrap();
        t.set_override("train.fault_seed", "9").unwrap();
        t.set_override("train.checkpoint_every", "5").unwrap();
        t.set_override("train.resume", "\"auto\"").unwrap();
        t.set_override("paths.checkpoints", "\"/tmp/ck\"").unwrap();
        let c = TrainConfig::from_toml(&t).unwrap();
        assert_eq!(c.faults, "drop@3:1:precond");
        assert_eq!(c.fault_seed, 9);
        assert_eq!(c.checkpoint_every, 5);
        assert_eq!(c.resume, "auto");
        assert_eq!(c.checkpoint_dir, "/tmp/ck");
    }

    #[test]
    fn fault_validation_rejects_bad_plans() {
        // malformed plan grammar is a config error, not a runtime one
        let mut t = Toml::parse(SAMPLE).unwrap();
        t.set_override("train.faults", "\"explode@x\"").unwrap();
        let err = TrainConfig::from_toml(&t).unwrap_err();
        assert!(err.contains("faults"), "{err}");

        // a plan with no collectives to bite on is silently inert — reject
        let mut t2 = Toml::parse(SAMPLE).unwrap();
        t2.set_override("train.faults", "\"drop@3:1\"").unwrap();
        t2.set_override("train.workers", "1").unwrap();
        let err = TrainConfig::from_toml(&t2).unwrap_err();
        assert!(err.contains("workers"), "{err}");

        // a rank outside the world is a plan bug, not a runtime surprise
        let mut t3 = Toml::parse(SAMPLE).unwrap();
        t3.set_override("train.faults", "\"drop@3:r9\"").unwrap();
        let err = TrainConfig::from_toml(&t3).unwrap_err();
        assert!(err.contains("out of range"), "{err}");

        // rejoin must target a rank the plan previously drops
        let mut t4 = Toml::parse(SAMPLE).unwrap();
        t4.set_override("train.faults", "\"rejoin@5:r1\"").unwrap();
        let err = TrainConfig::from_toml(&t4).unwrap_err();
        assert!(err.contains("never dropped"), "{err}");

        // ...and the drop+rejoin pair is a valid plan
        let mut t5 = Toml::parse(SAMPLE).unwrap();
        t5.set_override("train.faults", "\"drop@2:r1:precond; rejoin@5:r1\"").unwrap();
        let c = TrainConfig::from_toml(&t5).unwrap();
        assert_eq!(c.faults, "drop@2:r1:precond; rejoin@5:r1");
    }

    #[test]
    fn parse_errors_have_line_numbers() {
        let err = Toml::parse("[train\nx = 1").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = Toml::parse("justakey").unwrap_err();
        assert!(err.contains("key = value"), "{err}");
    }

    #[test]
    fn comments_and_strings() {
        let t = Toml::parse("name = \"a # not comment\" # real comment").unwrap();
        assert_eq!(t.str_or("name", ""), "a # not comment");
    }

    #[test]
    fn bootstrap_rule_matches_paper() {
        let mut sgd = TrainConfig::default();
        sgd.optimizer = OptimizerKind::SGD;
        sgd.weight_decay = 1e-4;
        sgd.schedule = ScheduleKind::Cosine;
        let j = TrainConfig::bootstrap_jorge_from_sgd(&sgd, 0.9);
        assert_eq!(j.optimizer, OptimizerKind::JORGE);
        assert!((j.weight_decay - 1e-3).abs() < 1e-12); // 10x
        assert_eq!(j.schedule, ScheduleKind::Step);
        assert_eq!(j.lr, sgd.lr); // grafting carries SGD's lr
    }

    #[test]
    fn arrays_of_arrays() {
        let t = Toml::parse("x = [[1, 2], [3]]").unwrap();
        match t.get("x").unwrap() {
            TomlValue::Arr(a) => {
                assert_eq!(a.len(), 2);
                match &a[0] {
                    TomlValue::Arr(inner) => assert_eq!(inner.len(), 2),
                    _ => panic!(),
                }
            }
            _ => panic!(),
        }
    }
}
