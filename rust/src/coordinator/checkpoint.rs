//! Crash-safe binary checkpoint format for params + optimizer state.
//!
//! Layout (version 2): magic `"JORGECKPT"`, version byte `0x02`, u32
//! tensor count, then per tensor: u32 name_len, name bytes, u8 dtype
//! (0=f32, 1=i32), u32 ndims, u64 dims..., raw little-endian data; the
//! file ends with a CRC32 (IEEE) trailer over every preceding byte.
//! Version-1 files (`"JORGECKPT\x01"`, no trailer) still load.
//!
//! Saves are atomic: the bytes are written to `<path>.tmp`, fsynced,
//! then renamed over the destination — a crash mid-save leaves either
//! the old checkpoint or a `.tmp` leftover that discovery ignores,
//! never a half-written file under the real name. Loads are fully
//! bounds-checked against the actual file size before any allocation,
//! and corruption (truncation, bit flips, unknown dtypes) surfaces as a
//! typed [`CkptError`] instead of a panic or garbage tensors.

use crate::runtime::HostTensor;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 9] = b"JORGECKPT";
const VERSION: u8 = 2;

const MAX_TENSORS: usize = 1_000_000;
const MAX_NAME_LEN: usize = 4096;
const MAX_NDIMS: usize = 16;
const MAX_ELEMS: usize = 1 << 30;

// ---------------------------------------------------------------------------
// Typed errors
// ---------------------------------------------------------------------------

/// Typed checkpoint failure. Implements `std::error::Error`, so `?`
/// lifts it into `anyhow::Result` at the coordinator/CLI layer.
#[derive(Debug)]
pub enum CkptError {
    Io(std::io::Error),
    /// Not a jorge checkpoint at all.
    BadMagic,
    /// A jorge checkpoint from a format this build does not read.
    UnsupportedVersion(u8),
    /// The file ends before the field being read.
    Truncated { context: &'static str },
    /// CRC32 trailer mismatch — the file was bit-flipped or partially
    /// overwritten after it was written.
    Checksum { stored: u32, computed: u32 },
    /// A header field fails its sanity bound (guards allocations).
    Implausible { what: &'static str, value: u64 },
    /// Unknown dtype tag byte.
    BadDtype(u8),
    /// Tensor name is not UTF-8.
    BadName,
    /// Bytes remain after the last tensor (and before any trailer).
    TrailingData { bytes: usize },
}

impl fmt::Display for CkptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint io: {e}"),
            CkptError::BadMagic => write!(f, "not a jorge checkpoint (bad magic)"),
            CkptError::UnsupportedVersion(v) => {
                write!(f, "unsupported checkpoint version {v} (this build reads 1-{VERSION})")
            }
            CkptError::Truncated { context } => {
                write!(f, "truncated checkpoint: file ends inside {context}")
            }
            CkptError::Checksum { stored, computed } => write!(
                f,
                "checkpoint checksum mismatch: stored {stored:#010x}, computed {computed:#010x} \
                 (bit flip or partial write)"
            ),
            CkptError::Implausible { what, value } => {
                write!(f, "implausible checkpoint field: {what} = {value}")
            }
            CkptError::BadDtype(tag) => write!(f, "unknown dtype tag {tag}"),
            CkptError::BadName => write!(f, "tensor name is not valid UTF-8"),
            CkptError::TrailingData { bytes } => {
                write!(f, "{bytes} unexpected trailing bytes after the last tensor")
            }
        }
    }
}

impl std::error::Error for CkptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CkptError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> Self {
        CkptError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected, poly 0xEDB88320) — in-tree, no deps
// ---------------------------------------------------------------------------

fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

pub fn crc32(data: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Save (atomic: tmp + fsync + rename)
// ---------------------------------------------------------------------------

/// Serialize tensors into the versioned checkpoint byte format
/// (magic + version + body + CRC32 trailer). This is the exact on-disk
/// encoding [`save`] writes — the rejoin resync broadcasts the same
/// blob over the wire, so resync and `--resume` share one codepath.
pub fn encode_blob(tensors: &[(String, &HostTensor)]) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.push(VERSION);
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        let (tag, shape): (u8, &[usize]) = match t {
            HostTensor::F32 { shape, .. } => (0, shape),
            HostTensor::I32 { shape, .. } => (1, shape),
        };
        buf.push(tag);
        buf.extend_from_slice(&(shape.len() as u32).to_le_bytes());
        for &d in shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        match t {
            HostTensor::F32 { data, .. } => {
                for v in data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            HostTensor::I32 { data, .. } => {
                for v in data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

/// Atomically write a checkpoint: serialize, write `<path>.tmp`, fsync,
/// rename over `path`. The destination is either the complete new file
/// or whatever was there before — never a torn write.
pub fn save(path: impl AsRef<Path>, tensors: &[(String, &HostTensor)]) -> Result<(), CkptError> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)?;
        }
    }
    let bytes = encode_blob(tensors);
    let tmp = {
        let mut os = path.as_os_str().to_os_string();
        os.push(".tmp");
        PathBuf::from(os)
    };
    let written = write_atomic(&tmp, path, &bytes);
    if written.is_err() {
        fs::remove_file(&tmp).ok();
    }
    written
}

fn write_atomic(tmp: &Path, path: &Path, bytes: &[u8]) -> Result<(), CkptError> {
    use std::io::Write;
    let mut f = fs::File::create(tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    fs::rename(tmp, path)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Load (whole-file slice parser, bounds checked before every allocation)
// ---------------------------------------------------------------------------

struct Cur<'a> {
    b: &'a [u8],
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, context: &'static str) -> Result<&'a [u8], CkptError> {
        if self.b.len() < n {
            return Err(CkptError::Truncated { context });
        }
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    fn u8(&mut self, context: &'static str) -> Result<u8, CkptError> {
        Ok(self.take(1, context)?[0])
    }

    fn u32(&mut self, context: &'static str) -> Result<u32, CkptError> {
        let b = self.take(4, context)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, context: &'static str) -> Result<u64, CkptError> {
        let b = self.take(8, context)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<(String, HostTensor)>, CkptError> {
    let bytes = fs::read(path)?;
    decode_blob(&bytes)
}

/// Parse a checkpoint byte blob ([`encode_blob`]'s inverse): magic and
/// version checks, CRC32 verification (v2), then the fully
/// bounds-checked tensor parse. `decode_blob(&encode_blob(t)) == t`
/// bitwise — the rejoin resync relies on this to restore a broadcast
/// state blob exactly as `--resume` would restore the file.
pub fn decode_blob(bytes: &[u8]) -> Result<Vec<(String, HostTensor)>, CkptError> {
    if bytes.len() < MAGIC.len() + 1 {
        return Err(CkptError::Truncated { context: "magic/version header" });
    }
    if &bytes[..MAGIC.len()] != MAGIC {
        return Err(CkptError::BadMagic);
    }
    let version = bytes[MAGIC.len()];
    let body = match version {
        1 => &bytes[MAGIC.len() + 1..],
        2 => {
            // last 4 bytes are the CRC32 of everything before them
            if bytes.len() < MAGIC.len() + 1 + 4 {
                return Err(CkptError::Truncated { context: "checksum trailer" });
            }
            let split = bytes.len() - 4;
            let stored = u32::from_le_bytes([
                bytes[split],
                bytes[split + 1],
                bytes[split + 2],
                bytes[split + 3],
            ]);
            let computed = crc32(&bytes[..split]);
            if stored != computed {
                return Err(CkptError::Checksum { stored, computed });
            }
            &bytes[MAGIC.len() + 1..split]
        }
        v => return Err(CkptError::UnsupportedVersion(v)),
    };

    let mut cur = Cur { b: body };
    let count = cur.u32("tensor count")? as usize;
    if count > MAX_TENSORS {
        return Err(CkptError::Implausible { what: "tensor count", value: count as u64 });
    }
    let mut out = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        let name_len = cur.u32("name length")? as usize;
        if name_len > MAX_NAME_LEN {
            return Err(CkptError::Implausible { what: "name length", value: name_len as u64 });
        }
        let name_bytes = cur.take(name_len, "tensor name")?;
        let name = std::str::from_utf8(name_bytes).map_err(|_| CkptError::BadName)?.to_string();
        let dtype = cur.u8("dtype tag")?;
        if dtype > 1 {
            return Err(CkptError::BadDtype(dtype));
        }
        let ndims = cur.u32("rank")? as usize;
        if ndims > MAX_NDIMS {
            return Err(CkptError::Implausible { what: "rank", value: ndims as u64 });
        }
        let mut shape = Vec::with_capacity(ndims);
        let mut n: usize = 1;
        for _ in 0..ndims {
            let d = cur.u64("dimension")?;
            if d > MAX_ELEMS as u64 {
                return Err(CkptError::Implausible { what: "dimension", value: d });
            }
            let d = d as usize;
            n = n.saturating_mul(d);
            shape.push(d);
        }
        if n > MAX_ELEMS {
            return Err(CkptError::Implausible { what: "tensor elements", value: n as u64 });
        }
        // `take` bounds the payload against the real file size before the
        // data vector is allocated — no 4 GB allocation on a lying header.
        let payload = cur.take(4 * n, "tensor data")?;
        let t = match dtype {
            0 => {
                let data = payload
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                HostTensor::F32 { shape, data }
            }
            _ => {
                let data = payload
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                HostTensor::I32 { shape, data }
            }
        };
        out.push((name, t));
    }
    if !cur.b.is_empty() {
        return Err(CkptError::TrailingData { bytes: cur.b.len() });
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Discovery (checkpoint-directory layout for cadence saves + auto-resume)
// ---------------------------------------------------------------------------

/// Canonical cadence-save path: `dir/step_XXXXXXXX.ckpt`. Zero-padded so
/// lexicographic order == step order.
pub fn step_path(dir: impl AsRef<Path>, step: usize) -> PathBuf {
    dir.as_ref().join(format!("step_{step:08}.ckpt"))
}

/// All `*.ckpt` files in `dir`, sorted ascending (== step order for
/// cadence saves). `.tmp` leftovers from interrupted saves are excluded
/// by the extension filter.
pub fn list(dir: impl AsRef<Path>) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = match fs::read_dir(dir) {
        Ok(rd) => rd
            .flatten()
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|e| e == "ckpt"))
            .collect(),
        Err(_) => Vec::new(),
    };
    out.sort();
    out
}

/// Newest checkpoint in `dir` that loads cleanly. Corrupt or truncated
/// files are reported to stderr and skipped, so auto-resume falls back
/// to the previous valid checkpoint instead of dying on the newest one.
pub fn latest_valid(dir: impl AsRef<Path>) -> Option<(PathBuf, Vec<(String, HostTensor)>)> {
    for p in list(dir).into_iter().rev() {
        match load(&p) {
            Ok(tensors) => return Some((p, tensors)),
            Err(e) => eprintln!("checkpoint: skipping {}: {e}", p.display()),
        }
    }
    None
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("jorge_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let a = HostTensor::from_f32(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 1e-7, 9.9]);
        let b = HostTensor::from_i32(vec![4], vec![1, -2, 3, 4]);
        let s = HostTensor::scalar_f32(0.125);
        let path = tmp("rt.bin");
        save(&path, &[("w".into(), &a), ("tok".into(), &b), ("lr".into(), &s)]).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded[0].0, "w");
        assert_eq!(loaded[0].1, a);
        assert_eq!(loaded[1].1, b);
        assert_eq!(loaded[2].1, s);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_with_bad_magic() {
        let path = tmp("garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(matches!(load(&path), Err(CkptError::BadMagic)));
        std::fs::write(&path, b"JORG").unwrap();
        assert!(matches!(load(&path), Err(CkptError::Truncated { .. })));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated_with_typed_error() {
        let a = HostTensor::from_f32(vec![8, 8], vec![0.5; 64]);
        let path = tmp("trunc.bin");
        save(&path, &[("w".into(), &a)]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // truncating anywhere must yield a typed error, never garbage
        for cut in [bytes.len() / 2, 12, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            match load(&path) {
                Err(CkptError::Truncated { .. } | CkptError::Checksum { .. }) => {}
                other => panic!("cut={cut}: expected Truncated/Checksum, got {other:?}"),
            }
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_single_bit_flip_via_checksum() {
        let a = HostTensor::from_f32(vec![4, 4], (0..16).map(|i| i as f32).collect());
        let path = tmp("flip.bin");
        save(&path, &[("w".into(), &a)]).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // flip one bit in the payload region (past the header)
        let mut dirty = clean.clone();
        let i = dirty.len() - 10;
        dirty[i] ^= 0x10;
        std::fs::write(&path, &dirty).unwrap();
        assert!(matches!(load(&path), Err(CkptError::Checksum { .. })));
        // restore => loads again
        std::fs::write(&path, &clean).unwrap();
        assert!(load(&path).is_ok());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_unknown_dtype_and_implausible_headers() {
        let a = HostTensor::from_f32(vec![2], vec![1.0, 2.0]);
        let path = tmp("hdr.bin");
        save(&path, &[("w".into(), &a)]).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // dtype tag sits after count(4) + name_len(4) + name(1)
        let dtype_off = MAGIC.len() + 1 + 4 + 4 + 1;
        assert_eq!(clean[dtype_off], 0);
        let patch = |off: usize, val: &[u8]| {
            let mut b = clean.clone();
            b[off..off + val.len()].copy_from_slice(val);
            // re-seal the CRC so the header error (not the checksum) surfaces
            let split = b.len() - 4;
            let crc = crc32(&b[..split]).to_le_bytes();
            b[split..].copy_from_slice(&crc);
            b
        };
        std::fs::write(&path, patch(dtype_off, &[9])).unwrap();
        assert!(matches!(load(&path), Err(CkptError::BadDtype(9))));
        // name_len bound
        std::fs::write(&path, patch(MAGIC.len() + 1 + 4, &u32::MAX.to_le_bytes())).unwrap();
        assert!(matches!(load(&path), Err(CkptError::Implausible { .. })));
        // rank bound
        std::fs::write(&path, patch(dtype_off + 1, &1000u32.to_le_bytes())).unwrap();
        assert!(matches!(load(&path), Err(CkptError::Implausible { .. })));
        // huge dim: bounded before any allocation
        std::fs::write(&path, patch(dtype_off + 1 + 4, &u64::MAX.to_le_bytes())).unwrap();
        assert!(matches!(load(&path), Err(CkptError::Implausible { .. })));
        // unsupported version
        let mut b = clean.clone();
        b[MAGIC.len()] = 9;
        std::fs::write(&path, &b).unwrap();
        assert!(matches!(load(&path), Err(CkptError::UnsupportedVersion(9))));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn legacy_v1_files_still_load() {
        // hand-build a v1 file: "JORGECKPT\x01", no CRC trailer
        let mut b: Vec<u8> = Vec::new();
        b.extend_from_slice(b"JORGECKPT\x01");
        b.extend_from_slice(&1u32.to_le_bytes()); // count
        b.extend_from_slice(&1u32.to_le_bytes()); // name_len
        b.push(b'x');
        b.push(0); // dtype f32
        b.extend_from_slice(&1u32.to_le_bytes()); // ndims
        b.extend_from_slice(&2u64.to_le_bytes()); // dim
        b.extend_from_slice(&1.5f32.to_le_bytes());
        b.extend_from_slice(&(-2.0f32).to_le_bytes());
        let path = tmp("v1.bin");
        std::fs::write(&path, &b).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded, vec![("x".to_string(), HostTensor::from_f32(vec![2], vec![1.5, -2.0]))]);
        // v1 with trailing garbage is rejected, not silently accepted
        b.push(0xAB);
        std::fs::write(&path, &b).unwrap();
        assert!(matches!(load(&path), Err(CkptError::TrailingData { .. })));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn atomic_save_leaves_no_tmp_and_discovery_skips_corrupt() {
        let dir = tmp("dir_discovery");
        std::fs::create_dir_all(&dir).unwrap();
        let a = HostTensor::from_f32(vec![2], vec![1.0, 2.0]);
        let b = HostTensor::from_f32(vec![2], vec![3.0, 4.0]);
        save(step_path(&dir, 4), &[("w".into(), &a)]).unwrap();
        save(step_path(&dir, 8), &[("w".into(), &b)]).unwrap();
        // a stray .tmp from a "crashed" save must be invisible
        std::fs::write(dir.join("step_00000012.ckpt.tmp"), b"half-written").unwrap();
        assert!(!list(&dir).iter().any(|p| p.to_string_lossy().contains("tmp")));
        assert_eq!(list(&dir).len(), 2);
        let (newest, t) = latest_valid(&dir).unwrap();
        assert_eq!(newest, step_path(&dir, 8));
        assert_eq!(t[0].1, b);
        // corrupt the newest: discovery falls back to the previous valid one
        let mut bytes = std::fs::read(step_path(&dir, 8)).unwrap();
        let n = bytes.len();
        bytes[n - 6] ^= 0x01;
        std::fs::write(step_path(&dir, 8), &bytes).unwrap();
        let (fallback, t) = latest_valid(&dir).unwrap();
        assert_eq!(fallback, step_path(&dir, 4));
        assert_eq!(t[0].1, a);
        // everything corrupt => None
        std::fs::write(step_path(&dir, 4), b"junk").unwrap();
        std::fs::write(step_path(&dir, 8), b"junk").unwrap();
        assert!(latest_valid(&dir).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC-32 of "123456789" is 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn blob_encode_decode_is_bitwise_inverse() {
        // the resync path depends on decode(encode(x)) == x exactly,
        // including non-finite f32 payloads
        let a = HostTensor::from_f32(vec![2, 3], vec![1.0, -2.5, f32::NAN, 0.0, -0.0, 1e-37]);
        let b = HostTensor::from_i32(vec![3], vec![i32::MIN, 0, i32::MAX]);
        let tensors: Vec<(String, &HostTensor)> = vec![("w".into(), &a), ("steps".into(), &b)];
        let blob = encode_blob(&tensors);
        let back = decode_blob(&blob).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, "w");
        match (&back[0].1, &a) {
            (HostTensor::F32 { data: d, .. }, HostTensor::F32 { data: want, .. }) => {
                for (x, y) in d.iter().zip(want) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            other => panic!("dtype changed: {other:?}"),
        }
        assert_eq!(back[1].1, b);
        // re-encoding the decoded tensors reproduces the blob bytes
        let refs: Vec<(String, &HostTensor)> =
            back.iter().map(|(n, t)| (n.clone(), t)).collect();
        assert_eq!(encode_blob(&refs), blob);
        // and load() is read + decode of the same bytes (bitwise, via
        // re-encode: the payload holds a NaN, so == would be wrong)
        let path = tmp("blob_eq_file.bin");
        std::fs::write(&path, &blob).unwrap();
        let from_file = load(&path).unwrap();
        let file_refs: Vec<(String, &HostTensor)> =
            from_file.iter().map(|(n, t)| (n.clone(), t)).collect();
        assert_eq!(encode_blob(&file_refs), blob);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn latest_valid_corruption_matrix() {
        let dir = tmp("dir_matrix");
        std::fs::remove_dir_all(&dir).ok();

        // empty (nonexistent) dir => None, no panic
        assert!(latest_valid(&dir).is_none());
        std::fs::create_dir_all(&dir).unwrap();
        // existing but empty dir => None
        assert!(latest_valid(&dir).is_none());

        let tensor_at = |v: f32| HostTensor::from_f32(vec![4], vec![v; 4]);
        for step in [4usize, 8, 12] {
            save(step_path(&dir, step), &[("w".into(), &tensor_at(step as f32))]).unwrap();
        }
        let clean12 = std::fs::read(step_path(&dir, 12)).unwrap();

        // the corruption matrix: each entry mangles step 12 a different
        // way; every variant must be a typed error on direct load and
        // make discovery fall back to step 8
        let header_off = MAGIC.len() + 2; // inside the tensor-count field
        let body_off = clean12.len() / 2; // inside the f32 payload
        let trailer_off = clean12.len() - 2; // inside the CRC32 trailer
        let corruptions: Vec<(&str, Vec<u8>)> = vec![
            ("truncated", clean12[..clean12.len() / 3].to_vec()),
            ("header bit-flip", {
                let mut b = clean12.clone();
                b[header_off] ^= 0x04;
                b
            }),
            ("body bit-flip", {
                let mut b = clean12.clone();
                b[body_off] ^= 0x10;
                b
            }),
            ("trailer bit-flip", {
                let mut b = clean12.clone();
                b[trailer_off] ^= 0x01;
                b
            }),
        ];
        for (what, bytes) in &corruptions {
            std::fs::write(step_path(&dir, 12), bytes).unwrap();
            match load(step_path(&dir, 12)) {
                Err(
                    CkptError::Truncated { .. }
                    | CkptError::Checksum { .. }
                    | CkptError::Implausible { .. },
                ) => {}
                other => panic!("{what}: expected a typed CkptError, got {other:?}"),
            }
            let (p, t) = latest_valid(&dir)
                .unwrap_or_else(|| panic!("{what}: discovery must fall back"));
            assert_eq!(p, step_path(&dir, 8), "{what}");
            assert_eq!(t[0].1, tensor_at(8.0), "{what}");
        }

        // leftover .tmp from a crash mid-rename: newer step number but
        // invisible to discovery
        std::fs::write(
            dir.join("step_00000016.ckpt.tmp"),
            &clean12[..clean12.len() - 7],
        )
        .unwrap();
        let (p, _) = latest_valid(&dir).unwrap();
        assert_eq!(p, step_path(&dir, 8));

        // restore step 12: it becomes the pick again
        std::fs::write(step_path(&dir, 12), &clean12).unwrap();
        let (p, t) = latest_valid(&dir).unwrap();
        assert_eq!(p, step_path(&dir, 12));
        assert_eq!(t[0].1, tensor_at(12.0));

        // corrupt everything => None
        for step in [4usize, 8, 12] {
            std::fs::write(step_path(&dir, step), b"junk").unwrap();
        }
        assert!(latest_valid(&dir).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
