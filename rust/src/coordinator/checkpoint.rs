//! Binary checkpoint format for params + optimizer state.
//!
//! Layout: magic "JORGECKPT\x01", u32 tensor count, then per tensor:
//! u32 name_len, name bytes, u8 dtype (0=f32, 1=i32), u32 ndims,
//! u64 dims..., raw little-endian data. Round-trips exactly.

use crate::runtime::HostTensor;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 10] = b"JORGECKPT\x01";

pub fn save(
    path: impl AsRef<Path>,
    tensors: &[(String, &HostTensor)],
) -> std::io::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        match t {
            HostTensor::F32 { shape, data } => {
                w.write_all(&[0u8])?;
                w.write_all(&(shape.len() as u32).to_le_bytes())?;
                for &d in shape {
                    w.write_all(&(d as u64).to_le_bytes())?;
                }
                for v in data {
                    w.write_all(&v.to_le_bytes())?;
                }
            }
            HostTensor::I32 { shape, data } => {
                w.write_all(&[1u8])?;
                w.write_all(&(shape.len() as u32).to_le_bytes())?;
                for &d in shape {
                    w.write_all(&(d as u64).to_le_bytes())?;
                }
                for v in data {
                    w.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    w.flush()
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

pub fn load(path: impl AsRef<Path>) -> std::io::Result<Vec<(String, HostTensor)>> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 10];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("not a jorge checkpoint (bad magic)"));
    }
    let count = read_u32(&mut r)? as usize;
    if count > 1_000_000 {
        return Err(bad("implausible tensor count"));
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            return Err(bad("implausible name length"));
        }
        let mut name_bytes = vec![0u8; name_len];
        r.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes).map_err(|_| bad("bad tensor name"))?;
        let mut dtype = [0u8; 1];
        r.read_exact(&mut dtype)?;
        let ndims = read_u32(&mut r)? as usize;
        if ndims > 16 {
            return Err(bad("implausible rank"));
        }
        let mut shape = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            shape.push(read_u64(&mut r)? as usize);
        }
        let n: usize = shape.iter().product::<usize>().max(1);
        if n > 1 << 30 {
            return Err(bad("implausible tensor size"));
        }
        let t = match dtype[0] {
            0 => {
                let mut data = vec![0f32; n];
                let mut buf = vec![0u8; 4 * n];
                r.read_exact(&mut buf)?;
                for (i, c) in buf.chunks_exact(4).enumerate() {
                    data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                HostTensor::F32 { shape, data }
            }
            1 => {
                let mut data = vec![0i32; n];
                let mut buf = vec![0u8; 4 * n];
                r.read_exact(&mut buf)?;
                for (i, c) in buf.chunks_exact(4).enumerate() {
                    data[i] = i32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                HostTensor::I32 { shape, data }
            }
            other => return Err(bad(&format!("unknown dtype tag {other}"))),
        };
        out.push((name, t));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("jorge_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let a = HostTensor::from_f32(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 1e-7, 9.9]);
        let b = HostTensor::from_i32(vec![4], vec![1, -2, 3, 4]);
        let s = HostTensor::scalar_f32(0.125);
        let path = tmp("rt.bin");
        save(&path, &[("w".into(), &a), ("tok".into(), &b), ("lr".into(), &s)]).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded[0].0, "w");
        assert_eq!(loaded[0].1, a);
        assert_eq!(loaded[1].1, b);
        assert_eq!(loaded[2].1, s);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated() {
        let a = HostTensor::from_f32(vec![8, 8], vec![0.5; 64]);
        let path = tmp("trunc.bin");
        save(&path, &[("w".into(), &a)]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
