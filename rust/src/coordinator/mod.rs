//! L3 coordinator: training loop, data-parallel orchestration,
//! checkpointing. See `trainer.rs` for the two execution modes.
//!
//! This layer owns failure handling for the whole run, so panicking
//! escape hatches are linted out: every fallible path must surface a
//! typed error the CLI can report (tests may opt out locally).
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod checkpoint;
pub mod sched;
pub mod trainer;

pub use sched::{Stage, StageSpec, StepPlan};
pub use trainer::{
    assign_owners, EpochRecord, FaultReport, RunResult, ShardReport, Trainer,
};
