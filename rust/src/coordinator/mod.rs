//! L3 coordinator: training loop, data-parallel orchestration,
//! checkpointing. See `trainer.rs` for the two execution modes.

pub mod checkpoint;
pub mod trainer;

pub use trainer::{assign_owners, EpochRecord, RunResult, ShardReport, Trainer};
