//! L3 step schedule: every training step is a declarative [`StepPlan`]
//! of typed [`Stage`]s with explicit data dependencies, executed by one
//! plan executor the three trainer loops (fused, data-parallel,
//! sharded) drive as thin front-ends.
//!
//! The plan is the single place the step's structure lives:
//!
//! * **Stages** name the units of work
//!   (`Data → FwdBwd → GradReduce → PrecondRefresh → PrecondExchange →
//!   Apply`, plus the boundary stages `Resync`/`Checkpoint`/`Eval`).
//!   The backend fuses forward and backward into one executable call,
//!   so the plan models them as a single `FwdBwd` stage.
//! * **`after` edges** record which earlier stages a stage actually
//!   consumes. Execution on the single simulated node is sequential in
//!   list order; the edges are what the perf model reads to decide what
//!   a real cluster could overlap. The payoff is the deferred
//!   preconditioner exchange (`--precond-overlap`): in the overlapped
//!   plan `Apply` depends only on `GradReduce` — the all-gather of
//!   freshly refreshed preconditioners is off the apply's critical
//!   path, and its import lands at the *next* step boundary as a
//!   `PrecondImport` stage (async-Shampoo style one-refresh staleness).
//! * **Trace scopes** open in the executor, not at call sites: a stage
//!   whose spec is `scoped` gets its [`Phase`] timer for exactly the
//!   hook's duration. Stages whose callees attribute their own time
//!   (the fused executable's internal forward/backward/apply, the
//!   native optimizer's refresh/apply scopes) are marked unscoped so
//!   nothing is double-counted.
//!
//! Plans are validated on every execution: a stage may appear at most
//! once and every dependency must run earlier in the list, so a driver
//! cannot silently build a plan that consumes data before it exists.

use crate::trace::{self, Phase};
use anyhow::{anyhow, Result};

/// A typed unit of per-step work. Drivers match on the stage in their
/// hook; the executor owns ordering, validation, and trace scoping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Rejoin barrier: leader resync broadcast to readmitted ranks.
    Resync,
    /// Batch assembly: dataset slicing + host tensor packing.
    Data,
    /// Fused forward + backward (one backend call per simulated rank).
    FwdBwd,
    /// Ring all-reduce of the gradient buckets.
    GradReduce,
    /// Deferred-exchange landing: import the preconditioners gathered
    /// at the previous step (`--precond-overlap` only).
    PrecondImport,
    /// Owner-computes preconditioner refresh on the owned layers.
    PrecondRefresh,
    /// Export + ring all-gather of refreshed preconditioners; the
    /// import applies immediately (sync) or is deferred (overlap).
    PrecondExchange,
    /// Parameter update from the reduced gradients.
    Apply,
    /// Cadenced checkpoint save.
    Checkpoint,
    /// Held-out evaluation + eval-result broadcast.
    Eval,
}

impl Stage {
    /// Stable snake_case name for errors and plan introspection.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Resync => "resync",
            Stage::Data => "data",
            Stage::FwdBwd => "fwd_bwd",
            Stage::GradReduce => "grad_reduce",
            Stage::PrecondImport => "precond_import",
            Stage::PrecondRefresh => "precond_refresh",
            Stage::PrecondExchange => "precond_exchange",
            Stage::Apply => "apply",
            Stage::Checkpoint => "checkpoint",
            Stage::Eval => "eval",
        }
    }

    /// The trace phase the executor opens for a `scoped` stage. Stages
    /// timed inside their callees map to `None` at the executor level;
    /// the deferred import is charged to the all-gather phase, same as
    /// the synchronous import it replaces.
    pub fn scope_phase(self) -> Option<Phase> {
        match self {
            Stage::Data => Some(Phase::Data),
            Stage::GradReduce => Some(Phase::GradReduce),
            Stage::PrecondImport => Some(Phase::PrecondGather),
            Stage::PrecondExchange => Some(Phase::PrecondGather),
            Stage::Apply => Some(Phase::Apply),
            Stage::Checkpoint => Some(Phase::Checkpoint),
            Stage::Eval => Some(Phase::Eval),
            Stage::Resync => Some(Phase::Resync),
            Stage::FwdBwd | Stage::PrecondRefresh => None,
        }
    }
}

/// One stage instance in a plan: the stage, the earlier stages whose
/// outputs it consumes, and whether the executor opens its trace scope.
#[derive(Clone, Debug)]
pub struct StageSpec {
    pub stage: Stage,
    /// Data dependencies; each must appear earlier in the plan.
    pub after: Vec<Stage>,
    /// `true` → the executor opens [`Stage::scope_phase`] around the
    /// hook. `false` for stages whose callee scopes its own time.
    pub scoped: bool,
}

/// A declarative per-step schedule, executed in list order.
#[derive(Clone, Debug)]
pub struct StepPlan {
    pub stages: Vec<StageSpec>,
}

impl StepPlan {
    /// Single-worker fused step: the train executable runs forward,
    /// backward, and the optimizer in one backend call.
    pub fn fused() -> StepPlan {
        StepPlan {
            stages: vec![
                StageSpec { stage: Stage::Data, after: vec![], scoped: true },
                StageSpec { stage: Stage::FwdBwd, after: vec![Stage::Data], scoped: false },
            ],
        }
    }

    /// Data-parallel step with a serial optimizer: per-rank grads,
    /// ring all-reduce, leader apply.
    pub fn data_parallel() -> StepPlan {
        StepPlan {
            stages: vec![
                StageSpec { stage: Stage::Data, after: vec![], scoped: true },
                StageSpec { stage: Stage::FwdBwd, after: vec![Stage::Data], scoped: false },
                StageSpec { stage: Stage::GradReduce, after: vec![Stage::FwdBwd], scoped: true },
                StageSpec { stage: Stage::Apply, after: vec![Stage::GradReduce], scoped: true },
            ],
        }
    }

    /// Sharded (owner-computes) step. `update` adds the exchange on
    /// refresh steps; `overlap` defers its import past the apply, which
    /// then depends only on the gradient reduce; `pending_import` lands
    /// the previous overlapped exchange before this step's refresh.
    pub fn sharded(update: bool, overlap: bool, pending_import: bool) -> StepPlan {
        let mut stages = vec![
            StageSpec { stage: Stage::Data, after: vec![], scoped: true },
            StageSpec { stage: Stage::FwdBwd, after: vec![Stage::Data], scoped: false },
            StageSpec { stage: Stage::GradReduce, after: vec![Stage::FwdBwd], scoped: true },
        ];
        // the deferred import consumes last step's gather, nothing from
        // this step — but the refresh must not run before it lands
        let mut refresh_after = vec![Stage::GradReduce];
        if pending_import {
            stages.push(StageSpec { stage: Stage::PrecondImport, after: vec![], scoped: true });
            refresh_after.push(Stage::PrecondImport);
        }
        stages.push(StageSpec {
            stage: Stage::PrecondRefresh,
            after: refresh_after,
            scoped: false,
        });
        if update {
            stages.push(StageSpec {
                stage: Stage::PrecondExchange,
                after: vec![Stage::PrecondRefresh],
                scoped: true,
            });
        }
        let apply_after = if update && !overlap {
            vec![Stage::GradReduce, Stage::PrecondExchange]
        } else if update {
            // overlapped: the apply runs on the pre-refresh (stale)
            // preconditioners, so the exchange is off its critical path
            vec![Stage::GradReduce]
        } else {
            vec![Stage::GradReduce, Stage::PrecondRefresh]
        };
        stages.push(StageSpec { stage: Stage::Apply, after: apply_after, scoped: false });
        StepPlan { stages }
    }

    /// A single boundary stage (`Resync`, `Checkpoint`, or `Eval`) run
    /// through the same executor as the step stages.
    pub fn boundary(stage: Stage) -> StepPlan {
        StepPlan { stages: vec![StageSpec { stage, after: vec![], scoped: true }] }
    }

    /// Structural validation: no duplicate stages, no self-deps, every
    /// dependency satisfied by an earlier stage.
    pub fn validate(&self) -> Result<(), String> {
        for (i, spec) in self.stages.iter().enumerate() {
            if self.stages[..i].iter().any(|s| s.stage == spec.stage) {
                return Err(format!("stage {} appears twice", spec.stage.name()));
            }
            for dep in &spec.after {
                if *dep == spec.stage {
                    return Err(format!("stage {} depends on itself", spec.stage.name()));
                }
                if !self.stages[..i].iter().any(|s| s.stage == *dep) {
                    return Err(format!(
                        "stage {} depends on {}, which does not run before it",
                        spec.stage.name(),
                        dep.name()
                    ));
                }
            }
        }
        Ok(())
    }

    /// The stages on `Apply`'s transitive dependency chain — what a
    /// real cluster could *not* hide behind compute. Used by tests to
    /// pin that the overlapped plan takes the exchange off the path.
    pub fn apply_critical_path(&self) -> Vec<Stage> {
        let mut on_path = vec![Stage::Apply];
        // walk the list backwards, pulling in deps of anything on-path
        for spec in self.stages.iter().rev() {
            if on_path.contains(&spec.stage) {
                for dep in &spec.after {
                    if !on_path.contains(dep) {
                        on_path.push(*dep);
                    }
                }
            }
        }
        self.stages
            .iter()
            .map(|s| s.stage)
            .filter(|s| on_path.contains(s))
            .collect()
    }
}

/// Per-stage callback the drivers implement; any
/// `FnMut(Stage) -> Result<()>` works.
pub trait StageHooks {
    fn on_stage(&mut self, stage: Stage) -> Result<()>;
}

impl<F> StageHooks for F
where
    F: FnMut(Stage) -> Result<()>,
{
    fn on_stage(&mut self, stage: Stage) -> Result<()> {
        self(stage)
    }
}

/// Run a plan: validate it, then invoke the hook once per stage in
/// list order, opening the stage's trace scope where the spec asks for
/// it. Stops at the first failing stage.
pub fn execute<H: StageHooks + ?Sized>(plan: &StepPlan, hooks: &mut H) -> Result<()> {
    plan.validate().map_err(|e| anyhow!("step plan: {e}"))?;
    for spec in &plan.stages {
        let _scope = if spec.scoped { spec.stage.scope_phase().map(trace::scope) } else { None };
        hooks.on_stage(spec.stage)?;
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn names(plan: &StepPlan) -> Vec<&'static str> {
        plan.stages.iter().map(|s| s.stage.name()).collect()
    }

    #[test]
    fn builtin_plans_validate() {
        for plan in [
            StepPlan::fused(),
            StepPlan::data_parallel(),
            StepPlan::sharded(false, false, false),
            StepPlan::sharded(true, false, false),
            StepPlan::sharded(true, true, false),
            StepPlan::sharded(true, true, true),
            StepPlan::sharded(false, true, true),
            StepPlan::boundary(Stage::Resync),
            StepPlan::boundary(Stage::Checkpoint),
            StepPlan::boundary(Stage::Eval),
        ] {
            assert_eq!(plan.validate(), Ok(()), "plan {:?}", names(&plan));
        }
    }

    #[test]
    fn sharded_plan_shapes() {
        let sync = StepPlan::sharded(true, false, false);
        assert_eq!(
            names(&sync),
            vec!["data", "fwd_bwd", "grad_reduce", "precond_refresh", "precond_exchange", "apply"]
        );
        // skip steps have no exchange
        let skip = StepPlan::sharded(false, false, false);
        assert!(!skip.stages.iter().any(|s| s.stage == Stage::PrecondExchange));
        // a pending import lands before the refresh, and the refresh
        // declares the dependency
        let landing = StepPlan::sharded(true, true, true);
        let import_at = landing
            .stages
            .iter()
            .position(|s| s.stage == Stage::PrecondImport)
            .unwrap();
        let refresh_at = landing
            .stages
            .iter()
            .position(|s| s.stage == Stage::PrecondRefresh)
            .unwrap();
        assert!(import_at < refresh_at);
        assert!(landing.stages[refresh_at].after.contains(&Stage::PrecondImport));
    }

    #[test]
    fn overlap_takes_exchange_off_the_apply_critical_path() {
        let sync = StepPlan::sharded(true, false, false);
        assert!(sync.apply_critical_path().contains(&Stage::PrecondExchange));

        let overlapped = StepPlan::sharded(true, true, false);
        let path = overlapped.apply_critical_path();
        assert!(!path.contains(&Stage::PrecondExchange));
        assert!(!path.contains(&Stage::PrecondRefresh));
        assert!(path.contains(&Stage::GradReduce));
        // the exchange still *runs* — it is scheduled, just not awaited
        // by the apply
        assert!(overlapped.stages.iter().any(|s| s.stage == Stage::PrecondExchange));
    }

    #[test]
    fn validate_rejects_malformed_plans() {
        let dup = StepPlan {
            stages: vec![
                StageSpec { stage: Stage::Data, after: vec![], scoped: true },
                StageSpec { stage: Stage::Data, after: vec![], scoped: true },
            ],
        };
        assert!(dup.validate().unwrap_err().contains("twice"));

        let self_dep = StepPlan {
            stages: vec![StageSpec {
                stage: Stage::Apply,
                after: vec![Stage::Apply],
                scoped: false,
            }],
        };
        assert!(self_dep.validate().unwrap_err().contains("itself"));

        let forward_dep = StepPlan {
            stages: vec![
                StageSpec { stage: Stage::Apply, after: vec![Stage::GradReduce], scoped: false },
                StageSpec { stage: Stage::GradReduce, after: vec![], scoped: true },
            ],
        };
        assert!(forward_dep.validate().unwrap_err().contains("does not run before"));
    }

    #[test]
    fn executor_runs_stages_in_order_and_stops_on_error() {
        let plan = StepPlan::sharded(true, false, false);
        let mut seen: Vec<&'static str> = Vec::new();
        execute(&plan, &mut |stage: Stage| {
            seen.push(stage.name());
            Ok(())
        })
        .unwrap();
        assert_eq!(
            seen,
            vec!["data", "fwd_bwd", "grad_reduce", "precond_refresh", "precond_exchange", "apply"]
        );

        let mut ran = 0usize;
        let err = execute(&plan, &mut |stage: Stage| {
            ran += 1;
            if stage == Stage::GradReduce {
                Err(anyhow!("reduce lost"))
            } else {
                Ok(())
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("reduce lost"));
        assert_eq!(ran, 3, "stages after the failure must not run");
    }

    #[test]
    fn executor_rejects_invalid_plan_before_running_hooks() {
        let bad = StepPlan {
            stages: vec![StageSpec {
                stage: Stage::Apply,
                after: vec![Stage::Data],
                scoped: false,
            }],
        };
        let mut ran = false;
        let err = execute(&bad, &mut |_stage: Stage| {
            ran = true;
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("step plan"));
        assert!(!ran);
    }
}
