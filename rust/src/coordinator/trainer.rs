//! The training coordinator — L3's core loop.
//!
//! Two execution modes, both generic over the execution backend
//! (native Rust or PJRT) and with Python nowhere on the path:
//!
//! * **fused** (workers == 1): one backend call per step runs
//!   fwd + bwd + optimizer, with the coordinator choosing the
//!   `train_*` vs `train_*_skip` executable per step — this is how the
//!   paper's *preconditioner update interval* hyperparameter is realised.
//! * **data-parallel** (workers > 1): each simulated GPU runs the
//!   `grad_*` executable on its shard, gradients are averaged with a real
//!   ring all-reduce over shared memory, and the leader applies the
//!   optimizer via the `apply_*` executable (or the native mirror with
//!   `--native`).
//!
//! The **sharded** variants (`shampoo_sharded` / `jorge_sharded`) extend
//! the data-parallel mode with owner-computes preconditioner sharding
//! (dist-Shampoo, Anil et al. 2020): after the gradient all-reduce, each
//! worker refreshes only the preconditioners of the layers it owns
//! (assignment balanced by refresh FLOPs, see [`assign_owners`]), the
//! refreshed preconditioners are all-gathered through `collectives`, and
//! every worker applies the identical update. Refresh + all-gather +
//! apply runs the same per-layer float ops as the serial fused step, so
//! trajectories are bitwise identical at any worker count.

use crate::collectives::{ring_all_gather, ring_all_reduce_mean, CommCostModel};
use crate::config::{ShardPolicy, TrainConfig};
use crate::data::{for_model, Dataset, Sharder};
use crate::metricsio::{CsvWriter, Stopwatch, Summary};
use crate::optim::{self, Hyper, Optimizer, OptimizerKind, Schedule, StepCtx};
use crate::rngx::Rng;
use crate::runtime::{Dtype, ExecBackend, ExecStep, HostTensor, Manifest, Role};
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Per-epoch summary record.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub lr: f64,
    pub train_loss: f64,
    pub train_metric: f64,
    pub val_metric: f64,
    pub val_loss: f64,
    pub iter_time_s: f64,
    pub wall_s: f64,
}

/// Result of a full training run.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub model: String,
    pub optimizer: String,
    pub epochs: Vec<EpochRecord>,
    pub step_losses: Vec<f32>,
    pub epochs_to_target: Option<usize>,
    pub time_to_target_s: Option<f64>,
    pub total_time_s: f64,
    pub mean_iter_s: f64,
    pub final_val_metric: f64,
    pub best_val_metric: f64,
    /// Sharding telemetry; `None` for serial optimizers.
    pub shard: Option<ShardReport>,
}

/// What the sharded step path actually did, for benches and tests:
/// which layers each worker owned, how many refreshes it ran, and the
/// all-gather traffic charged to the comm cost model.
#[derive(Clone, Debug, Default)]
pub struct ShardReport {
    pub workers: usize,
    /// Layer indices owned by each worker (preconditioned layers only).
    pub owned_layers: Vec<Vec<usize>>,
    /// Per-worker count of preconditioner refreshes performed.
    pub refresh_events: Vec<usize>,
    /// Number of preconditioner all-gathers (one per update step).
    pub allgather_calls: usize,
    /// Total floats moved through preconditioner all-gathers.
    pub allgather_floats: usize,
    /// A100 cost-model time for that all-gather traffic.
    pub modeled_comm_s: f64,
}

/// Deterministic owner-computes assignment: `costs[l]` is the refresh
/// cost of layer `l` (0 = no preconditioner, stays unowned). `Flops`
/// runs greedy longest-processing-time: heaviest layer first onto the
/// least-loaded worker, ties broken by lower layer index then lower
/// worker id — deterministic for a fixed inventory, independent of step
/// order or thread scheduling.
pub fn assign_owners(costs: &[f64], workers: usize, policy: ShardPolicy) -> Vec<Option<usize>> {
    let workers = workers.max(1);
    let mut owner = vec![None; costs.len()];
    match policy {
        ShardPolicy::RoundRobin => {
            let mut next = 0usize;
            for (li, &c) in costs.iter().enumerate() {
                if c > 0.0 {
                    owner[li] = Some(next % workers);
                    next += 1;
                }
            }
        }
        ShardPolicy::Flops => {
            let mut order: Vec<usize> = (0..costs.len()).filter(|&i| costs[i] > 0.0).collect();
            order.sort_by(|&a, &b| {
                costs[b]
                    .partial_cmp(&costs[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            let mut load = vec![0.0f64; workers];
            for li in order {
                let w = (0..workers)
                    .min_by(|&a, &b| {
                        load[a]
                            .partial_cmp(&load[b])
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.cmp(&b))
                    })
                    .unwrap();
                owner[li] = Some(w);
                load[w] += costs[li];
            }
        }
    }
    owner
}

/// Live sharding bookkeeping (telemetry mirrors [`ShardReport`]).
struct ShardState {
    owned: Vec<Vec<usize>>,
    refresh_layer_events: Vec<usize>,
    allgather_calls: usize,
    allgather_floats: usize,
    modeled_comm_s: f64,
    comm: CommCostModel,
}

impl RunResult {
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["epoch", "lr", "train_loss", "train_metric", "val_loss", "val_metric", "iter_s", "wall_s"],
        )?;
        for e in &self.epochs {
            w.row(&[
                e.epoch as f64,
                e.lr,
                e.train_loss,
                e.train_metric,
                e.val_loss,
                e.val_metric,
                e.iter_time_s,
                e.wall_s,
            ])?;
        }
        w.flush()
    }
}

const EVAL_BATCHES: usize = 4;

/// 2-D collapse of host tensors for the native optimizer mirrors.
fn to_matrices(tensors: &[HostTensor]) -> Vec<Matrix> {
    tensors
        .iter()
        .map(|t| {
            let sh = t.shape();
            Matrix::from_vec(sh[0], sh.get(1).copied().unwrap_or(1), t.as_f32().unwrap().to_vec())
        })
        .collect()
}

pub struct Trainer {
    pub cfg: TrainConfig,
    engine: Arc<dyn ExecBackend>,
    dataset: Box<dyn Dataset>,
    schedule: Schedule,
    // executables
    train_full: Arc<dyn ExecStep>,
    train_skip: Option<Arc<dyn ExecStep>>,
    grad: Arc<dyn ExecStep>,
    apply_full: Arc<dyn ExecStep>,
    apply_skip: Option<Arc<dyn ExecStep>>,
    eval: Arc<dyn ExecStep>,
    // live state
    pub params: Vec<HostTensor>,
    pub opt_state: Vec<HostTensor>,
    native_opt: Option<Box<dyn Optimizer>>,
    /// Effective optimizer kind: `cfg.optimizer`, downgraded to its
    /// serial base when there is a single worker (nothing to shard).
    kind: OptimizerKind,
    shard: Option<ShardState>,
    n_params: usize,
    global_step: usize,
}

impl Trainer {
    pub fn new(cfg: TrainConfig, engine: Arc<dyn ExecBackend>) -> Result<Trainer> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        let mut kind = cfg.optimizer;
        if kind.sharded && cfg.workers == 1 {
            eprintln!(
                "[trainer] note: {kind} with workers = 1 has nothing to shard; \
                 running the serial {} path",
                kind.serial()
            );
            kind = kind.serial();
        }
        let has_skip = kind.has_skip();

        let train_full = engine.load(&Manifest::train_name(&cfg.model, kind, true))?;
        let train_skip = if has_skip {
            Some(engine.load(&Manifest::train_name(&cfg.model, kind, false))?)
        } else {
            None
        };
        let grad = engine.load(&format!("grad_{}", cfg.model))?;
        let apply_full = engine.load(&Manifest::apply_name(&cfg.model, kind, true))?;
        let apply_skip = if has_skip {
            Some(engine.load(&Manifest::apply_name(&cfg.model, kind, false))?)
        } else {
            None
        };
        let eval = engine.load(&format!("eval_{}", cfg.model))?;

        // initialise params + optimizer state from the manifest rules
        let mut rng = Rng::new(cfg.seed);
        let mut params = Vec::new();
        let mut opt_state = Vec::new();
        for spec in &train_full.spec().inputs {
            match spec.role {
                Role::Param => params.push(HostTensor::from_init(spec, &mut rng).map_err(|e| anyhow!(e))?),
                Role::State => {
                    opt_state.push(HostTensor::from_init(spec, &mut rng).map_err(|e| anyhow!(e))?)
                }
                _ => {}
            }
        }
        let n_params = params.len();

        // the sharded path splits refresh from apply, which the fused
        // apply artifacts cannot do — it always drives the native mirror
        let native_opt = if cfg.native || kind.sharded {
            let shapes: Vec<(usize, usize)> = train_full
                .spec()
                .inputs
                .iter()
                .filter(|s| s.role == Role::Param)
                .map(|s| (s.shape[0], s.shape.get(1).copied().unwrap_or(1)))
                .collect();
            Some(optim::build(kind, &shapes, Hyper::default()))
        } else {
            None
        };

        let shard = if kind.sharded {
            let native = native_opt.as_ref().unwrap();
            let costs: Vec<f64> =
                (0..native.n_layers()).map(|l| native.refresh_flops(l)).collect();
            let owner = assign_owners(&costs, cfg.workers, cfg.shard_policy);
            let mut owned = vec![Vec::new(); cfg.workers];
            for (li, o) in owner.iter().enumerate() {
                if let Some(w) = *o {
                    owned[w].push(li);
                }
            }
            Some(ShardState {
                owned,
                refresh_layer_events: vec![0; cfg.workers],
                allgather_calls: 0,
                allgather_floats: 0,
                modeled_comm_s: 0.0,
                comm: CommCostModel::nvlink_a100(),
            })
        } else {
            None
        };

        // dataset: train region + held-out eval region
        let meta = engine
            .manifest()
            .models
            .get(&cfg.model)
            .ok_or_else(|| anyhow!("model {} not in manifest", cfg.model))?;
        let total_len = cfg.dataset_size + EVAL_BATCHES * meta.eval_batch;
        let dataset = for_model(&cfg.model, total_len, cfg.seed ^ 0xDA7A5E7).map_err(|e| anyhow!(e))?;

        let total_steps = cfg.epochs * cfg.steps_per_epoch;
        let warmup = (cfg.warmup_epochs * cfg.steps_per_epoch as f64).round() as usize;
        let schedule = Schedule::new(cfg.schedule, cfg.lr, total_steps, warmup, &cfg.decay_at);

        Ok(Trainer {
            cfg,
            engine,
            dataset,
            schedule,
            train_full,
            train_skip,
            grad,
            apply_full,
            apply_skip,
            eval,
            params,
            opt_state,
            native_opt,
            kind,
            shard,
            n_params,
            global_step: 0,
        })
    }

    /// Sharding telemetry for this trainer (`None` for serial kinds).
    pub fn shard_report(&self) -> Option<ShardReport> {
        self.shard.as_ref().map(|s| ShardReport {
            workers: self.cfg.workers,
            owned_layers: s.owned.clone(),
            refresh_events: s.refresh_layer_events.clone(),
            allgather_calls: s.allgather_calls,
            allgather_floats: s.allgather_floats,
            modeled_comm_s: s.modeled_comm_s,
        })
    }

    fn batch_tensors(&self, step: &dyn ExecStep, indices: &[usize]) -> (HostTensor, HostTensor) {
        let b = self.dataset.batch(indices);
        let spec = step.spec();
        let x_spec = &spec.inputs[spec.input_index(Role::X).unwrap()];
        let y_spec = &spec.inputs[spec.input_index(Role::Y).unwrap()];
        let x = match x_spec.dtype {
            Dtype::F32 => HostTensor::from_f32(x_spec.shape.clone(), b.x_f32),
            Dtype::I32 => HostTensor::from_i32(x_spec.shape.clone(), b.x_i32),
        };
        let y = HostTensor::from_i32(y_spec.shape.clone(), b.y);
        (x, y)
    }

    fn precond_update_now(&self) -> bool {
        // step 0 refreshes, then every `precond_every` steps
        self.global_step % self.cfg.precond_every == 0
    }

    /// One fused train step (single-worker path). Returns (loss, metric).
    fn fused_step(&mut self, indices: &[usize], lr: f64) -> Result<(f64, f64)> {
        let update = self.precond_update_now();
        let step = if update || self.train_skip.is_none() {
            self.train_full.clone()
        } else {
            self.train_skip.as_ref().unwrap().clone()
        };
        let (x, y) = self.batch_tensors(step.as_ref(), indices);
        let mut inputs: Vec<HostTensor> =
            Vec::with_capacity(self.params.len() + self.opt_state.len() + 4);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.opt_state.iter().cloned());
        inputs.push(x);
        inputs.push(y);
        inputs.push(HostTensor::scalar_f32(lr as f32));
        inputs.push(HostTensor::scalar_f32(self.cfg.weight_decay as f32));

        let mut outputs = step.run(&inputs)?;
        let metric = outputs.pop().unwrap().scalar();
        let loss = outputs.pop().unwrap().scalar();
        let state = outputs.split_off(self.n_params);
        self.params = outputs;
        self.opt_state = state;
        Ok((loss, metric))
    }

    /// One data-parallel step: grads on every worker, ring all-reduce,
    /// leader applies the optimizer. Returns mean (loss, metric).
    fn data_parallel_step(&mut self, worker_indices: &[Vec<usize>], lr: f64) -> Result<(f64, f64)> {
        let workers = worker_indices.len();
        let grad_step = self.grad.clone();
        let params = &self.params;

        // fan out gradient computation
        let results: Vec<Result<(Vec<HostTensor>, f64, f64)>> = std::thread::scope(|s| {
            let handles: Vec<_> = worker_indices
                .iter()
                .map(|idx| {
                    let grad_step = grad_step.clone();
                    let (x, y) = self.batch_tensors(grad_step.as_ref(), idx);
                    s.spawn(move || -> Result<(Vec<HostTensor>, f64, f64)> {
                        let mut inputs: Vec<HostTensor> = params.to_vec();
                        inputs.push(x);
                        inputs.push(y);
                        let mut out = grad_step.run(&inputs)?;
                        let metric = out.pop().unwrap().scalar();
                        let loss = out.pop().unwrap().scalar();
                        Ok((out, loss, metric))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        let mut grads_per_worker: Vec<Vec<HostTensor>> = Vec::with_capacity(workers);
        let mut loss_sum = 0.0;
        let mut metric_sum = 0.0;
        for r in results {
            let (g, l, m) = r?;
            grads_per_worker.push(g);
            loss_sum += l;
            metric_sum += m;
        }

        // bucket-flatten each worker's grads and ring-all-reduce the mean
        let mut buffers: Vec<Vec<f32>> = grads_per_worker
            .iter()
            .map(|gs| {
                let mut flat = Vec::new();
                for g in gs {
                    flat.extend_from_slice(g.as_f32().unwrap());
                }
                flat
            })
            .collect();
        ring_all_reduce_mean(&mut buffers);

        // unflatten rank-0's reduced buffer back into grad tensors
        let mut reduced: Vec<HostTensor> = Vec::with_capacity(self.n_params);
        let mut off = 0usize;
        for g in &grads_per_worker[0] {
            let n = g.len();
            reduced.push(HostTensor::from_f32(
                g.shape().to_vec(),
                buffers[0][off..off + n].to_vec(),
            ));
            off += n;
        }

        if self.shard.is_some() {
            self.sharded_apply(reduced, lr)?;
        } else {
            self.apply_reduced(reduced, lr)?;
        }
        Ok((loss_sum / workers as f64, metric_sum / workers as f64))
    }

    /// Sharded optimizer application (owner-computes): every worker
    /// refreshes only the layers it owns, the refreshed preconditioners
    /// travel a real ring all-gather, then the update is applied with
    /// the gathered state. The per-layer float ops equal the serial
    /// fused step's exactly, so the trajectory is bitwise identical.
    fn sharded_apply(&mut self, grads: Vec<HostTensor>, lr: f64) -> Result<()> {
        let update = self.precond_update_now();
        let wd = self.cfg.weight_decay as f32;
        let native = self.native_opt.as_mut().expect("sharded mode forces the native mirror");
        let shard = self.shard.as_mut().expect("sharded_apply without shard state");

        let mut mats = to_matrices(&self.params);
        let gmats = to_matrices(&grads);

        // owner-computes refresh; Shampoo also advances its stat EMAs
        // here on skip steps, so this runs every step
        for w in 0..shard.owned.len() {
            native.refresh_layers(&shard.owned[w], &gmats, update);
            if update {
                shard.refresh_layer_events[w] += shard.owned[w].len();
            }
        }

        if update {
            // owner w contributes the preconditioners it refreshed
            let chunks: Vec<Vec<f32>> =
                shard.owned.iter().map(|ls| native.export_preconditioners(ls)).collect();
            let chunk_bytes: Vec<usize> = chunks.iter().map(|c| 4 * c.len()).collect();
            let gathered = ring_all_gather(&chunks);
            shard.allgather_calls += 1;
            shard.allgather_floats += gathered.last().map_or(0, |b| b.len());
            shard.modeled_comm_s += shard.comm.all_gather_ragged_time(&chunk_bytes);
            // continue from the last rank's assembled buffer, so the
            // state the run depends on has genuinely been around the ring
            if let Some(buf) = gathered.last() {
                let order: Vec<usize> = shard.owned.concat();
                let used = native.import_preconditioners(&order, buf);
                debug_assert_eq!(used, buf.len(), "all-gather payload mismatch");
            }
        }

        native.apply_update(
            &mut mats,
            &gmats,
            StepCtx { lr: lr as f32, weight_decay: wd, update_precond: false },
        );
        for (p, m) in self.params.iter_mut().zip(mats) {
            *p.as_f32_mut().unwrap() = m.data;
        }
        Ok(())
    }

    fn apply_reduced(&mut self, grads: Vec<HostTensor>, lr: f64) -> Result<()> {
        let update = self.precond_update_now();
        if let Some(native) = &mut self.native_opt {
            // native mirror path
            let mut mats = to_matrices(&self.params);
            let gmats = to_matrices(&grads);
            native.step(
                &mut mats,
                &gmats,
                StepCtx {
                    lr: lr as f32,
                    weight_decay: self.cfg.weight_decay as f32,
                    update_precond: update,
                },
            );
            for (p, m) in self.params.iter_mut().zip(mats) {
                *p.as_f32_mut().unwrap() = m.data;
            }
            return Ok(());
        }
        let step = if update || self.apply_skip.is_none() {
            self.apply_full.clone()
        } else {
            self.apply_skip.as_ref().unwrap().clone()
        };
        let mut inputs: Vec<HostTensor> =
            Vec::with_capacity(2 * self.n_params + self.opt_state.len() + 2);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(grads);
        inputs.extend(self.opt_state.iter().cloned());
        inputs.push(HostTensor::scalar_f32(lr as f32));
        inputs.push(HostTensor::scalar_f32(self.cfg.weight_decay as f32));
        let mut outputs = step.run(&inputs)?;
        let state = outputs.split_off(self.n_params);
        self.params = outputs;
        self.opt_state = state;
        Ok(())
    }

    /// Held-out evaluation: mean loss/metric over EVAL_BATCHES batches.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let meta = &self.engine.manifest().models[&self.cfg.model];
        let eb = meta.eval_batch;
        let mut loss = Summary::new();
        let mut metric = Summary::new();
        for k in 0..EVAL_BATCHES {
            let base = self.cfg.dataset_size + k * eb;
            let indices: Vec<usize> = (base..base + eb).collect();
            let (x, y) = self.batch_tensors(self.eval.as_ref(), &indices);
            let mut inputs: Vec<HostTensor> = self.params.to_vec();
            inputs.push(x);
            inputs.push(y);
            let out = self.eval.run(&inputs)?;
            loss.add(out[0].scalar());
            metric.add(out[1].scalar());
        }
        Ok((loss.mean(), metric.mean()))
    }

    /// Run the full training loop.
    pub fn run(&mut self) -> Result<RunResult> {
        // grad artifact batch == model batch; with workers > 1 every
        // worker consumes a full batch (weak scaling, like the paper's
        // DDP runs)
        let per_worker_batch = self.engine.manifest().models[&self.cfg.model].batch;

        let mut result = RunResult {
            model: self.cfg.model.clone(),
            optimizer: self.kind.to_string(),
            ..Default::default()
        };
        let sw = Stopwatch::new();
        let mut iter_times = Summary::new();
        let sharder = Sharder {
            dataset_len: self.cfg.dataset_size,
            workers: self.cfg.workers,
            seed: self.cfg.seed ^ 0x5A4D,
        };

        'epochs: for epoch in 0..self.cfg.epochs {
            let shards = sharder.epoch_shards(epoch);
            let steps_this_epoch = (shards[0].len() / per_worker_batch)
                .min(self.cfg.steps_per_epoch)
                .max(1);
            let mut ep_loss = Summary::new();
            let mut ep_metric = Summary::new();
            let mut lr_now = self.cfg.lr;

            for si in 0..steps_this_epoch {
                if self.global_step >= self.cfg.max_steps {
                    break 'epochs;
                }
                lr_now = self.schedule.lr_at(self.global_step);
                let t0 = std::time::Instant::now();
                let (loss, metric) = if self.cfg.workers == 1 {
                    let lo = si * per_worker_batch;
                    self.fused_step(&shards[0][lo..lo + per_worker_batch], lr_now)?
                } else {
                    let worker_indices: Vec<Vec<usize>> = shards
                        .iter()
                        .map(|sh| {
                            let lo = (si * per_worker_batch) % (sh.len() - per_worker_batch + 1);
                            sh[lo..lo + per_worker_batch].to_vec()
                        })
                        .collect();
                    self.data_parallel_step(&worker_indices, lr_now)?
                };
                iter_times.add(t0.elapsed().as_secs_f64());
                self.global_step += 1;
                ep_loss.add(loss);
                ep_metric.add(metric);
                result.step_losses.push(loss as f32);
            }

            let (val_loss, val_metric) = self.evaluate()?;
            let rec = EpochRecord {
                epoch,
                lr: lr_now,
                train_loss: ep_loss.mean(),
                train_metric: ep_metric.mean(),
                val_metric,
                val_loss,
                iter_time_s: iter_times.mean(),
                wall_s: sw.total(),
            };
            if epoch % self.cfg.eval_every_epochs == 0 || epoch + 1 == self.cfg.epochs {
                eprintln!(
                    "[{} {}] epoch {epoch:>3} lr {:.4} loss {:.4} val {:.4} ({:.1}s)",
                    self.cfg.model, self.kind, rec.lr, rec.train_loss, rec.val_metric, rec.wall_s
                );
            }
            result.best_val_metric = result.best_val_metric.max(val_metric);
            result.epochs.push(rec);
            if self.cfg.target_metric > 0.0
                && val_metric >= self.cfg.target_metric
                && result.epochs_to_target.is_none()
            {
                result.epochs_to_target = Some(epoch + 1);
                result.time_to_target_s = Some(sw.total());
                break;
            }
        }

        result.total_time_s = sw.total();
        result.mean_iter_s = iter_times.mean();
        result.final_val_metric = result.epochs.last().map(|e| e.val_metric).unwrap_or(0.0);
        result.shard = self.shard_report();
        Ok(result)
    }

    /// Save params + optimizer state.
    pub fn save_checkpoint(&self, path: &str) -> std::io::Result<()> {
        let spec = self.train_full.spec();
        let mut named: Vec<(String, &HostTensor)> = Vec::new();
        let mut pi = 0;
        let mut si = 0;
        for input in &spec.inputs {
            match input.role {
                Role::Param => {
                    named.push((format!("param/{}", input.name), &self.params[pi]));
                    pi += 1;
                }
                Role::State => {
                    named.push((format!("state/{}", input.name), &self.opt_state[si]));
                    si += 1;
                }
                _ => {}
            }
        }
        super::checkpoint::save(path, &named)
    }

    /// Restore params + optimizer state from a checkpoint.
    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        let tensors = super::checkpoint::load(path)?;
        let mut params = Vec::new();
        let mut state = Vec::new();
        for (name, t) in tensors {
            if name.starts_with("param/") {
                params.push(t);
            } else if name.starts_with("state/") {
                state.push(t);
            }
        }
        if params.len() != self.params.len() || state.len() != self.opt_state.len() {
            return Err(anyhow!(
                "checkpoint mismatch: {}p/{}s vs expected {}p/{}s",
                params.len(),
                state.len(),
                self.params.len(),
                self.opt_state.len()
            ));
        }
        for (a, b) in self.params.iter().zip(&params) {
            if a.shape() != b.shape() {
                return Err(anyhow!("checkpoint param shape mismatch"));
            }
        }
        self.params = params;
        self.opt_state = state;
        Ok(())
    }
}
