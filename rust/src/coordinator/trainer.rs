//! The training coordinator — L3's core loop.
//!
//! Two execution modes, both generic over the execution backend
//! (native Rust or PJRT) and with Python nowhere on the path:
//!
//! * **fused** (workers == 1): one backend call per step runs
//!   fwd + bwd + optimizer, with the coordinator choosing the
//!   `train_*` vs `train_*_skip` executable per step — this is how the
//!   paper's *preconditioner update interval* hyperparameter is realised.
//! * **data-parallel** (workers > 1): each simulated GPU runs the
//!   `grad_*` executable on its shard, gradients are averaged with a real
//!   ring all-reduce over shared memory, and the leader applies the
//!   optimizer via the `apply_*` executable (or the native mirror with
//!   `--native`).
//!
//! The **sharded** variants (`shampoo_sharded` / `jorge_sharded`) extend
//! the data-parallel mode with owner-computes preconditioner sharding
//! (dist-Shampoo, Anil et al. 2020): after the gradient all-reduce, each
//! worker refreshes only the preconditioners of the layers it owns
//! (assignment balanced by refresh FLOPs, see [`assign_owners`]), the
//! refreshed preconditioners are all-gathered through `collectives`, and
//! every worker applies the identical update. Refresh + all-gather +
//! apply runs the same per-layer float ops as the serial fused step, so
//! trajectories are bitwise identical at any worker count.
//!
//! ## Step schedule
//!
//! Every step runs through the typed schedule in [`sched`](super::sched):
//! the loop builds a declarative `StepPlan` per step and the executor
//! drives this trainer's stage hooks in dependency order, opening the
//! trace scopes the plan asks for. With `--precond-overlap` the sharded
//! plan defers the preconditioner exchange: owners still refresh at
//! step `t` and the all-gather still runs, but the gathered import is
//! parked in a double-buffered slot and lands at the `t + 1` step
//! boundary, so step `t`'s apply uses one-refresh-stale preconditioners
//! (async distributed Shampoo style) and the exchange drops off the
//! apply's critical path — the perf model then charges
//! `max(gather, fwd + bwd)` instead of their sum. The synchronous
//! (default) plans run the exact float-op sequence of the pre-schedule
//! trainer, so trajectories are bitwise unchanged.
//!
//! ## Fault tolerance
//!
//! With a [`FaultPlan`] configured (`cfg.faults` / `JORGE_FAULTS`), the
//! collectives run through a [`FaultSession`] and the coordinator
//! degrades gracefully instead of crashing:
//!
//! * a rank lost during the **gradient all-reduce** is shed, the
//!   surviving buffers re-form the ring, and the step's loss averages
//!   over the survivors;
//! * an owner lost during the **preconditioner all-gather** has its
//!   layers reverted to the *stale* pre-refresh preconditioners for that
//!   step (a sound degradation mode — Anil et al. 2021), the
//!   FLOPs-balanced owner assignment is re-run over the survivors, and
//!   the gather retries without the dead rank;
//! * a previously-dropped rank with a `rejoin@step:rank` event is
//!   readmitted at the **step boundary** (never mid-collective): the
//!   leader tree-broadcasts the full training state — params, optimizer
//!   mirror state, preconditioners — as the exact checkpoint blob
//!   `--resume` would read, so resync and resume share one codepath,
//!   and the FLOPs-balanced owner assignment is re-run over the
//!   restored membership;
//! * every recovery lands in the [`ShardReport`] / [`FaultReport`]
//!   telemetry on [`RunResult`].
//!
//! When no plan is configured the fault paths are never entered and the
//! float ops are identical to the fault-free build. Cadence
//! checkpointing (`cfg.checkpoint_every`) and `cfg.resume` make the loop
//! crash-safe: a resumed run skips completed steps deterministically
//! (the sharder is pure per epoch) and continues bitwise-identically.

use crate::collectives::{
    ring_all_gather, ring_all_reduce_mean, CollectiveError, CommCostModel, FaultPlan, FaultSession,
};
use crate::config::{ShardPolicy, TrainConfig};
use crate::coordinator::sched::{self, Stage, StepPlan};
use crate::data::{for_model, Dataset, Sharder};
use crate::jsonio::Json;
use crate::metricsio::{CsvWriter, JsonlWriter, Stopwatch, Summary};
use crate::optim::{self, GuardReport, Hyper, Optimizer, OptimizerKind, Schedule, StepCtx};
use crate::rngx::Rng;
use crate::runtime::{Dtype, ExecBackend, ExecStep, HostTensor, Manifest, Role};
use crate::tensor::{dispatch_counters, Matrix};
use crate::trace::{self, MetricsReport};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Per-epoch summary record.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub lr: f64,
    pub train_loss: f64,
    pub train_metric: f64,
    pub val_metric: f64,
    pub val_loss: f64,
    pub iter_time_s: f64,
    pub wall_s: f64,
}

/// Result of a full training run.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub model: String,
    pub optimizer: String,
    pub epochs: Vec<EpochRecord>,
    pub step_losses: Vec<f32>,
    pub epochs_to_target: Option<usize>,
    pub time_to_target_s: Option<f64>,
    pub total_time_s: f64,
    /// Mean step time over the *warm* iterations: the first measured
    /// step (pool spawn, cache-cold GEMMs) is excluded whenever more
    /// than one step ran, so bench tables aren't skewed by warmup.
    pub mean_iter_s: f64,
    /// Warm step-time percentiles (same exclusion as `mean_iter_s`).
    pub iter_p50_s: f64,
    pub iter_p95_s: f64,
    pub final_val_metric: f64,
    pub best_val_metric: f64,
    /// Sharding telemetry; `None` for serial optimizers.
    pub shard: Option<ShardReport>,
    /// Numerical-guardrail counters from the native optimizer mirror
    /// (all zeros on a healthy run, and on the artifact-apply path).
    pub guard: GuardReport,
    /// Fault-injection telemetry; `None` when no fault plan was active.
    pub faults: Option<FaultReport>,
    /// Phase timings + unified counters; `None` unless tracing was on
    /// (`--trace` / `--metrics-out`, or `trace::set_enabled` in tests).
    pub metrics: Option<MetricsReport>,
}

/// What the sharded step path actually did, for benches and tests:
/// which layers each worker owned, how many refreshes it ran, and the
/// all-gather traffic charged to the comm cost model.
#[derive(Clone, Debug, Default)]
pub struct ShardReport {
    pub workers: usize,
    /// Layer indices owned by each worker (preconditioned layers only).
    pub owned_layers: Vec<Vec<usize>>,
    /// Per-worker count of preconditioner refreshes performed.
    pub refresh_events: Vec<usize>,
    /// Number of preconditioner all-gathers (one per update step).
    pub allgather_calls: usize,
    /// Total floats moved through preconditioner all-gathers.
    pub allgather_floats: usize,
    /// A100 cost-model time for that all-gather traffic.
    pub modeled_comm_s: f64,
    /// Exchanges whose gathered import was deferred to the next step
    /// boundary (`--precond-overlap`).
    pub overlap_exchanges: usize,
    /// Update steps applied with one-refresh-stale preconditioners
    /// because their exchange was deferred.
    pub stale_applies: usize,
    /// Layer-steps that fell back to stale preconditioners because
    /// their owner was lost mid-gather.
    pub stale_fallback_layers: usize,
    /// Times the owner assignment was re-balanced over the survivors
    /// after membership shrank.
    pub reassignments: usize,
    /// Ranks readmitted through the step-boundary rejoin barrier.
    pub rejoin_events: usize,
    /// Bytes of checkpoint-encoded state broadcast to rejoining ranks.
    pub resync_bytes: usize,
}

/// What the fault session did over the whole run.
#[derive(Clone, Debug, Default)]
pub struct FaultReport {
    /// Human-readable record of every injected fault and its recovery.
    pub events: Vec<String>,
    /// Straggler retries absorbed by the backoff policy.
    pub retries: usize,
    /// Modeled (never slept) backoff charged for those retries.
    pub modeled_backoff_s: f64,
    /// Ranks that left the job (drop or timeout), in rank order.
    pub dropped: Vec<usize>,
    /// Ranks still alive at the end of the run.
    pub survivors: usize,
    /// Ranks readmitted by `rejoin` events.
    pub rejoins: usize,
    /// Bytes of state broadcast to rejoining ranks.
    pub resync_bytes: usize,
    /// Membership epoch at end of run (bumped on every leave/rejoin).
    pub membership_epochs: usize,
}

/// Deterministic owner-computes assignment: `costs[l]` is the refresh
/// cost of layer `l` (0 = no preconditioner, stays unowned). `Flops`
/// runs greedy longest-processing-time: heaviest layer first onto the
/// least-loaded worker, ties broken by lower layer index then lower
/// worker id — deterministic for a fixed inventory, independent of step
/// order or thread scheduling.
pub fn assign_owners(costs: &[f64], workers: usize, policy: ShardPolicy) -> Vec<Option<usize>> {
    let workers = workers.max(1);
    let mut owner = vec![None; costs.len()];
    match policy {
        ShardPolicy::RoundRobin => {
            let mut next = 0usize;
            for (li, &c) in costs.iter().enumerate() {
                if c > 0.0 {
                    owner[li] = Some(next % workers);
                    next += 1;
                }
            }
        }
        ShardPolicy::Flops => {
            let mut order: Vec<usize> = (0..costs.len()).filter(|&i| costs[i] > 0.0).collect();
            order.sort_by(|&a, &b| {
                costs[b]
                    .partial_cmp(&costs[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            let mut load = vec![0.0f64; workers];
            for li in order {
                let w = (0..workers)
                    .min_by(|&a, &b| {
                        load[a]
                            .partial_cmp(&load[b])
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.cmp(&b))
                    })
                    .unwrap_or(0);
                owner[li] = Some(w);
                load[w] += costs[li];
            }
        }
    }
    owner
}

/// Deferred-exchange double buffer (`--precond-overlap`): the gathered
/// preconditioners plus the layer order they were exported in, parked
/// until the next step boundary. Import goes by explicit layer index,
/// so a membership change between the gather and the landing cannot
/// misroute it.
struct PendingImport {
    order: Vec<usize>,
    buf: Vec<f32>,
}

/// Live sharding bookkeeping (telemetry mirrors [`ShardReport`]).
struct ShardState {
    owned: Vec<Vec<usize>>,
    refresh_layer_events: Vec<usize>,
    allgather_calls: usize,
    allgather_floats: usize,
    modeled_comm_s: f64,
    stale_fallback_layers: usize,
    reassignments: usize,
    comm: CommCostModel,
    /// `--precond-overlap`: defer each exchange's import past the apply.
    overlap: bool,
    /// The one in-flight deferred import (double buffer, depth 1).
    pending: Option<PendingImport>,
    overlap_exchanges: usize,
    stale_applies: usize,
}

/// Re-run the FLOPs-balanced assignment over the surviving ranks. The
/// owner map stays keyed by *original* rank id (dead ranks own
/// nothing), so telemetry vectors and gather ordering remain stable.
fn reassign_owners(
    shard: &mut ShardState,
    native: &dyn Optimizer,
    live: &[usize],
    policy: ShardPolicy,
) -> Result<()> {
    if live.is_empty() {
        return Err(anyhow!("no live workers left to own preconditioners"));
    }
    let costs: Vec<f64> = (0..native.n_layers()).map(|l| native.refresh_flops(l)).collect();
    let owner = assign_owners(&costs, live.len(), policy);
    let mut owned = vec![Vec::new(); shard.owned.len()];
    for (li, o) in owner.iter().enumerate() {
        if let Some(w) = *o {
            owned[live[w]].push(li);
        }
    }
    shard.owned = owned;
    shard.reassignments += 1;
    Ok(())
}

/// Per-step scratch the data-parallel driver threads through the
/// sharded stage hooks ([`Trainer::shard_refresh`] fills it,
/// [`Trainer::shard_exchange`] / [`Trainer::shard_apply`] consume it).
struct ShardStepCx {
    update: bool,
    lr: f64,
    mats: Vec<Matrix>,
    gmats: Vec<Matrix>,
    /// Pre-refresh preconditioner snapshot, keyed by original rank id.
    stale: Option<Vec<Vec<f32>>>,
    /// Owner map as of refresh time: the overlap revert targets exactly
    /// the layers this map says were refreshed, independent of any
    /// mid-gather reassignment.
    refresh_owned: Vec<Vec<usize>>,
}

impl ShardStepCx {
    fn new(update: bool, lr: f64) -> ShardStepCx {
        ShardStepCx {
            update,
            lr,
            mats: Vec::new(),
            gmats: Vec::new(),
            stale: None,
            refresh_owned: Vec::new(),
        }
    }
}

impl RunResult {
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["epoch", "lr", "train_loss", "train_metric", "val_loss", "val_metric", "iter_s", "wall_s"],
        )?;
        for e in &self.epochs {
            w.row(&[
                e.epoch as f64,
                e.lr,
                e.train_loss,
                e.train_metric,
                e.val_loss,
                e.val_metric,
                e.iter_time_s,
                e.wall_s,
            ])?;
        }
        w.flush()
    }
}

const EVAL_BATCHES: usize = 4;

/// 2-D collapse of host tensors for the native optimizer mirrors.
fn to_matrices(tensors: &[HostTensor]) -> Result<Vec<Matrix>> {
    tensors
        .iter()
        .map(|t| {
            let sh = t.shape();
            let data = t
                .as_f32()
                .ok_or_else(|| anyhow!("non-f32 tensor in param/grad list"))?;
            Ok(Matrix::from_vec(
                sh.first().copied().unwrap_or(1),
                sh.get(1).copied().unwrap_or(1),
                data.to_vec(),
            ))
        })
        .collect()
}

pub struct Trainer {
    pub cfg: TrainConfig,
    engine: Arc<dyn ExecBackend>,
    dataset: Box<dyn Dataset>,
    schedule: Schedule,
    // executables
    train_full: Arc<dyn ExecStep>,
    train_skip: Option<Arc<dyn ExecStep>>,
    grad: Arc<dyn ExecStep>,
    apply_full: Arc<dyn ExecStep>,
    apply_skip: Option<Arc<dyn ExecStep>>,
    eval: Arc<dyn ExecStep>,
    // live state
    pub params: Vec<HostTensor>,
    pub opt_state: Vec<HostTensor>,
    native_opt: Option<Box<dyn Optimizer>>,
    /// Effective optimizer kind: `cfg.optimizer`, downgraded to its
    /// serial base when there is a single worker (nothing to shard).
    kind: OptimizerKind,
    shard: Option<ShardState>,
    /// Fault injector; `None` unless a plan is configured and workers > 1.
    fault: Option<FaultSession>,
    n_params: usize,
    global_step: usize,
}

impl Trainer {
    pub fn new(cfg: TrainConfig, engine: Arc<dyn ExecBackend>) -> Result<Trainer> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        let mut kind = cfg.optimizer;
        if kind.sharded && cfg.workers == 1 {
            eprintln!(
                "[trainer] note: {kind} with workers = 1 has nothing to shard; \
                 running the serial {} path",
                kind.serial()
            );
            if cfg.precond_overlap {
                eprintln!(
                    "[trainer] note: --precond-overlap has no preconditioner \
                     exchange to defer with workers = 1; running synchronously"
                );
            }
            kind = kind.serial();
        }
        let has_skip = kind.has_skip();

        let train_full = engine.load(&Manifest::train_name(&cfg.model, kind, true))?;
        let train_skip = if has_skip {
            Some(engine.load(&Manifest::train_name(&cfg.model, kind, false))?)
        } else {
            None
        };
        let grad = engine.load(&format!("grad_{}", cfg.model))?;
        let apply_full = engine.load(&Manifest::apply_name(&cfg.model, kind, true))?;
        let apply_skip = if has_skip {
            Some(engine.load(&Manifest::apply_name(&cfg.model, kind, false))?)
        } else {
            None
        };
        let eval = engine.load(&format!("eval_{}", cfg.model))?;

        // initialise params + optimizer state from the manifest rules
        let mut rng = Rng::new(cfg.seed);
        let mut params = Vec::new();
        let mut opt_state = Vec::new();
        for spec in &train_full.spec().inputs {
            match spec.role {
                Role::Param => params.push(HostTensor::from_init(spec, &mut rng).map_err(|e| anyhow!(e))?),
                Role::State => {
                    opt_state.push(HostTensor::from_init(spec, &mut rng).map_err(|e| anyhow!(e))?)
                }
                _ => {}
            }
        }
        let n_params = params.len();

        // the sharded path splits refresh from apply, which the fused
        // apply artifacts cannot do — it always drives the native mirror
        let native_opt = if cfg.native || kind.sharded {
            let shapes: Vec<(usize, usize)> = train_full
                .spec()
                .inputs
                .iter()
                .filter(|s| s.role == Role::Param)
                .map(|s| (s.shape[0], s.shape.get(1).copied().unwrap_or(1)))
                .collect();
            Some(optim::build(kind, &shapes, Hyper::default()))
        } else {
            None
        };

        let shard = match (&native_opt, kind.sharded) {
            (Some(native), true) => {
                let costs: Vec<f64> =
                    (0..native.n_layers()).map(|l| native.refresh_flops(l)).collect();
                let owner = assign_owners(&costs, cfg.workers, cfg.shard_policy);
                let mut owned = vec![Vec::new(); cfg.workers];
                for (li, o) in owner.iter().enumerate() {
                    if let Some(w) = *o {
                        owned[w].push(li);
                    }
                }
                Some(ShardState {
                    owned,
                    refresh_layer_events: vec![0; cfg.workers],
                    allgather_calls: 0,
                    allgather_floats: 0,
                    modeled_comm_s: 0.0,
                    stale_fallback_layers: 0,
                    reassignments: 0,
                    comm: CommCostModel::nvlink_a100(),
                    overlap: cfg.precond_overlap,
                    pending: None,
                    overlap_exchanges: 0,
                    stale_applies: 0,
                })
            }
            _ => None,
        };

        // fault injection: explicit config wins, else the environment
        // (JORGE_FAULTS); only armed where collectives actually run
        let fault = if cfg.workers > 1 {
            let plan = if cfg.faults.is_empty() {
                FaultPlan::from_env().map_err(|e| anyhow!(e))?
            } else {
                Some(FaultPlan::parse(&cfg.faults, cfg.fault_seed).map_err(|e| anyhow!(e))?)
            };
            if let Some(p) = &plan {
                // rank ranges + rejoin-of-a-live-rank are plan bugs;
                // catch them before any step runs
                p.validate(cfg.workers).map_err(|e| anyhow!("faults: {e}"))?;
            }
            plan.filter(|p| !p.is_empty())
                .map(|p| FaultSession::new(p, cfg.workers))
        } else {
            None
        };

        // dataset: train region + held-out eval region
        let meta = engine
            .manifest()
            .models
            .get(&cfg.model)
            .ok_or_else(|| anyhow!("model {} not in manifest", cfg.model))?;
        let total_len = cfg.dataset_size + EVAL_BATCHES * meta.eval_batch;
        let dataset = for_model(&cfg.model, total_len, cfg.seed ^ 0xDA7A5E7).map_err(|e| anyhow!(e))?;

        let total_steps = cfg.epochs * cfg.steps_per_epoch;
        let warmup = (cfg.warmup_epochs * cfg.steps_per_epoch as f64).round() as usize;
        let schedule = Schedule::new(cfg.schedule, cfg.lr, total_steps, warmup, &cfg.decay_at);

        Ok(Trainer {
            cfg,
            engine,
            dataset,
            schedule,
            train_full,
            train_skip,
            grad,
            apply_full,
            apply_skip,
            eval,
            params,
            opt_state,
            native_opt,
            kind,
            shard,
            fault,
            n_params,
            global_step: 0,
        })
    }

    /// Sharding telemetry for this trainer (`None` for serial kinds).
    pub fn shard_report(&self) -> Option<ShardReport> {
        self.shard.as_ref().map(|s| ShardReport {
            workers: self.cfg.workers,
            owned_layers: s.owned.clone(),
            refresh_events: s.refresh_layer_events.clone(),
            allgather_calls: s.allgather_calls,
            allgather_floats: s.allgather_floats,
            modeled_comm_s: s.modeled_comm_s,
            overlap_exchanges: s.overlap_exchanges,
            stale_applies: s.stale_applies,
            stale_fallback_layers: s.stale_fallback_layers,
            reassignments: s.reassignments,
            rejoin_events: self.fault.as_ref().map_or(0, |f| f.rejoins()),
            resync_bytes: self.fault.as_ref().map_or(0, |f| f.resync_bytes()),
        })
    }

    /// Fault-injection telemetry (`None` when no plan was active).
    pub fn fault_report(&self) -> Option<FaultReport> {
        let f = self.fault.as_ref()?;
        let live = f.live_ranks();
        Some(FaultReport {
            events: f
                .records()
                .iter()
                .map(|r| {
                    if matches!(r.kind, crate::collectives::FaultKind::Rejoin) {
                        // rejoins fire at the step boundary, not inside a
                        // collective — no op token in the event line
                        format!("step {} rank {} {}: {}", r.step, r.rank, r.kind, r.action)
                    } else {
                        format!("step {} rank {} {} {}: {}", r.step, r.rank, r.op, r.kind, r.action)
                    }
                })
                .collect(),
            retries: f.retries(),
            modeled_backoff_s: f.modeled_backoff_s(),
            dropped: (0..self.cfg.workers).filter(|&r| !live.contains(&r)).collect(),
            survivors: live.len(),
            rejoins: f.rejoins(),
            resync_bytes: f.resync_bytes(),
            membership_epochs: f.membership_epoch(),
        })
    }

    /// Numerical-guardrail counters from the native mirror (all zeros
    /// when running through the fused/apply artifacts).
    pub fn guard_report(&self) -> GuardReport {
        self.native_opt.as_ref().map(|o| o.guard_report()).unwrap_or_default()
    }

    /// Directory cadence checkpoints are written to and `--resume auto`
    /// searches: `cfg.checkpoint_dir`, or a run-keyed default under
    /// `out_dir` so different runs never clobber each other.
    pub fn checkpoint_dir(&self) -> String {
        if self.cfg.checkpoint_dir.is_empty() {
            format!(
                "{}/ckpt_{}_{}_s{}",
                self.cfg.out_dir, self.cfg.model, self.kind, self.cfg.seed
            )
        } else {
            self.cfg.checkpoint_dir.clone()
        }
    }

    fn batch_tensors(
        &self,
        step: &dyn ExecStep,
        indices: &[usize],
    ) -> Result<(HostTensor, HostTensor)> {
        let b = self.dataset.batch(indices);
        let spec = step.spec();
        let xi = spec
            .input_index(Role::X)
            .ok_or_else(|| anyhow!("executable has no X input"))?;
        let yi = spec
            .input_index(Role::Y)
            .ok_or_else(|| anyhow!("executable has no Y input"))?;
        let x_spec = &spec.inputs[xi];
        let y_spec = &spec.inputs[yi];
        let x = match x_spec.dtype {
            Dtype::F32 => HostTensor::from_f32(x_spec.shape.clone(), b.x_f32),
            Dtype::I32 => HostTensor::from_i32(x_spec.shape.clone(), b.x_i32),
        };
        let y = HostTensor::from_i32(y_spec.shape.clone(), b.y);
        Ok((x, y))
    }

    fn precond_update_now(&self) -> bool {
        // step 0 refreshes, then every `precond_every` steps
        self.global_step % self.cfg.precond_every == 0
    }

    /// One fused train step (single-worker path), driven through
    /// [`StepPlan::fused`]. Returns (loss, metric).
    fn fused_step(&mut self, indices: &[usize], lr: f64) -> Result<(f64, f64)> {
        let update = self.precond_update_now();
        let step = match (&self.train_skip, update) {
            (Some(skip), false) => skip.clone(),
            _ => self.train_full.clone(),
        };
        let plan = StepPlan::fused();
        let mut inputs: Vec<HostTensor> = Vec::new();
        let mut loss_metric = (0.0f64, 0.0f64);
        sched::execute(&plan, &mut |stage: Stage| -> Result<()> {
            match stage {
                Stage::Data => {
                    let (x, y) = self.batch_tensors(step.as_ref(), indices)?;
                    inputs = Vec::with_capacity(self.params.len() + self.opt_state.len() + 4);
                    inputs.extend(self.params.iter().cloned());
                    inputs.extend(self.opt_state.iter().cloned());
                    inputs.push(x);
                    inputs.push(y);
                    inputs.push(HostTensor::scalar_f32(lr as f32));
                    inputs.push(HostTensor::scalar_f32(self.cfg.weight_decay as f32));
                    Ok(())
                }
                Stage::FwdBwd => {
                    // forward, backward, and apply run fused inside the
                    // executable, which attributes its own phase time
                    let ins = std::mem::take(&mut inputs);
                    let mut outputs = step.run(&ins)?;
                    let metric = outputs
                        .pop()
                        .ok_or_else(|| anyhow!("train step returned no metric output"))?
                        .scalar();
                    let loss = outputs
                        .pop()
                        .ok_or_else(|| anyhow!("train step returned no loss output"))?
                        .scalar();
                    if outputs.len() < self.n_params {
                        return Err(anyhow!("train step output arity mismatch"));
                    }
                    let state = outputs.split_off(self.n_params);
                    self.params = outputs;
                    self.opt_state = state;
                    loss_metric = (loss, metric);
                    Ok(())
                }
                other => Err(anyhow!("unexpected stage {} in fused plan", other.name())),
            }
        })?;
        Ok(loss_metric)
    }

    /// One data-parallel step: grads on every live worker, ring
    /// all-reduce, leader applies the optimizer — driven through
    /// [`StepPlan::data_parallel`] or [`StepPlan::sharded`]. A rank
    /// lost during the reduce is shed and the survivors retry; the
    /// step's loss averages over the ranks whose gradients made it into
    /// the reduce.
    fn data_parallel_step(&mut self, worker_indices: &[Vec<usize>], lr: f64) -> Result<(f64, f64)> {
        let live: Vec<usize> = match &self.fault {
            Some(f) => f.live_ranks(),
            None => (0..worker_indices.len()).collect(),
        };
        if live.is_empty() {
            return Err(anyhow!("no live workers remain"));
        }
        let update = self.precond_update_now();
        let plan = match &self.shard {
            Some(sh) => StepPlan::sharded(update, sh.overlap, sh.pending.is_some()),
            None => StepPlan::data_parallel(),
        };
        let grad_step = self.grad.clone();

        // per-step scratch threaded between the stage hooks
        let mut batches: Vec<(HostTensor, HostTensor)> = Vec::new();
        let mut grads_per_worker: Vec<Vec<HostTensor>> = Vec::new();
        let mut losses: Vec<f64> = Vec::new();
        let mut metrics: Vec<f64> = Vec::new();
        let mut reduced: Option<Vec<HostTensor>> = None;
        let mut cx = ShardStepCx::new(update, lr);

        sched::execute(&plan, &mut |stage: Stage| -> Result<()> {
            match stage {
                Stage::Data => {
                    batches.reserve(live.len());
                    for &r in &live {
                        batches
                            .push(self.batch_tensors(grad_step.as_ref(), &worker_indices[r])?);
                    }
                    Ok(())
                }
                Stage::FwdBwd => {
                    let params = &self.params;
                    // fan out gradient computation over the live ranks;
                    // forward/backward time is attributed inside the
                    // executable
                    let results: Vec<Result<(Vec<HostTensor>, f64, f64)>> =
                        std::thread::scope(|s| {
                            let handles: Vec<_> = std::mem::take(&mut batches)
                                .into_iter()
                                .map(|(x, y)| {
                                    let grad_step = grad_step.clone();
                                    s.spawn(move || -> Result<(Vec<HostTensor>, f64, f64)> {
                                        let mut inputs: Vec<HostTensor> = params.to_vec();
                                        inputs.push(x);
                                        inputs.push(y);
                                        let mut out = grad_step.run(&inputs)?;
                                        let metric = out
                                            .pop()
                                            .ok_or_else(|| {
                                                anyhow!("grad step returned no metric output")
                                            })?
                                            .scalar();
                                        let loss = out
                                            .pop()
                                            .ok_or_else(|| {
                                                anyhow!("grad step returned no loss output")
                                            })?
                                            .scalar();
                                        Ok((out, loss, metric))
                                    })
                                })
                                .collect();
                            handles
                                .into_iter()
                                .map(|h| {
                                    h.join().unwrap_or_else(|_| {
                                        Err(anyhow!("gradient worker panicked"))
                                    })
                                })
                                .collect()
                        });
                    for r in results {
                        let (g, l, m) = r?;
                        grads_per_worker.push(g);
                        losses.push(l);
                        metrics.push(m);
                    }
                    Ok(())
                }
                Stage::GradReduce => {
                    // bucket-flatten each live worker's grads
                    let mut buffers: Vec<Vec<f32>> = Vec::with_capacity(grads_per_worker.len());
                    for gs in &grads_per_worker {
                        let mut flat = Vec::new();
                        for g in gs {
                            flat.extend_from_slice(
                                g.as_f32().ok_or_else(|| anyhow!("non-f32 gradient tensor"))?,
                            );
                        }
                        buffers.push(flat);
                    }

                    // ring-all-reduce the mean, shedding ranks the fault
                    // session kills mid-collective
                    let mut ranks = live.clone();
                    match &mut self.fault {
                        None => ring_all_reduce_mean(&mut buffers)?,
                        Some(fault) => loop {
                            match fault.all_reduce_mean(self.global_step, &mut buffers, &ranks) {
                                Ok(()) => break,
                                Err(
                                    CollectiveError::WorkerDropped { rank, .. }
                                    | CollectiveError::Timeout { rank, .. },
                                ) => {
                                    let Some(slot) = ranks.iter().position(|&r| r == rank)
                                    else {
                                        return Err(anyhow!(
                                            "fault session dropped unknown rank {rank}"
                                        ));
                                    };
                                    eprintln!(
                                        "[faults] step {}: rank {rank} lost during gradient \
                                         reduce; continuing with {} survivor(s)",
                                        self.global_step,
                                        ranks.len() - 1
                                    );
                                    ranks.remove(slot);
                                    buffers.remove(slot);
                                    grads_per_worker.remove(slot);
                                    losses.remove(slot);
                                    metrics.remove(slot);
                                    if ranks.is_empty() {
                                        return Err(anyhow!(
                                            "every worker was lost during the gradient reduce"
                                        ));
                                    }
                                }
                                Err(e) => return Err(e.into()),
                            }
                        },
                    }

                    // unflatten the first survivor's reduced buffer into
                    // grad tensors
                    let (first_grads, first_buf) =
                        match (grads_per_worker.first(), buffers.first()) {
                            (Some(g), Some(b)) => (g, b),
                            _ => return Err(anyhow!("no gradients survived the reduce")),
                        };
                    let mut red: Vec<HostTensor> = Vec::with_capacity(self.n_params);
                    let mut off = 0usize;
                    for g in first_grads {
                        let n = g.len();
                        red.push(HostTensor::from_f32(
                            g.shape().to_vec(),
                            first_buf[off..off + n].to_vec(),
                        ));
                        off += n;
                    }
                    reduced = Some(red);
                    Ok(())
                }
                Stage::PrecondImport => self.shard_import_pending(),
                Stage::PrecondRefresh => {
                    let grads = reduced
                        .as_deref()
                        .ok_or_else(|| anyhow!("preconditioner refresh before gradient reduce"))?;
                    self.shard_refresh(&mut cx, grads)
                }
                Stage::PrecondExchange => self.shard_exchange(&mut cx),
                Stage::Apply => {
                    if self.shard.is_some() {
                        self.shard_apply(&mut cx)
                    } else {
                        let grads = reduced
                            .take()
                            .ok_or_else(|| anyhow!("apply before gradient reduce"))?;
                        self.apply_reduced(grads, lr)
                    }
                }
                other => {
                    Err(anyhow!("unexpected stage {} in data-parallel plan", other.name()))
                }
            }
        })?;

        let n = losses.len() as f64;
        Ok((losses.iter().sum::<f64>() / n, metrics.iter().sum::<f64>() / n))
    }

    /// Land the previous step's deferred preconditioner import — the
    /// `--precond-overlap` double buffer — before any of this step's
    /// refresh work. Import goes by the layer order captured when the
    /// buffer was exported, so it is sound across membership changes.
    fn shard_import_pending(&mut self) -> Result<()> {
        let Some(native) = self.native_opt.as_mut() else {
            return Err(anyhow!("sharded mode requires the native optimizer mirror"));
        };
        let Some(shard) = self.shard.as_mut() else {
            return Err(anyhow!("shard_import_pending called without shard state"));
        };
        let Some(p) = shard.pending.take() else {
            return Ok(());
        };
        let used = native.import_preconditioners(&p.order, &p.buf);
        if used != p.buf.len() {
            return Err(anyhow!(
                "deferred preconditioner import payload mismatch: used {used} of {} floats",
                p.buf.len()
            ));
        }
        Ok(())
    }

    /// Owner-computes refresh over the reduced gradients. Re-balances
    /// the owner map if membership shrank during the gradient reduce,
    /// snapshots the pre-refresh preconditioners where a later stage
    /// needs them (mid-gather fault revert, overlap staleness), then
    /// refreshes each live owner's layers. Shampoo also advances its
    /// stat EMAs here on skip steps, so this stage runs every step.
    fn shard_refresh(&mut self, cx: &mut ShardStepCx, grads: &[HostTensor]) -> Result<()> {
        let policy = self.cfg.shard_policy;
        let Some(native) = self.native_opt.as_mut() else {
            return Err(anyhow!("sharded mode requires the native optimizer mirror"));
        };
        let Some(shard) = self.shard.as_mut() else {
            return Err(anyhow!("shard_refresh called without shard state"));
        };

        cx.mats = to_matrices(&self.params)?;
        cx.gmats = to_matrices(grads)?;

        // membership may have shrunk during the gradient reduce:
        // re-balance the owner map over the survivors before any refresh
        // work, so no layer's statistics stall on a dead rank
        if let Some(fault) = self.fault.as_ref() {
            if shard
                .owned
                .iter()
                .enumerate()
                .any(|(w, ls)| !ls.is_empty() && !fault.is_alive(w))
            {
                reassign_owners(shard, &**native, &fault.live_ranks(), policy)?;
            }
        }

        // pre-refresh snapshot: an owner lost mid-gather falls back to
        // these stale preconditioners for this step, and the overlapped
        // exchange reverts to them so its apply is one refresh stale
        cx.stale = if cx.update && (self.fault.is_some() || shard.overlap) {
            Some(shard.owned.iter().map(|ls| native.export_preconditioners(ls)).collect())
        } else {
            None
        };
        if cx.update && shard.overlap {
            cx.refresh_owned = shard.owned.clone();
        }

        for w in 0..shard.owned.len() {
            if self.fault.as_ref().is_some_and(|f| !f.is_alive(w)) {
                continue;
            }
            native.refresh_layers(&shard.owned[w], &cx.gmats, cx.update);
            if cx.update {
                shard.refresh_layer_events[w] += shard.owned[w].len();
            }
        }
        Ok(())
    }

    /// Export the refreshed preconditioners and run the ring
    /// all-gather. On the synchronous path the gathered buffer is
    /// imported immediately — float-for-float the serial step. Under
    /// `--precond-overlap` it is parked in the pending slot for the
    /// next step boundary and the mirror reverts to the pre-refresh
    /// snapshot, so this step's apply is one refresh stale.
    ///
    /// Under fault injection, an owner lost mid-gather degrades
    /// gracefully: its layers keep the stale pre-refresh
    /// preconditioners, the assignment is re-balanced over the
    /// survivors, and the gather retries.
    fn shard_exchange(&mut self, cx: &mut ShardStepCx) -> Result<()> {
        let step = self.global_step;
        let policy = self.cfg.shard_policy;
        let Some(native) = self.native_opt.as_mut() else {
            return Err(anyhow!("sharded mode requires the native optimizer mirror"));
        };
        let Some(shard) = self.shard.as_mut() else {
            return Err(anyhow!("shard_exchange called without shard state"));
        };
        match self.fault.as_mut() {
            None => {
                // fault-free path: float-for-float the serial step
                let chunks: Vec<Vec<f32>> =
                    shard.owned.iter().map(|ls| native.export_preconditioners(ls)).collect();
                let chunk_bytes: Vec<usize> = chunks.iter().map(|c| 4 * c.len()).collect();
                let gathered = ring_all_gather(&chunks)?;
                shard.allgather_calls += 1;
                shard.allgather_floats += gathered.last().map_or(0, |b| b.len());
                shard.modeled_comm_s += shard.comm.all_gather_ragged_time(&chunk_bytes);
                // continue from the last rank's assembled buffer, so
                // the state the run depends on has genuinely been
                // around the ring
                if let Some(buf) = gathered.last() {
                    let order: Vec<usize> = shard.owned.concat();
                    if shard.overlap {
                        shard.pending = Some(PendingImport { order, buf: buf.clone() });
                    } else {
                        let used = native.import_preconditioners(&order, buf);
                        debug_assert_eq!(used, buf.len(), "all-gather payload mismatch");
                    }
                }
            }
            Some(fault) => {
                // the gather runs over the owner map as it stood when
                // the chunks were exported; a mid-gather reassignment
                // only affects future steps, so capture the
                // participants' layer lists up front
                let mut participants: Vec<usize> = fault.live_ranks();
                let mut gather_owned: Vec<Vec<usize>> =
                    participants.iter().map(|&r| shard.owned[r].clone()).collect();
                let mut chunks: Vec<Vec<f32>> = gather_owned
                    .iter()
                    .map(|ls| native.export_preconditioners(ls))
                    .collect();
                loop {
                    match fault.all_gather(step, &mut chunks, &participants) {
                        Ok(gathered) => {
                            let chunk_bytes: Vec<usize> =
                                chunks.iter().map(|c| 4 * c.len()).collect();
                            shard.allgather_calls += 1;
                            shard.allgather_floats += gathered.last().map_or(0, |b| b.len());
                            shard.modeled_comm_s +=
                                shard.comm.all_gather_ragged_time(&chunk_bytes);
                            if let Some(buf) = gathered.last() {
                                let order: Vec<usize> = gather_owned.concat();
                                if shard.overlap {
                                    shard.pending =
                                        Some(PendingImport { order, buf: buf.clone() });
                                } else {
                                    let used = native.import_preconditioners(&order, buf);
                                    if used != buf.len() {
                                        return Err(anyhow!(
                                            "all-gather payload mismatch: used {used} of {} \
                                             floats",
                                            buf.len()
                                        ));
                                    }
                                }
                            }
                            break;
                        }
                        Err(
                            CollectiveError::WorkerDropped { rank, .. }
                            | CollectiveError::Timeout { rank, .. },
                        ) => {
                            let Some(slot) = participants.iter().position(|&r| r == rank)
                            else {
                                return Err(anyhow!(
                                    "fault session dropped unknown rank {rank}"
                                ));
                            };
                            // the dead owner's refreshed preconditioners
                            // never made it around the ring: revert its
                            // layers to the stale snapshot for this step
                            if let (Some(st), Some(ls)) =
                                (cx.stale.as_ref(), gather_owned.get(slot))
                            {
                                native.import_preconditioners(ls, &st[rank]);
                                shard.stale_fallback_layers += ls.len();
                                eprintln!(
                                    "[faults] step {step}: owner rank {rank} lost during \
                                     preconditioner all-gather; {} layer(s) keep stale \
                                     preconditioners this step",
                                    ls.len()
                                );
                            }
                            participants.remove(slot);
                            gather_owned.remove(slot);
                            chunks.remove(slot);
                            if participants.is_empty() {
                                return Err(anyhow!(
                                    "every worker was lost during the preconditioner \
                                     all-gather"
                                ));
                            }
                            // re-balance future refreshes over survivors
                            reassign_owners(shard, &**native, &participants, policy)?;
                        }
                        Err(e) => return Err(e.into()),
                    }
                }
            }
        }
        if shard.overlap {
            // the apply this step runs on the pre-refresh
            // preconditioners: revert the refreshed layers now; the
            // gathered copy lands from the pending slot at the next
            // step boundary
            let stale = cx
                .stale
                .as_ref()
                .ok_or_else(|| anyhow!("overlapped exchange without a stale snapshot"))?;
            for (w, ls) in cx.refresh_owned.iter().enumerate() {
                native.import_preconditioners(ls, &stale[w]);
            }
            shard.overlap_exchanges += 1;
            shard.stale_applies += 1;
        }
        Ok(())
    }

    /// Apply the update with the current preconditioners — freshly
    /// gathered on the synchronous path, one refresh stale under
    /// `--precond-overlap`. The native mirror attributes its own Apply
    /// phase time.
    fn shard_apply(&mut self, cx: &mut ShardStepCx) -> Result<()> {
        let wd = self.cfg.weight_decay as f32;
        let Some(native) = self.native_opt.as_mut() else {
            return Err(anyhow!("sharded mode requires the native optimizer mirror"));
        };
        native.apply_update(
            &mut cx.mats,
            &cx.gmats,
            StepCtx { lr: cx.lr as f32, weight_decay: wd, update_precond: false },
        );
        for (p, m) in self.params.iter_mut().zip(cx.mats.drain(..)) {
            if let Some(buf) = p.as_f32_mut() {
                *buf = m.data;
            }
        }
        Ok(())
    }

    /// Serial-optimizer apply for the data-parallel path; the plan
    /// executor owns the Apply trace scope.
    fn apply_reduced(&mut self, grads: Vec<HostTensor>, lr: f64) -> Result<()> {
        let update = self.precond_update_now();
        if let Some(native) = &mut self.native_opt {
            // native mirror path: the fused step() runs refresh + apply
            // back to back, so its whole cost is attributed to Apply
            let mut mats = to_matrices(&self.params)?;
            let gmats = to_matrices(&grads)?;
            native.step(
                &mut mats,
                &gmats,
                StepCtx {
                    lr: lr as f32,
                    weight_decay: self.cfg.weight_decay as f32,
                    update_precond: update,
                },
            );
            for (p, m) in self.params.iter_mut().zip(mats) {
                if let Some(buf) = p.as_f32_mut() {
                    *buf = m.data;
                }
            }
            return Ok(());
        }
        let step = match (&self.apply_skip, update) {
            (Some(skip), false) => skip.clone(),
            _ => self.apply_full.clone(),
        };
        let mut inputs: Vec<HostTensor> =
            Vec::with_capacity(2 * self.n_params + self.opt_state.len() + 2);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(grads);
        inputs.extend(self.opt_state.iter().cloned());
        inputs.push(HostTensor::scalar_f32(lr as f32));
        inputs.push(HostTensor::scalar_f32(self.cfg.weight_decay as f32));
        let mut outputs = step.run(&inputs)?;
        if outputs.len() < self.n_params {
            return Err(anyhow!("apply step output arity mismatch"));
        }
        let state = outputs.split_off(self.n_params);
        self.params = outputs;
        self.opt_state = state;
        Ok(())
    }

    /// Held-out evaluation: mean loss/metric over EVAL_BATCHES batches.
    ///
    /// The leader computes the result; with a fault session active the
    /// `[loss, metric]` pair is then pushed through the fault-aware tree
    /// broadcast, so `--faults` events against the `eval` op are actually
    /// routable (a rank lost here is shed like any other collective
    /// casualty, a corrupted receiver copy is re-fetched from the
    /// leader). The leader's f64 values stay authoritative either way, so
    /// eval numerics are bitwise independent of the fault plan.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let mut result = (0.0f64, 0.0f64);
        sched::execute(&StepPlan::boundary(Stage::Eval), &mut |_stage: Stage| -> Result<()> {
            let meta = self
                .engine
                .manifest()
                .models
                .get(&self.cfg.model)
                .ok_or_else(|| anyhow!("model {} not in manifest", self.cfg.model))?;
            let eb = meta.eval_batch;
            let mut loss = Summary::new();
            let mut metric = Summary::new();
            for k in 0..EVAL_BATCHES {
                let base = self.cfg.dataset_size + k * eb;
                let indices: Vec<usize> = (base..base + eb).collect();
                let (x, y) = self.batch_tensors(self.eval.as_ref(), &indices)?;
                let mut inputs: Vec<HostTensor> = self.params.to_vec();
                inputs.push(x);
                inputs.push(y);
                let out = self.eval.run(&inputs)?;
                if out.len() < 2 {
                    return Err(anyhow!("eval step returned {} outputs, need 2", out.len()));
                }
                loss.add(out[0].scalar());
                metric.add(out[1].scalar());
            }
            let (loss, metric) = (loss.mean(), metric.mean());
            self.broadcast_eval_result(loss, metric)?;
            result = (loss, metric);
            Ok(())
        })?;
        Ok(result)
    }

    /// Distribute the leader's eval result to the live ranks through the
    /// fault session (no-op without one). Ranks lost mid-broadcast are
    /// shed and the survivors retry; corrupted receiver copies are
    /// counted and discarded in favour of the leader's values.
    fn broadcast_eval_result(&mut self, loss: f64, metric: f64) -> Result<()> {
        let Some(fault) = self.fault.as_mut() else { return Ok(()) };
        let step = self.global_step;
        let mut ranks = fault.live_ranks();
        loop {
            if ranks.is_empty() {
                return Err(anyhow!("every worker was lost during the eval broadcast"));
            }
            let root = ranks[0];
            let mut bufs: Vec<Vec<f32>> =
                ranks.iter().map(|_| vec![loss as f32, metric as f32]).collect();
            match fault.broadcast(step, &mut bufs, &ranks, root) {
                Ok(()) => {
                    let corrupted =
                        bufs.iter().filter(|b| b.iter().any(|v| !v.is_finite())).count();
                    trace::incr("fault.eval_corrupt_refetches", corrupted as u64);
                    return Ok(());
                }
                Err(
                    CollectiveError::WorkerDropped { rank, .. }
                    | CollectiveError::Timeout { rank, .. },
                ) => {
                    eprintln!(
                        "[faults] step {step}: rank {rank} lost during eval broadcast; \
                         continuing with {} survivor(s)",
                        ranks.len() - 1
                    );
                    ranks.retain(|&r| r != rank);
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Step-boundary re-admission barrier: fire any `rejoin` events due
    /// at the current global step. Each readmitted rank receives the
    /// leader's full training state — the exact checkpoint blob
    /// `--resume` reads — through the real tree-broadcast schedule,
    /// restores it via [`Trainer::apply_checkpoint`], and the
    /// FLOPs-balanced owner assignment is re-run over the restored
    /// membership. `decode_blob(encode_blob(state))` is a bitwise
    /// identity and the leader's state is never perturbed, so the
    /// trajectory from this step onward is bitwise identical to a run
    /// that entered the step with full membership and the same state.
    fn readmit_ranks(&mut self) -> Result<()> {
        let step = self.global_step;
        let rejoined = match self.fault.as_mut() {
            Some(f) => f.take_rejoins(step),
            None => return Ok(()),
        };
        if rejoined.is_empty() {
            return Ok(());
        }
        sched::execute(&StepPlan::boundary(Stage::Resync), &mut |_stage: Stage| -> Result<()> {
            let named = self.state_tensors();
            let refs: Vec<(String, &HostTensor)> =
                named.iter().map(|(n, t)| (n.clone(), t)).collect();
            let blob = super::checkpoint::encode_blob(&refs);
            let comm =
                self.shard.as_ref().map(|s| s.comm).unwrap_or_else(CommCostModel::nvlink_a100);
            // the barrier world is the *restored* membership: take_rejoins
            // already flipped the readmitted ranks back to alive
            let live: Vec<usize> = match &self.fault {
                Some(f) => f.live_ranks(),
                None => Vec::new(),
            };
            // leader = lowest rank that was live before the barrier (it
            // carries authoritative state; a rank cannot resync from itself)
            let root = live
                .iter()
                .copied()
                .find(|r| !rejoined.contains(r))
                .ok_or_else(|| anyhow!("rejoin barrier: no surviving leader to resync from"))?;
            let (received, resync_s) = {
                let Some(fault) = self.fault.as_mut() else { return Ok(()) };
                let before = fault.modeled_resync_s();
                let mut received: Option<Vec<u8>> = None;
                for &r in &rejoined {
                    let bytes = fault.resync_broadcast(&blob, &live, root, r, &comm)?;
                    eprintln!(
                        "[faults] step {step}: rank {r} rejoined; resynced {} bytes from \
                         leader rank {root}",
                        blob.len()
                    );
                    received = Some(bytes);
                }
                (received, fault.modeled_resync_s() - before)
            };
            // restore the received copy through the shared resume codepath,
            // exercising the full serialize -> broadcast -> deserialize
            // contract the rejoining worker would run
            if let Some(bytes) = received {
                let tensors = super::checkpoint::decode_blob(&bytes)
                    .map_err(|e| anyhow!("rejoin resync decode: {e}"))?;
                self.apply_checkpoint(tensors)?;
            }
            // fold the readmitted ranks back into owner-computes refresh;
            // with full membership restored the deterministic LPT reproduces
            // the original assignment, and the resync traffic is charged to
            // the modeled step like any other collective
            let policy = self.cfg.shard_policy;
            if let (Some(native), Some(shard)) =
                (self.native_opt.as_deref(), self.shard.as_mut())
            {
                reassign_owners(shard, native, &live, policy)?;
                shard.modeled_comm_s += resync_s;
            }
            Ok(())
        })
    }

    /// Apply `cfg.resume`: `""` starts fresh, `"auto"` restores the
    /// newest *valid* checkpoint in [`Trainer::checkpoint_dir`]
    /// (truncated or bit-flipped files are skipped by the CRC check),
    /// anything else is an explicit checkpoint path.
    fn maybe_resume(&mut self) -> Result<()> {
        let resume = self.cfg.resume.clone();
        match resume.as_str() {
            "" => Ok(()),
            "auto" => {
                let dir = self.checkpoint_dir();
                match super::checkpoint::latest_valid(&dir) {
                    Some((path, tensors)) => {
                        self.apply_checkpoint(tensors)
                            .map_err(|e| anyhow!("resume from {}: {e}", path.display()))?;
                        eprintln!(
                            "[resume] restored step {} from {}",
                            self.global_step,
                            path.display()
                        );
                        Ok(())
                    }
                    None => {
                        eprintln!("[resume] no valid checkpoint under {dir}; starting fresh");
                        Ok(())
                    }
                }
            }
            path => {
                self.load_checkpoint(path)?;
                eprintln!("[resume] restored step {} from {path}", self.global_step);
                Ok(())
            }
        }
    }

    /// Run the full training loop. With `cfg.resume` set, completed
    /// steps are skipped deterministically (the sharder is pure per
    /// epoch), so a resumed run continues bitwise-identically to an
    /// uninterrupted one.
    pub fn run(&mut self) -> Result<RunResult> {
        // arm the trace registry when the run asked for it; leave it
        // untouched (still a single relaxed load per scope) otherwise
        let self_enabled = !trace::enabled()
            && (!self.cfg.trace_path.is_empty() || !self.cfg.metrics_out.is_empty());
        if self_enabled {
            trace::set_enabled(true);
        }
        let tracing = trace::enabled();
        let mut trace_log = if tracing && !self.cfg.trace_path.is_empty() {
            Some(JsonlWriter::create(&self.cfg.trace_path)?)
        } else {
            None
        };
        if let Some(w) = &mut trace_log {
            let mut ev = BTreeMap::new();
            ev.insert("event".to_string(), Json::Str("run_start".to_string()));
            ev.insert("model".to_string(), Json::Str(self.cfg.model.clone()));
            ev.insert("optimizer".to_string(), Json::Str(self.kind.to_string()));
            ev.insert("workers".to_string(), Json::Num(self.cfg.workers as f64));
            ev.insert("precond_every".to_string(), Json::Num(self.cfg.precond_every as f64));
            ev.insert("seed".to_string(), Json::Num(self.cfg.seed as f64));
            w.write(&Json::Obj(ev))?;
        }
        let pool_baseline = dispatch_counters();

        self.maybe_resume()?;
        let resume_step = self.global_step;
        let ckpt_dir = self.checkpoint_dir();

        // grad artifact batch == model batch; with workers > 1 every
        // worker consumes a full batch (weak scaling, like the paper's
        // DDP runs)
        let per_worker_batch = self
            .engine
            .manifest()
            .models
            .get(&self.cfg.model)
            .ok_or_else(|| anyhow!("model {} not in manifest", self.cfg.model))?
            .batch;

        let mut result = RunResult {
            model: self.cfg.model.clone(),
            optimizer: self.kind.to_string(),
            ..Default::default()
        };
        let sw = Stopwatch::new();
        let mut iter_times = Summary::new();
        // warm-iteration stats: skip the first measured step (pool
        // spawn, cache-cold code paths) so reported means/percentiles
        // reflect steady state
        let mut warm_times = Summary::new();
        let sharder = Sharder {
            dataset_len: self.cfg.dataset_size,
            workers: self.cfg.workers,
            seed: self.cfg.seed ^ 0x5A4D,
        };

        let mut seen = 0usize;
        'epochs: for epoch in 0..self.cfg.epochs {
            let shards = sharder.epoch_shards(epoch);
            let steps_this_epoch = (shards[0].len() / per_worker_batch)
                .min(self.cfg.steps_per_epoch)
                .max(1);
            if seen + steps_this_epoch <= resume_step {
                // the whole epoch completed before the checkpoint was taken
                seen += steps_this_epoch;
                continue;
            }
            let mut ep_loss = Summary::new();
            let mut ep_metric = Summary::new();
            let mut lr_now = self.cfg.lr;

            for si in 0..steps_this_epoch {
                if seen < resume_step {
                    seen += 1;
                    continue;
                }
                if self.global_step >= self.cfg.max_steps {
                    break 'epochs;
                }
                lr_now = self.schedule.lr_at(self.global_step);
                self.readmit_ranks()?;
                let t0 = std::time::Instant::now();
                let (loss, metric) = if self.cfg.workers == 1 {
                    let lo = si * per_worker_batch;
                    self.fused_step(&shards[0][lo..lo + per_worker_batch], lr_now)?
                } else {
                    let worker_indices: Vec<Vec<usize>> = shards
                        .iter()
                        .map(|sh| {
                            let lo = (si * per_worker_batch) % (sh.len() - per_worker_batch + 1);
                            sh[lo..lo + per_worker_batch].to_vec()
                        })
                        .collect();
                    self.data_parallel_step(&worker_indices, lr_now)?
                };
                let dt = t0.elapsed().as_secs_f64();
                iter_times.add(dt);
                if iter_times.count() > 1 {
                    warm_times.add(dt);
                }
                self.global_step += 1;
                seen += 1;
                ep_loss.add(loss);
                ep_metric.add(metric);
                result.step_losses.push(loss as f32);
                if self.cfg.checkpoint_every > 0
                    && self.global_step % self.cfg.checkpoint_every == 0
                {
                    let plan = StepPlan::boundary(Stage::Checkpoint);
                    sched::execute(&plan, &mut |_stage: Stage| -> Result<()> {
                        let path = super::checkpoint::step_path(&ckpt_dir, self.global_step)
                            .to_string_lossy()
                            .to_string();
                        self.save_checkpoint(&path)
                    })?;
                }
                if let Some(rows) = trace::flush_step() {
                    if let Some(w) = &mut trace_log {
                        let mut ev = BTreeMap::new();
                        ev.insert("event".to_string(), Json::Str("step".to_string()));
                        ev.insert(
                            "step".to_string(),
                            Json::Num((self.global_step - 1) as f64),
                        );
                        ev.insert("loss".to_string(), Json::Num(loss));
                        ev.insert("wall_s".to_string(), Json::Num(dt));
                        let phases: BTreeMap<String, Json> = rows
                            .iter()
                            .map(|(name, s)| (name.to_string(), Json::Num(*s)))
                            .collect();
                        ev.insert("phases".to_string(), Json::Obj(phases));
                        w.write(&Json::Obj(ev))?;
                    }
                }
            }

            let (val_loss, val_metric) = self.evaluate()?;
            // roll eval time into its own trace row so step rows stay
            // strictly per-training-step
            if let Some(rows) = trace::flush_step() {
                if let Some(w) = &mut trace_log {
                    let mut ev = BTreeMap::new();
                    ev.insert("event".to_string(), Json::Str("eval".to_string()));
                    ev.insert("epoch".to_string(), Json::Num(epoch as f64));
                    ev.insert("val_loss".to_string(), Json::Num(val_loss));
                    ev.insert("val_metric".to_string(), Json::Num(val_metric));
                    let phases: BTreeMap<String, Json> = rows
                        .iter()
                        .map(|(name, s)| (name.to_string(), Json::Num(*s)))
                        .collect();
                    ev.insert("phases".to_string(), Json::Obj(phases));
                    w.write(&Json::Obj(ev))?;
                }
            }
            let rec = EpochRecord {
                epoch,
                lr: lr_now,
                train_loss: ep_loss.mean(),
                train_metric: ep_metric.mean(),
                val_metric,
                val_loss,
                iter_time_s: iter_times.mean(),
                wall_s: sw.total(),
            };
            if epoch % self.cfg.eval_every_epochs == 0 || epoch + 1 == self.cfg.epochs {
                eprintln!(
                    "[{} {}] epoch {epoch:>3} lr {:.4} loss {:.4} val {:.4} ({:.1}s)",
                    self.cfg.model, self.kind, rec.lr, rec.train_loss, rec.val_metric, rec.wall_s
                );
            }
            result.best_val_metric = result.best_val_metric.max(val_metric);
            result.epochs.push(rec);
            if self.cfg.target_metric > 0.0
                && val_metric >= self.cfg.target_metric
                && result.epochs_to_target.is_none()
            {
                result.epochs_to_target = Some(epoch + 1);
                result.time_to_target_s = Some(sw.total());
                break;
            }
        }

        result.total_time_s = sw.total();
        // warm stats when available (any run of >= 2 steps); a 0/1-step
        // run falls back to the raw samples
        let stats = if warm_times.count() > 0 { &warm_times } else { &iter_times };
        result.mean_iter_s = stats.mean();
        if stats.count() > 0 {
            result.iter_p50_s = stats.percentile(50.0);
            result.iter_p95_s = stats.percentile(95.0);
        }
        result.final_val_metric = result.epochs.last().map(|e| e.val_metric).unwrap_or(0.0);
        result.shard = self.shard_report();
        result.guard = self.guard_report();
        result.faults = self.fault_report();

        if tracing {
            // unify every subsystem's counters in the one registry
            for (name, v) in result.guard.counter_pairs() {
                trace::incr(&format!("guard.{name}"), v as u64);
            }
            if let Some(sh) = &result.shard {
                trace::incr("shard.allgather_calls", sh.allgather_calls as u64);
                trace::incr("shard.allgather_floats", sh.allgather_floats as u64);
                trace::incr("shard.overlap_exchanges", sh.overlap_exchanges as u64);
                trace::incr("shard.stale_applies", sh.stale_applies as u64);
                trace::incr("shard.stale_fallback_layers", sh.stale_fallback_layers as u64);
                trace::incr("shard.reassignments", sh.reassignments as u64);
                trace::incr("shard.rejoin_events", sh.rejoin_events as u64);
                trace::incr("shard.resync_bytes", sh.resync_bytes as u64);
                trace::set_gauge("shard.modeled_comm_s", sh.modeled_comm_s);
            }
            if let Some(f) = &result.faults {
                trace::incr("fault.events", f.events.len() as u64);
                trace::incr("fault.retries", f.retries as u64);
                trace::incr("fault.dropped", f.dropped.len() as u64);
                trace::incr("fault.rejoins", f.rejoins as u64);
                trace::incr("fault.membership_epochs", f.membership_epochs as u64);
                trace::set_gauge("fault.modeled_backoff_s", f.modeled_backoff_s);
            }
            let pd = dispatch_counters().since(&pool_baseline);
            trace::incr("pool.jobs", pd.pool_jobs);
            trace::incr("pool.inline_jobs", pd.inline_jobs);
            trace::incr("pool.tasks", pd.tasks);
            trace::set_gauge("pool.fanout_ratio", pd.fanout_ratio());
            trace::set_gauge("step_total_s", iter_times.total());
            trace::set_gauge("steps", result.step_losses.len() as f64);

            let report = trace::take_report();
            if let Some(w) = &mut trace_log {
                let mut ev = BTreeMap::new();
                ev.insert("event".to_string(), Json::Str("summary".to_string()));
                ev.insert("metrics".to_string(), report.to_json());
                w.write(&Json::Obj(ev))?;
                w.flush()?;
            }
            result.metrics = Some(report);
            if self_enabled {
                trace::set_enabled(false);
            }
        }
        Ok(result)
    }

    /// The full named training state under the checkpoint contract:
    /// params + optimizer state — and, on the native path, the mirror's
    /// preconditioner state and step counter. Both cadence checkpoints
    /// and the rejoin resync broadcast serialize exactly this list, so
    /// a resynced rank and a `--resume`d run restore through one
    /// codepath.
    fn state_tensors(&mut self) -> Vec<(String, HostTensor)> {
        let mut named: Vec<(String, HostTensor)> = Vec::new();
        {
            let spec = self.train_full.spec();
            let mut pi = 0;
            let mut si = 0;
            for input in &spec.inputs {
                match input.role {
                    Role::Param => {
                        named.push((format!("param/{}", input.name), self.params[pi].clone()));
                        pi += 1;
                    }
                    Role::State => {
                        named.push((format!("state/{}", input.name), self.opt_state[si].clone()));
                        si += 1;
                    }
                    _ => {}
                }
            }
        }
        if let Some(native) = &mut self.native_opt {
            let t = native.step_count();
            for (i, m) in native.state_mut().into_iter().enumerate() {
                named.push((
                    format!("native/{i:04}"),
                    HostTensor::from_f32(vec![m.rows, m.cols], m.data.clone()),
                ));
            }
            named.push((
                "native/step_count".to_string(),
                HostTensor::from_i32(vec![1], vec![t as i32]),
            ));
        }
        named.push((
            "meta/global_step".to_string(),
            HostTensor::from_i32(vec![1], vec![self.global_step as i32]),
        ));
        named
    }

    /// Save the full training state. Atomic + checksummed: see
    /// [`super::checkpoint::save`]. A resumed run continues
    /// bitwise-identically.
    pub fn save_checkpoint(&mut self, path: &str) -> Result<()> {
        let named = self.state_tensors();
        let refs: Vec<(String, &HostTensor)> =
            named.iter().map(|(n, t)| (n.clone(), t)).collect();
        super::checkpoint::save(path, &refs)?;
        Ok(())
    }

    /// Restore params + optimizer state from a checkpoint.
    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        let tensors = super::checkpoint::load(path)?;
        self.apply_checkpoint(tensors)
    }

    /// Route loaded tensors back into live state by name prefix; strict
    /// about counts and shapes so a checkpoint from a different model or
    /// optimizer is a typed error, not silent corruption.
    fn apply_checkpoint(&mut self, tensors: Vec<(String, HostTensor)>) -> Result<()> {
        let mut params = Vec::new();
        let mut state = Vec::new();
        let mut native_state: Vec<HostTensor> = Vec::new();
        let mut native_step: Option<u64> = None;
        let mut global_step: Option<usize> = None;
        for (name, t) in tensors {
            if name.starts_with("param/") {
                params.push(t);
            } else if name.starts_with("state/") {
                state.push(t);
            } else if name == "native/step_count" {
                native_step = t.as_i32().and_then(|v| v.first()).map(|&v| v.max(0) as u64);
            } else if name.starts_with("native/") {
                native_state.push(t);
            } else if name == "meta/global_step" {
                global_step = t.as_i32().and_then(|v| v.first()).map(|&v| v.max(0) as usize);
            }
        }
        if params.len() != self.params.len() || state.len() != self.opt_state.len() {
            return Err(anyhow!(
                "checkpoint mismatch: {}p/{}s vs expected {}p/{}s",
                params.len(),
                state.len(),
                self.params.len(),
                self.opt_state.len()
            ));
        }
        for (a, b) in self.params.iter().zip(&params) {
            if a.shape() != b.shape() {
                return Err(anyhow!("checkpoint param shape mismatch"));
            }
        }
        self.params = params;
        self.opt_state = state;
        if let Some(native) = &mut self.native_opt {
            if !native_state.is_empty() {
                let mut slots = native.state_mut();
                if slots.len() != native_state.len() {
                    return Err(anyhow!(
                        "checkpoint native-state mismatch: {} tensors vs expected {}",
                        native_state.len(),
                        slots.len()
                    ));
                }
                for (slot, t) in slots.iter_mut().zip(&native_state) {
                    let data = t
                        .as_f32()
                        .ok_or_else(|| anyhow!("native optimizer state tensor is not f32"))?;
                    if data.len() != slot.data.len() {
                        return Err(anyhow!("checkpoint native-state shape mismatch"));
                    }
                    slot.data.copy_from_slice(data);
                }
                if let Some(t) = native_step {
                    native.set_step_count(t);
                }
            }
        }
        if let Some(gs) = global_step {
            self.global_step = gs;
        }
        Ok(())
    }
}
