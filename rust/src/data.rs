//! Synthetic datasets (substrate — DESIGN.md §3 substitution table).
//!
//! Each dataset is a deterministic function of (seed, sample index), so
//! data-parallel sharding, shuffling and multi-trial reproducibility need
//! no on-disk corpus. All four tasks are *learnable* — class structure is
//! planted so the optimizer comparison (sample efficiency to a target
//! metric) is meaningful:
//!
//! * `SynthImages`   — gaussian-mixture images/features (mlp + cnn slots)
//! * `SynthSeg`      — per-pixel labels from a planted color->class rule
//! * `MarkovTokens`  — order-1 Markov chain with peaked transitions (LM)

use crate::rngx::Rng;

/// One host-side batch, dtype-tagged to match the artifact input spec.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x_f32: Vec<f32>,
    pub x_i32: Vec<i32>,
    pub y: Vec<i32>,
    pub batch_size: usize,
}

pub trait Dataset: Send + Sync {
    fn name(&self) -> &'static str;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Floats per sample in x (0 if the input is integer tokens).
    fn x_f32_len(&self) -> usize;
    /// Ints per sample in x (0 if the input is float).
    fn x_i32_len(&self) -> usize;
    /// Labels per sample (1 for classification, H*W for segmentation,
    /// seq_len for LM).
    fn y_len(&self) -> usize;
    /// Write sample `idx` into the provided slices.
    fn sample(&self, idx: usize, x_f32: &mut [f32], x_i32: &mut [i32], y: &mut [i32]);

    fn batch(&self, indices: &[usize]) -> Batch {
        let b = indices.len();
        let mut out = Batch {
            x_f32: vec![0.0; b * self.x_f32_len()],
            x_i32: vec![0; b * self.x_i32_len()],
            y: vec![0; b * self.y_len()],
            batch_size: b,
        };
        let (fx, ix, yl) = (self.x_f32_len(), self.x_i32_len(), self.y_len());
        for (k, &idx) in indices.iter().enumerate() {
            self.sample(
                idx,
                &mut out.x_f32[k * fx..(k + 1) * fx],
                &mut out.x_i32[k * ix..(k + 1) * ix],
                &mut out.y[k * yl..(k + 1) * yl],
            );
        }
        out
    }
}

/// Epoch iterator: shuffled indices, sharded round-robin across workers.
pub struct Sharder {
    pub dataset_len: usize,
    pub workers: usize,
    pub seed: u64,
}

impl Sharder {
    /// Index lists per worker for `epoch`, all workers equal length
    /// (remainder dropped, like DistributedSampler).
    pub fn epoch_shards(&self, epoch: usize) -> Vec<Vec<usize>> {
        let mut rng = Rng::new(self.seed ^ (epoch as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let perm = rng.permutation(self.dataset_len);
        let per = self.dataset_len / self.workers;
        (0..self.workers)
            .map(|w| perm[w * per..(w + 1) * per].to_vec())
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Gaussian-mixture images / features
// ---------------------------------------------------------------------------

pub struct SynthImages {
    len: usize,
    dims: usize,
    classes: usize,
    /// class means, planted with separation `sep`
    means: Vec<f32>,
    /// per-dimension scale factors (log-uniform). This plants the
    /// *ill-conditioned* gradient covariance where second-order
    /// preconditioning pays off — the regime the paper targets. The
    /// Bayes-optimal accuracy is unchanged (the scaling is invertible).
    dim_scales: Vec<f32>,
    /// optional orthogonal mixing matrix (dims x dims, row-major). With
    /// mixing, the planted anisotropy is *non-diagonal*, so per-coordinate
    /// adaptivity (Adam) cannot undo it but full-matrix preconditioning
    /// (Shampoo/Jorge) can — the regime where the paper's method shines.
    mix: Option<Vec<f32>>,
    noise: f32,
    seed: u64,
    name: &'static str,
}

impl SynthImages {
    pub fn new_mlp(len: usize, seed: u64) -> Self {
        // sep chosen so the Bayes-optimal accuracy is high but reaching it
        // takes tens of epochs — the regime where sample-efficiency
        // differences between optimizers are visible.
        let mut s = Self::new(len, 128, 10, 0.32, 1.0, seed, "synth-mlp");
        s.mix = Some(random_orthogonal(s.dims, seed ^ 0x0127A7E));
        s
    }

    /// 32x32x3 images for the cnn (ResNet stand-in): smooth class
    /// patterns + noise.
    pub fn new_cnn(len: usize, seed: u64) -> Self {
        let mut s = Self::new(len, 32 * 32 * 3, 10, 0.22, 1.0, seed, "synth-cifar");
        // smooth the class means spatially so convs have local structure,
        // then restore the planted separation (blur shrinks the std)
        let dims = s.dims;
        let sep = 0.20f32;
        for c in 0..s.classes {
            let mean = &mut s.means[c * dims..(c + 1) * dims];
            smooth_hwc(mean, 32, 32, 3);
            let std = (mean.iter().map(|v| v * v).sum::<f32>() / dims as f32).sqrt();
            let k = sep / std.max(1e-6);
            for v in mean.iter_mut() {
                *v *= k;
            }
        }
        // convs are translation-equivariant, so keep the planted
        // ill-conditioning *spatially smooth*, milder than the mlp's, and
        // normalised to geometric mean 1 (no global magnitude blow-up)
        let mut rng = Rng::new(seed ^ 0x5CA1E);
        for v in s.dim_scales.iter_mut() {
            *v = 10f32.powf(rng.uniform_in(-0.6, 0.6));
        }
        smooth_hwc(&mut s.dim_scales, 32, 32, 3);
        let log_mean =
            s.dim_scales.iter().map(|v| v.ln()).sum::<f32>() / s.dim_scales.len() as f32;
        let norm = (-log_mean).exp();
        for v in s.dim_scales.iter_mut() {
            *v *= norm;
        }
        s
    }

    fn new(
        len: usize,
        dims: usize,
        classes: usize,
        sep: f32,
        noise: f32,
        seed: u64,
        name: &'static str,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0xDA7A);
        let mut means = vec![0.0f32; classes * dims];
        rng.fill_normal(&mut means, 0.0, sep);
        // condition number ~ 10^2.4 across feature dimensions
        let dim_scales: Vec<f32> = (0..dims)
            .map(|_| 10f32.powf(rng.uniform_in(-1.2, 1.2)))
            .collect();
        SynthImages { len, dims, classes, means, dim_scales, mix: None, noise, seed, name }
    }

    pub fn classes(&self) -> usize {
        self.classes
    }
}

/// Random orthogonal matrix via modified Gram-Schmidt on a gaussian.
fn random_orthogonal(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut q = vec![0.0f32; n * n];
    rng.fill_normal(&mut q, 0.0, 1.0);
    for i in 0..n {
        for j in 0..i {
            let dot: f32 = (0..n).map(|k| q[i * n + k] * q[j * n + k]).sum();
            for k in 0..n {
                q[i * n + k] -= dot * q[j * n + k];
            }
        }
        let norm: f32 = (0..n).map(|k| q[i * n + k] * q[i * n + k]).sum::<f32>().sqrt();
        let inv = 1.0 / norm.max(1e-12);
        for k in 0..n {
            q[i * n + k] *= inv;
        }
    }
    q
}

fn smooth_hwc(data: &mut [f32], h: usize, w: usize, c: usize) {
    // 3x3 box blur, two passes
    for _ in 0..2 {
        let src = data.to_vec();
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    let mut acc = 0.0;
                    let mut cnt = 0.0;
                    for dy in -1i64..=1 {
                        for dx in -1i64..=1 {
                            let (ny, nx) = (y as i64 + dy, x as i64 + dx);
                            if (0..h as i64).contains(&ny) && (0..w as i64).contains(&nx) {
                                acc += src[(ny as usize * w + nx as usize) * c + ch];
                                cnt += 1.0;
                            }
                        }
                    }
                    data[(y * w + x) * c + ch] = acc / cnt;
                }
            }
        }
    }
}

impl Dataset for SynthImages {
    fn name(&self) -> &'static str {
        self.name
    }
    fn len(&self) -> usize {
        self.len
    }
    fn x_f32_len(&self) -> usize {
        self.dims
    }
    fn x_i32_len(&self) -> usize {
        0
    }
    fn y_len(&self) -> usize {
        1
    }

    fn sample(&self, idx: usize, x_f32: &mut [f32], _x_i32: &mut [i32], y: &mut [i32]) {
        let mut rng = Rng::new(self.seed ^ (idx as u64).wrapping_mul(0x2545F4914F6CDD1D));
        let class = (idx % self.classes) as i32; // balanced classes
        let mean = &self.means[class as usize * self.dims..(class as usize + 1) * self.dims];
        for ((o, &m), &s) in x_f32.iter_mut().zip(mean).zip(&self.dim_scales) {
            *o = s * (m + rng.normal_f32(0.0, self.noise));
        }
        if let Some(q) = &self.mix {
            // x <- Q x (orthogonal mixing)
            let d = self.dims;
            let mut out = vec![0.0f32; d];
            for (i, o) in out.iter_mut().enumerate() {
                let row = &q[i * d..(i + 1) * d];
                *o = row.iter().zip(x_f32.iter()).map(|(a, b)| a * b).sum();
            }
            x_f32.copy_from_slice(&out);
        }
        y[0] = class;
    }
}

// ---------------------------------------------------------------------------
// Synthetic segmentation
// ---------------------------------------------------------------------------

pub struct SynthSeg {
    len: usize,
    hw: usize,
    classes: usize,
    /// planted pixel-color -> class projection (classes x 3)
    proj: Vec<f32>,
    seed: u64,
}

impl SynthSeg {
    pub fn new(len: usize, seed: u64) -> Self {
        let classes = 8;
        let mut rng = Rng::new(seed ^ 0x5E6);
        let mut proj = vec![0.0f32; classes * 3];
        rng.fill_normal(&mut proj, 0.0, 1.0);
        SynthSeg { len, hw: 16, classes, proj, seed }
    }

    pub fn classes(&self) -> usize {
        self.classes
    }
}

impl Dataset for SynthSeg {
    fn name(&self) -> &'static str {
        "synth-seg"
    }
    fn len(&self) -> usize {
        self.len
    }
    fn x_f32_len(&self) -> usize {
        self.hw * self.hw * 3
    }
    fn x_i32_len(&self) -> usize {
        0
    }
    fn y_len(&self) -> usize {
        self.hw * self.hw
    }

    fn sample(&self, idx: usize, x_f32: &mut [f32], _x_i32: &mut [i32], y: &mut [i32]) {
        let mut rng = Rng::new(self.seed ^ (idx as u64).wrapping_mul(0x9E3779B97F4A7C15));
        // smooth random image: low-frequency sin blobs + noise
        let (fx, fy, ph) = (
            rng.uniform_in(0.2, 0.8),
            rng.uniform_in(0.2, 0.8),
            rng.uniform_in(0.0, 6.28),
        );
        for py in 0..self.hw {
            for px in 0..self.hw {
                let base = ((px as f32 * fx + py as f32 * fy) * 0.7 + ph).sin();
                let p = (py * self.hw + px) * 3;
                for ch in 0..3 {
                    x_f32[p + ch] = base * (1.0 + ch as f32 * 0.5)
                        + rng.normal_f32(0.0, 0.25);
                }
                // label = argmax_c proj_c . color  (pointwise-learnable)
                let mut best = 0usize;
                let mut best_v = f32::NEG_INFINITY;
                for c in 0..self.classes {
                    let v = (0..3)
                        .map(|ch| self.proj[c * 3 + ch] * x_f32[p + ch])
                        .sum::<f32>();
                    if v > best_v {
                        best_v = v;
                        best = c;
                    }
                }
                y[py * self.hw + px] = best as i32;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Markov token stream (transformer LM)
// ---------------------------------------------------------------------------

pub struct MarkovTokens {
    len: usize,
    vocab: usize,
    seq: usize,
    /// per token: 4 likely successors
    successors: Vec<[u32; 4]>,
    seed: u64,
}

impl MarkovTokens {
    pub fn new(len: usize, seed: u64) -> Self {
        let vocab = 512;
        let mut rng = Rng::new(seed ^ 0x70CE75);
        let successors = (0..vocab)
            .map(|_| {
                [
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                ]
            })
            .collect();
        MarkovTokens { len, vocab, seq: 64, successors, seed }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }
}

impl Dataset for MarkovTokens {
    fn name(&self) -> &'static str {
        "markov-lm"
    }
    fn len(&self) -> usize {
        self.len
    }
    fn x_f32_len(&self) -> usize {
        0
    }
    fn x_i32_len(&self) -> usize {
        self.seq
    }
    fn y_len(&self) -> usize {
        self.seq
    }

    fn sample(&self, idx: usize, _x_f32: &mut [f32], x_i32: &mut [i32], y: &mut [i32]) {
        let mut rng = Rng::new(self.seed ^ (idx as u64).wrapping_mul(0xD1B54A32D192ED03));
        let mut tok = rng.below(self.vocab as u64) as u32;
        for t in 0..self.seq {
            x_i32[t] = tok as i32;
            // 90%: one of the 4 planted successors; 10%: uniform noise
            let next = if rng.uniform() < 0.9 {
                self.successors[tok as usize][rng.below(4) as usize]
            } else {
                rng.below(self.vocab as u64) as u32
            };
            y[t] = next as i32;
            tok = next;
        }
    }
}

/// Build the dataset matching a model name (shapes match the manifest).
pub fn for_model(model: &str, len: usize, seed: u64) -> Result<Box<dyn Dataset>, String> {
    match model {
        "mlp" => Ok(Box::new(SynthImages::new_mlp(len, seed))),
        "cnn" => Ok(Box::new(SynthImages::new_cnn(len, seed))),
        "segnet" => Ok(Box::new(SynthSeg::new(len, seed))),
        "transformer" => Ok(Box::new(MarkovTokens::new(len, seed))),
        other => Err(format!("no dataset for model {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_are_deterministic() {
        let d = SynthImages::new_mlp(100, 7);
        let b1 = d.batch(&[3, 14, 15]);
        let b2 = d.batch(&[3, 14, 15]);
        assert_eq!(b1.x_f32, b2.x_f32);
        assert_eq!(b1.y, b2.y);
    }

    #[test]
    fn classes_are_balanced_and_separable() {
        let d = SynthImages::new_mlp(1000, 1);
        let idx: Vec<usize> = (0..200).collect();
        let b = d.batch(&idx);
        // balanced
        let mut counts = [0usize; 10];
        for &y in &b.y {
            counts[y as usize] += 1;
        }
        for c in counts {
            assert_eq!(c, 20);
        }
        // nearest-class-mean classifier should beat chance easily
        let dims = d.x_f32_len();
        let q = d.mix.as_ref().unwrap();
        let mut correct = 0;
        for k in 0..200 {
            let mixed = &b.x_f32[k * dims..(k + 1) * dims];
            // undo the orthogonal mixing: x = Q^T mixed
            let x: Vec<f32> = (0..dims)
                .map(|j| (0..dims).map(|i| q[i * dims + j] * mixed[i]).sum())
                .collect();
            let x = &x[..];
            let mut best = 0;
            let mut best_d = f32::INFINITY;
            for c in 0..10 {
                let m = &d.means[c * dims..(c + 1) * dims];
                // whitened nearest-mean (undo the planted dim scaling)
                let dist: f32 = x
                    .iter()
                    .zip(m)
                    .zip(&d.dim_scales)
                    .map(|((a, b), s)| {
                        let w = a / s - b;
                        w * w
                    })
                    .sum();
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            if best as i32 == b.y[k] {
                correct += 1;
            }
        }
        assert!(correct > 150, "separability too low: {correct}/200");
    }

    #[test]
    fn seg_labels_in_range_and_learnable_rule() {
        let d = SynthSeg::new(100, 2);
        let b = d.batch(&[0, 1, 2]);
        assert_eq!(b.y.len(), 3 * 256);
        for &y in &b.y {
            assert!((0..8).contains(&y));
        }
        // multiple classes present
        let distinct: std::collections::BTreeSet<i32> = b.y.iter().cloned().collect();
        assert!(distinct.len() >= 3, "degenerate segmentation labels");
    }

    #[test]
    fn markov_tokens_shift_property() {
        let d = MarkovTokens::new(10, 3);
        let b = d.batch(&[5]);
        // y[t] == x[t+1] by construction
        for t in 0..63 {
            assert_eq!(b.y[t], b.x_i32[t + 1]);
        }
        for &t in &b.x_i32 {
            assert!((0..512).contains(&t));
        }
    }

    #[test]
    fn markov_transitions_are_predictable() {
        let d = MarkovTokens::new(1000, 4);
        // empirical: >70% of steps use a planted successor
        let idx: Vec<usize> = (0..50).collect();
        let b = d.batch(&idx);
        let mut planted = 0;
        let mut total = 0;
        for k in 0..50 {
            for t in 0..64 {
                let cur = b.x_i32[k * 64 + t] as usize;
                let nxt = b.y[k * 64 + t] as u32;
                total += 1;
                if d.successors[cur].contains(&nxt) {
                    planted += 1;
                }
            }
        }
        assert!(planted as f64 / total as f64 > 0.7);
    }

    #[test]
    fn sharder_shards_are_disjoint_equal_and_cover() {
        let s = Sharder { dataset_len: 100, workers: 4, seed: 1 };
        let shards = s.epoch_shards(0);
        assert_eq!(shards.len(), 4);
        let mut all: Vec<usize> = shards.iter().flatten().cloned().collect();
        assert_eq!(all.len(), 100);
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 100);
        for sh in &shards {
            assert_eq!(sh.len(), 25);
        }
        // different epochs shuffle differently
        let shards1 = s.epoch_shards(1);
        assert_ne!(shards[0], shards1[0]);
        // same epoch is reproducible
        assert_eq!(shards1, s.epoch_shards(1));
    }

    #[test]
    fn for_model_builds_matching_shapes() {
        let m = for_model("mlp", 10, 0).unwrap();
        assert_eq!(m.x_f32_len(), 128);
        let c = for_model("cnn", 10, 0).unwrap();
        assert_eq!(c.x_f32_len(), 32 * 32 * 3);
        let s = for_model("segnet", 10, 0).unwrap();
        assert_eq!((s.x_f32_len(), s.y_len()), (768, 256));
        let t = for_model("transformer", 10, 0).unwrap();
        assert_eq!((t.x_i32_len(), t.y_len()), (64, 64));
        assert!(for_model("nope", 10, 0).is_err());
    }
}
