//! Minimal JSON parser + writer (substrate — offline build has no serde).
//!
//! Parses the artifact manifest written by `python/compile/aot.py` and
//! serialises metrics/results. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (not produced by our tooling).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path accessor: `man.path(&["models", "mlp", "batch"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- writer ---------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        let pad = |out: &mut String, d: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..d {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    v.write(out, depth + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, depth + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let s = std::str::from_utf8(rest).map_err(|_| "invalid utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] got {:?}", other.map(|b| b as char))),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} got {:?}", other.map(|b| b as char))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "c"}, null], "d": {"e": false}}"#).unwrap();
        assert_eq!(j.path(&["d", "e"]).unwrap(), &Json::Bool(false));
        assert_eq!(j.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("a").unwrap().idx(1).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"shapes": [[128, 256], []], "eps": 1e-06, "name": "L\"x\"", "ok": true}"#;
        let j = Json::parse(src).unwrap();
        let re = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, re);
        let re2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, re2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let man = Json::parse(&text).expect("manifest must parse");
            assert!(man.get("artifacts").is_some());
            assert_eq!(man.path(&["version"]).unwrap().as_f64(), Some(1.0));
        }
    }
}
