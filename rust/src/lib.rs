//! # Jorge — approximate preconditioning for GPU-efficient second-order optimization
//!
//! Full-stack reproduction of Singh, Sating & Bhatele (2023). Three layers:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the inverse-free
//!   Jorge preconditioner update as tiled GEMMs (build time only).
//! * **L2** — JAX models + optimizers (`python/compile/`): fused train
//!   steps AOT-lowered to HLO-text artifacts.
//! * **L3** — this crate: the training coordinator. Executes steps
//!   through a pluggable [`runtime::ExecBackend`] — the pure-Rust
//!   [`runtime::NativeBackend`] (native models in [`nn`] + optimizer
//!   mirrors in [`optim`], no artifacts needed) or the PJRT
//!   [`runtime::Engine`] behind the `pjrt` feature — schedules
//!   preconditioner updates, drives data-parallel workers with simulated
//!   collectives (`coordinator`, `collectives`), and regenerates every
//!   table/figure of the paper's evaluation (`benches/`, `perfmodel`).
//!
//! Native mirrors of all four optimizers live in [`optim`] and are
//! cross-validated against the HLO artifacts in the integration tests
//! when the `pjrt` feature and artifacts are available.

pub mod benchrun;
pub mod benchx;
pub mod checkers;
pub mod cli;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod jsonio;
pub mod metricsio;
pub mod models;
pub mod nn;
pub mod optim;
pub mod perfmodel;
pub mod rngx;
pub mod runtime;
pub mod tensor;
pub mod trace;
