//! `jorge` — leader entrypoint / CLI for the training coordinator.
//!
//! Subcommands:
//!   train          run a training job (config file + flag overrides)
//!   eval           evaluate a checkpoint
//!   bench-iter     per-iteration optimizer timing on paper inventories
//!   perf-model     print projected A100 iteration times (Table 1 scale)
//!   memory-report  optimizer state accounting (App. A.6)
//!   inspect        list artifacts in the manifest
//!   bench-diff     compare two BENCH_*.json files (CI perf drift check)

use anyhow::{anyhow, Result};
use jorge::benchx::Table;
use jorge::cli::{flag, switch, Args, FlagSpec};
use jorge::collectives::CommCostModel;
use jorge::config::{ShardPolicy, Toml, TrainConfig};
use jorge::coordinator::Trainer;
use jorge::jsonio::Json;
use jorge::models;
use jorge::optim::memory::{ratio_vs_adam, state_bytes, OptKind};
use jorge::perfmodel::{
    project_dist_shampoo_iteration, project_iteration, project_sharded_iteration,
    project_sharded_iteration_overlapped, GpuModel,
};
use jorge::runtime::backend_for;
use std::collections::BTreeMap;

fn flag_spec() -> Vec<FlagSpec> {
    vec![
        flag("config", "path to a TOML run config"),
        flag("model", "mlp | cnn | segnet | transformer"),
        flag("optimizer", "sgd | adamw | shampoo | jorge | shampoo_sharded | jorge_sharded"),
        flag("shard-policy", "flops | round_robin (owner assignment, sharded optimizers)"),
        flag("epochs", "training epochs"),
        flag("steps-per-epoch", "steps per epoch"),
        flag("lr", "base learning rate"),
        flag("weight-decay", "weight decay"),
        flag("schedule", "constant | step | cosine | poly"),
        flag("precond-every", "preconditioner update interval (steps)"),
        flag("workers", "simulated data-parallel workers"),
        flag("seed", "random seed"),
        flag("target-metric", "stop when validation metric reaches this"),
        flag("dataset-size", "synthetic dataset size"),
        flag("artifacts", "artifacts directory (default: artifacts)"),
        flag("backend", "execution backend: auto | native | pjrt"),
        flag("out", "output directory for CSV metrics"),
        flag("checkpoint", "checkpoint path to save (train) / load (eval)"),
        flag("checkpoint-every", "write a crash-safe checkpoint every N steps (0 = off)"),
        flag("checkpoint-dir", "directory for cadence checkpoints / auto-resume"),
        flag("resume", "\"auto\" (newest valid checkpoint) or an explicit path"),
        flag("faults", "fault-injection plan, e.g. \"drop@3:1:precond;delay@5:0:x4;rejoin@8:1\""),
        flag("fault-seed", "seed for deterministic fault corruption"),
        flag("max-steps", "hard cap on optimizer steps"),
        flag("trace", "write per-step phase-trace JSONL to this path"),
        flag("metrics-out", "write run-summary metrics JSON (bench-diff compatible)"),
        flag("tolerance", "bench-diff: relative drift threshold (default 0.15)"),
        switch("native", "apply optimizer via native mirrors (workers > 1)"),
        switch(
            "precond-overlap",
            "defer the sharded preconditioner all-gather to the next step (one refresh stale)",
        ),
        switch("strict", "bench-diff: exit nonzero on drift instead of warning"),
        switch("help", "print help"),
    ]
}

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("train", "run a training job"),
    ("eval", "evaluate a checkpoint on held-out data"),
    ("bench-iter", "measured per-iteration optimizer cost (native mirrors)"),
    ("perf-model", "projected A100 iteration times (Table 1 scale)"),
    ("memory-report", "optimizer state accounting (App. A.6)"),
    ("inspect", "list artifacts in the manifest"),
    ("bench-diff", "compare two BENCH_*.json files (warn-only perf drift)"),
];

fn apply_overrides(cfg: &mut TrainConfig, args: &Args) -> Result<()> {
    if let Some(v) = args.get("model") {
        cfg.model = v.into();
    }
    if let Some(v) = args.get("optimizer") {
        cfg.optimizer = v.parse().map_err(|e: String| anyhow!(e))?;
    }
    if let Some(v) = args.get("shard-policy") {
        cfg.shard_policy = ShardPolicy::parse(v).map_err(|e| anyhow!(e))?;
    }
    if let Some(v) = args.get_usize("epochs").map_err(|e| anyhow!(e))? {
        cfg.epochs = v;
    }
    if let Some(v) = args.get_usize("steps-per-epoch").map_err(|e| anyhow!(e))? {
        cfg.steps_per_epoch = v;
    }
    if let Some(v) = args.get_f64("lr").map_err(|e| anyhow!(e))? {
        cfg.lr = v;
    }
    if let Some(v) = args.get_f64("weight-decay").map_err(|e| anyhow!(e))? {
        cfg.weight_decay = v;
    }
    if let Some(v) = args.get("schedule") {
        cfg.schedule = jorge::config::ScheduleKind::parse(v).map_err(|e| anyhow!(e))?;
    }
    if let Some(v) = args.get_usize("precond-every").map_err(|e| anyhow!(e))? {
        cfg.precond_every = v;
    }
    if let Some(v) = args.get_usize("workers").map_err(|e| anyhow!(e))? {
        cfg.workers = v;
    }
    if let Some(v) = args.get_usize("seed").map_err(|e| anyhow!(e))? {
        cfg.seed = v as u64;
    }
    if let Some(v) = args.get_f64("target-metric").map_err(|e| anyhow!(e))? {
        cfg.target_metric = v;
    }
    if let Some(v) = args.get_usize("dataset-size").map_err(|e| anyhow!(e))? {
        cfg.dataset_size = v;
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = v.into();
    }
    if let Some(v) = args.get("backend") {
        cfg.backend = v.into();
    }
    if let Some(v) = args.get("out") {
        cfg.out_dir = v.into();
    }
    if let Some(v) = args.get_usize("max-steps").map_err(|e| anyhow!(e))? {
        cfg.max_steps = v;
    }
    if let Some(v) = args.get("faults") {
        cfg.faults = v.into();
    }
    if let Some(v) = args.get_usize("fault-seed").map_err(|e| anyhow!(e))? {
        cfg.fault_seed = v as u64;
    }
    if let Some(v) = args.get_usize("checkpoint-every").map_err(|e| anyhow!(e))? {
        cfg.checkpoint_every = v;
    }
    if let Some(v) = args.get("checkpoint-dir") {
        cfg.checkpoint_dir = v.into();
    }
    if let Some(v) = args.get("resume") {
        cfg.resume = v.into();
    }
    if let Some(v) = args.get("trace") {
        cfg.trace_path = v.into();
    }
    if let Some(v) = args.get("metrics-out") {
        cfg.metrics_out = v.into();
    }
    if args.has("native") {
        cfg.native = true;
    }
    if args.has("precond-overlap") {
        cfg.precond_overlap = true;
    }
    cfg.validate().map_err(|e| anyhow!(e))
}

fn load_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            TrainConfig::from_toml(&Toml::parse(&text).map_err(|e| anyhow!(e))?)
                .map_err(|e| anyhow!(e))?
        }
        None => TrainConfig::default(),
    };
    apply_overrides(&mut cfg, args)?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let engine = backend_for(&cfg.artifacts_dir, &cfg.backend)?;
    eprintln!(
        "jorge train: model={} opt={} workers={} precond_every={} schedule={} (backend: {})",
        cfg.model,
        cfg.optimizer,
        cfg.workers,
        cfg.precond_every,
        cfg.schedule.name(),
        engine.platform()
    );
    let out_dir = cfg.out_dir.clone();
    let metrics_out = cfg.metrics_out.clone();
    let tag = format!("{}_{}_s{}", cfg.model, cfg.optimizer, cfg.seed);
    let mut trainer = Trainer::new(cfg, engine)?;
    let result = trainer.run()?;
    std::fs::create_dir_all(&out_dir)?;
    let csv = format!("{out_dir}/{tag}.csv");
    result.write_csv(&csv)?;
    if let Some(path) = args.get("checkpoint") {
        trainer.save_checkpoint(path)?;
        eprintln!("checkpoint saved to {path}");
    }
    println!(
        "done: best_val={:.4} final_val={:.4} mean_iter={:.4}s total={:.1}s epochs_to_target={:?} (csv: {csv})",
        result.best_val_metric,
        result.final_val_metric,
        result.mean_iter_s,
        result.total_time_s,
        result.epochs_to_target,
    );
    if let Some(sh) = &result.shard {
        let owners: Vec<String> = sh
            .owned_layers
            .iter()
            .enumerate()
            .map(|(w, ls)| format!("w{w}:{ls:?}"))
            .collect();
        println!(
            "shard: workers={} owners=[{}] refreshes={:?} allgathers={} floats={} modeled_comm={:.3}ms stale_fallbacks={} reassignments={} rejoins={} resync_bytes={} overlap_exchanges={} stale_applies={}",
            sh.workers,
            owners.join(" "),
            sh.refresh_events,
            sh.allgather_calls,
            sh.allgather_floats,
            sh.modeled_comm_s * 1e3,
            sh.stale_fallback_layers,
            sh.reassignments,
            sh.rejoin_events,
            sh.resync_bytes,
            sh.overlap_exchanges,
            sh.stale_applies,
        );
    }
    if result.guard.total() > 0 {
        println!("guardrails: {}", result.guard);
    }
    if let Some(f) = &result.faults {
        println!(
            "faults: events={} retries={} modeled_backoff={:.3}s dropped={:?} survivors={} rejoins={} resync_bytes={} membership_epochs={}",
            f.events.len(),
            f.retries,
            f.modeled_backoff_s,
            f.dropped,
            f.survivors,
            f.rejoins,
            f.resync_bytes,
            f.membership_epochs,
        );
        for ev in &f.events {
            println!("fault-event: {ev}");
        }
    }
    if let Some(report) = &result.metrics {
        println!("trace: {report}");
        if !metrics_out.is_empty() {
            let envelope = jorge::benchrun::bench_envelope("train_metrics", report.to_json());
            if let Some(parent) = std::path::Path::new(&metrics_out).parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(&metrics_out, envelope.to_string_pretty())?;
            eprintln!("metrics written to {metrics_out}");
        }
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let engine = backend_for(&cfg.artifacts_dir, &cfg.backend)?;
    let mut trainer = Trainer::new(cfg, engine)?;
    if let Some(path) = args.get("checkpoint") {
        trainer.load_checkpoint(path)?;
    }
    let (loss, metric) = trainer.evaluate()?;
    println!("eval: loss={loss:.4} metric={metric:.4}");
    Ok(())
}

fn cmd_bench_iter(_args: &Args) -> Result<()> {
    use jorge::optim::{build, Hyper, StepCtx};
    use jorge::rngx::Rng;
    use jorge::tensor::Matrix;

    let mut table = Table::new(
        "Measured optimizer step time (native mirrors, this host)",
        &["network", "optimizer", "precond_every", "ms/iter"],
    );
    for net_name in ["resnet18", "resnet50"] {
        let net = models::by_name(net_name).unwrap().blocked(256);
        let shapes: Vec<(usize, usize)> = net.layers.iter().map(|l| (l.m, l.n)).collect();
        let mut rng = Rng::new(0);
        let grads: Vec<Matrix> = shapes
            .iter()
            .map(|&(m, n)| Matrix::randn(m, n, 0.01, &mut rng))
            .collect();
        for opt_name in ["sgd", "adamw", "jorge", "shampoo"] {
            let every = 50usize;
            let mut params: Vec<Matrix> = shapes
                .iter()
                .map(|&(m, n)| Matrix::randn(m, n, 0.1, &mut rng))
                .collect();
            let mut opt = build(opt_name.parse().unwrap(), &shapes, Hyper::default());
            let mut step_i = 0usize;
            let r = jorge::benchx::bench_n(opt_name, 3, || {
                let ctx = StepCtx {
                    lr: 0.1,
                    weight_decay: 1e-4,
                    update_precond: step_i % every == 0,
                };
                opt.step(&mut params, &grads, ctx);
                step_i += 1;
            });
            table.row(&[
                net_name.to_string(),
                opt_name.to_string(),
                every.to_string(),
                format!("{:.2}", r.mean_s * 1e3),
            ]);
        }
    }
    table.print();
    Ok(())
}

fn cmd_perf_model(_args: &Args) -> Result<()> {
    let gpu = GpuModel::a100();
    let comm = CommCostModel::nvlink_a100();
    let mut table = Table::new(
        "Projected A100 per-iteration time (paper Table 1 setting)",
        &["network", "gpus", "optimizer", "s/iter", "vs sgd"],
    );
    for (net_name, anchor, gpus) in [("resnet50", 0.085f64, 16usize), ("deeplabv3", 0.32, 4)] {
        let net = models::by_name(net_name).unwrap().blocked(1024);
        let sgd = project_iteration(&gpu, &comm, &net, OptKind::Sgd, 50, anchor, gpus).total();
        for opt in [OptKind::Sgd, OptKind::AdamW, OptKind::Jorge, OptKind::Shampoo] {
            let t = project_iteration(&gpu, &comm, &net, opt, 50, anchor, gpus).total();
            table.row(&[
                net_name.into(),
                gpus.to_string(),
                opt.name().into(),
                format!("{t:.3}"),
                format!("{:.2}x", t / sgd),
            ]);
        }
        let dist = project_dist_shampoo_iteration(&gpu, &comm, &net, 50, anchor, gpus).total();
        table.row(&[
            net_name.into(),
            gpus.to_string(),
            "dist-shampoo".into(),
            format!("{dist:.3}"),
            format!("{:.2}x", dist / sgd),
        ]);
        for opt in [OptKind::Shampoo, OptKind::Jorge] {
            let t = project_sharded_iteration(&gpu, &comm, &net, opt, 50, anchor, gpus).total();
            table.row(&[
                net_name.into(),
                gpus.to_string(),
                format!("{}_sharded", opt.name()),
                format!("{t:.3}"),
                format!("{:.2}x", t / sgd),
            ]);
            let o = project_sharded_iteration_overlapped(&gpu, &comm, &net, opt, 50, anchor, gpus)
                .total();
            table.row(&[
                net_name.into(),
                gpus.to_string(),
                format!("{}_sharded+overlap", opt.name()),
                format!("{o:.3}"),
                format!("{:.2}x", o / sgd),
            ]);
        }
    }
    table.print();
    Ok(())
}

fn cmd_memory_report(_args: &Args) -> Result<()> {
    let mut table = Table::new(
        "Optimizer state memory (App. A.6)",
        &["network", "optimizer", "state MB", "vs adam"],
    );
    for net_name in ["resnet18", "resnet50", "deeplabv3", "maskrcnn"] {
        let net = models::by_name(net_name).unwrap().blocked(1024);
        for (opt, grafting) in [
            (OptKind::Sgd, false),
            (OptKind::AdamW, false),
            (OptKind::Jorge, true),
            (OptKind::Shampoo, true),
        ] {
            table.row(&[
                net_name.into(),
                opt.name().into(),
                format!("{:.1}", state_bytes(&net, opt, grafting) as f64 / 1e6),
                format!("{:.2}x", ratio_vs_adam(&net, opt, grafting)),
            ]);
        }
    }
    table.print();
    Ok(())
}

/// Collect every numeric leaf as (path, value). Array elements are keyed
/// by their `"name"` field when present (the `json_row` convention), so
/// row reordering between runs doesn't produce false drift.
fn flatten_nums(j: &Json, prefix: &str, out: &mut Vec<(String, f64)>) {
    match j {
        Json::Num(n) => out.push((prefix.to_string(), *n)),
        Json::Arr(a) => {
            for (i, v) in a.iter().enumerate() {
                let key = v
                    .get("name")
                    .and_then(Json::as_str)
                    .map(String::from)
                    .unwrap_or_else(|| i.to_string());
                flatten_nums(v, &format!("{prefix}/{key}"), out);
            }
        }
        Json::Obj(m) => {
            for (k, v) in m {
                flatten_nums(v, &format!("{prefix}/{k}"), out);
            }
        }
        _ => {}
    }
}

/// Diff two `BENCH_*.json` files metric-by-metric. Perf on shared CI
/// runners is noisy and not every metric improves downward, so this is
/// advisory: drift beyond the tolerance prints GitHub `::warning::`
/// annotations and the command still exits 0 (unless `--strict`).
fn cmd_bench_diff(args: &Args) -> Result<()> {
    let (base_path, cur_path) = match args.positional.as_slice() {
        [b, c] => (b, c),
        _ => return Err(anyhow!("usage: jorge bench-diff <baseline.json> <current.json>")),
    };
    let tol = args.get_f64("tolerance").map_err(|e| anyhow!(e))?.unwrap_or(0.15);
    let base = Json::parse(&std::fs::read_to_string(base_path)?).map_err(|e| anyhow!(e))?;
    let cur = Json::parse(&std::fs::read_to_string(cur_path)?).map_err(|e| anyhow!(e))?;
    let mut base_leaves = Vec::new();
    let mut cur_leaves = Vec::new();
    flatten_nums(&base, "", &mut base_leaves);
    flatten_nums(&cur, "", &mut cur_leaves);
    let baseline: BTreeMap<String, f64> = base_leaves.into_iter().collect();

    let mut compared = 0usize;
    let mut drifted = 0usize;
    for (key, now) in &cur_leaves {
        let Some(&then) = baseline.get(key) else { continue };
        compared += 1;
        if then.abs() < 1e-12 {
            continue;
        }
        let rel = (now - then) / then.abs();
        if rel.abs() > tol {
            drifted += 1;
            println!(
                "::warning::bench drift {key}: {then:.6} -> {now:.6} ({:+.1}%)",
                rel * 100.0
            );
        }
    }
    println!(
        "bench-diff: {compared} comparable metrics, {drifted} drifted beyond ±{:.0}% \
         ({base_path} vs {cur_path})",
        tol * 100.0
    );
    if drifted > 0 && args.has("strict") {
        return Err(anyhow!("{drifted} metrics drifted beyond tolerance (--strict)"));
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let choice = args.get_or("backend", "auto");
    let engine = backend_for(&dir, &choice)?;
    let mut table = Table::new(
        &format!("Artifacts in {dir} (backend: {})", engine.platform()),
        &["name", "kind", "model", "inputs", "outputs"],
    );
    for (name, art) in &engine.manifest().artifacts {
        table.row(&[
            name.clone(),
            art.kind.clone(),
            art.model.clone().unwrap_or_default(),
            art.inputs.len().to_string(),
            art.outputs.len().to_string(),
        ]);
    }
    table.print();
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let spec = flag_spec();
    let args = match Args::parse(&argv, &spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.has("help") || args.subcommand.is_empty() {
        print!("{}", jorge::cli::render_help("jorge", SUBCOMMANDS, &spec));
        return;
    }
    let result = match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "bench-iter" => cmd_bench_iter(&args),
        "perf-model" => cmd_perf_model(&args),
        "memory-report" => cmd_memory_report(&args),
        "inspect" => cmd_inspect(&args),
        "bench-diff" => cmd_bench_diff(&args),
        other => Err(anyhow!("unknown subcommand {other:?} (try --help)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
