//! Metrics logging: CSV series + JSONL event streams + summary stats
//! (substrate — replaces the metrics half of a criterion dependency).

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Append-only CSV writer with a fixed header.
pub struct CsvWriter {
    w: BufWriter<File>,
    columns: usize,
    pub path: PathBuf,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        let f = File::create(&path)?;
        let mut w = BufWriter::new(f);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, columns: header.len(), path: path.as_ref().to_path_buf() })
    }

    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.columns, "csv row width mismatch");
        let line: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.w, "{}", line.join(","))
    }

    pub fn row_mixed(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.columns, "csv row width mismatch");
        writeln!(self.w, "{}", values.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

/// Wall-clock stopwatch with split support.
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn total(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous split.
    pub fn split(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }
}

/// Online summary statistics (Welford) + percentile snapshot.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let d = x - self.mean;
        self.mean += d / n;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sum of all samples.
    pub fn total(&self) -> f64 {
        self.mean * self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        (self.m2 / (self.samples.len() as f64 - 1.0)).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// JSONL event logger for structured run records.
pub struct JsonlWriter {
    w: BufWriter<File>,
    pub path: PathBuf,
}

impl JsonlWriter {
    pub fn create(path: impl AsRef<Path>) -> std::io::Result<JsonlWriter> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        Ok(JsonlWriter {
            w: BufWriter::new(File::create(&path)?),
            path: path.as_ref().to_path_buf(),
        })
    }

    pub fn write(&mut self, v: &crate::jsonio::Json) -> std::io::Result<()> {
        writeln!(self.w, "{}", v.to_string_compact())
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio::Json;
    use std::collections::BTreeMap;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("jorge_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn csv_writes_rows() {
        let path = tmp("m.csv");
        {
            let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.row(&[1.0, 0.5]).unwrap();
            w.row(&[2.0, 0.25]).unwrap();
            w.flush().unwrap();
        }
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("step,loss\n"));
        fs::remove_file(path).ok();
    }

    #[test]
    #[should_panic]
    fn csv_rejects_wrong_width() {
        let path = tmp("bad.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        let _ = w.row(&[1.0]);
    }

    #[test]
    fn summary_stats() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.total() - 15.0).abs() < 1e-12);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
    }

    #[test]
    fn jsonl_roundtrip() {
        let path = tmp("ev.jsonl");
        {
            let mut w = JsonlWriter::create(&path).unwrap();
            let mut m = BTreeMap::new();
            m.insert("step".to_string(), Json::Num(3.0));
            w.write(&Json::Obj(m)).unwrap();
            w.flush().unwrap();
        }
        let text = fs::read_to_string(&path).unwrap();
        let v = Json::parse(text.trim()).unwrap();
        assert_eq!(v.get("step").unwrap().as_f64(), Some(3.0));
        fs::remove_file(path).ok();
    }

    #[test]
    fn stopwatch_monotonic() {
        let mut sw = Stopwatch::new();
        let a = sw.split();
        let b = sw.split();
        assert!(a >= 0.0 && b >= 0.0);
        assert!(sw.total() >= a + b - 1e-6);
    }
}
