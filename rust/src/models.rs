//! Parameter-shape inventories of the paper's networks.
//!
//! Table 1 / Table 4 / Fig 2 / App A.6 depend only on the *shapes* of the
//! preconditioned parameter matrices (every N-D tensor collapsed to 2-D,
//! §3 of the paper), not on trained weights. These inventories reproduce
//! the torchvision architectures' layer lists so the Rust benches can run
//! the optimizer math over the exact op mix of ResNet-50, ResNet-18,
//! DeepLabv3-R50 and Mask-RCNN-R50, and the perf model can project to
//! A100-scale numbers.

/// One 2-D-collapsed parameter matrix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerShape {
    pub name: String,
    pub m: usize,
    pub n: usize,
}

impl LayerShape {
    pub fn new(name: impl Into<String>, m: usize, n: usize) -> Self {
        LayerShape { name: name.into(), m, n }
    }

    pub fn params(&self) -> usize {
        self.m * self.n
    }

    /// Shampoo/Jorge precondition layers with both dims > 1.
    pub fn preconditioned(&self) -> bool {
        self.m > 1 && self.n > 1
    }
}

/// A named network = list of collapsed parameter matrices.
#[derive(Clone, Debug)]
pub struct NetworkInventory {
    pub name: String,
    pub layers: Vec<LayerShape>,
}

impl NetworkInventory {
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Split oversized preconditioner dimensions into blocks of at most
    /// `max_dim`, the standard Shampoo blocking trick (Anil et al. 2021;
    /// Shi et al. 2023 default 1024/8192): a layer (m, n) with m > max_dim
    /// becomes ceil(m/max_dim) row-chunks treated as independent layers.
    pub fn blocked(&self, max_dim: usize) -> NetworkInventory {
        let mut layers = Vec::new();
        for l in &self.layers {
            if !l.preconditioned() || (l.m <= max_dim && l.n <= max_dim) {
                layers.push(l.clone());
                continue;
            }
            let mb = l.m.div_ceil(max_dim);
            let nb = l.n.div_ceil(max_dim);
            for i in 0..mb {
                for j in 0..nb {
                    let m = (l.m - i * max_dim).min(max_dim);
                    let n = (l.n - j * max_dim).min(max_dim);
                    layers.push(LayerShape::new(format!("{}.blk{}_{}", l.name, i, j), m, n));
                }
            }
        }
        NetworkInventory { name: format!("{}(blk{})", self.name, max_dim), layers }
    }
}

fn conv(name: &str, kh: usize, kw: usize, cin: usize, cout: usize) -> LayerShape {
    LayerShape::new(name, kh * kw * cin, cout)
}

fn bias(name: &str, n: usize) -> LayerShape {
    LayerShape::new(name, n, 1)
}

/// ResNet-18 (basic blocks): torchvision layout, BN folded out (BN scale
/// and bias are 1-D — unpreconditioned).
pub fn resnet18() -> NetworkInventory {
    let mut layers = vec![conv("conv1", 7, 7, 3, 64), bias("bn1", 64)];
    // (stage, blocks, channels)
    let stages = [(1usize, 2usize, 64usize), (2, 2, 128), (3, 2, 256), (4, 2, 512)];
    let mut cin = 64;
    for (s, blocks, ch) in stages {
        for b in 0..blocks {
            let in_ch = if b == 0 { cin } else { ch };
            layers.push(conv(&format!("l{s}.b{b}.conv1"), 3, 3, in_ch, ch));
            layers.push(bias(&format!("l{s}.b{b}.bn1"), ch));
            layers.push(conv(&format!("l{s}.b{b}.conv2"), 3, 3, ch, ch));
            layers.push(bias(&format!("l{s}.b{b}.bn2"), ch));
            if b == 0 && in_ch != ch {
                layers.push(conv(&format!("l{s}.b{b}.down"), 1, 1, in_ch, ch));
            }
        }
        cin = ch;
    }
    layers.push(LayerShape::new("fc", 512, 1000));
    layers.push(bias("fc.b", 1000));
    NetworkInventory { name: "resnet18".into(), layers }
}

/// ResNet-50 (bottleneck blocks), the paper's main benchmark backbone.
pub fn resnet50() -> NetworkInventory {
    let mut layers = vec![conv("conv1", 7, 7, 3, 64), bias("bn1", 64)];
    // (stage, blocks, mid, out)
    let stages = [
        (1usize, 3usize, 64usize, 256usize),
        (2, 4, 128, 512),
        (3, 6, 256, 1024),
        (4, 3, 512, 2048),
    ];
    let mut cin = 64;
    for (s, blocks, mid, out) in stages {
        for b in 0..blocks {
            let in_ch = if b == 0 { cin } else { out };
            layers.push(conv(&format!("l{s}.b{b}.conv1"), 1, 1, in_ch, mid));
            layers.push(bias(&format!("l{s}.b{b}.bn1"), mid));
            layers.push(conv(&format!("l{s}.b{b}.conv2"), 3, 3, mid, mid));
            layers.push(bias(&format!("l{s}.b{b}.bn2"), mid));
            layers.push(conv(&format!("l{s}.b{b}.conv3"), 1, 1, mid, out));
            layers.push(bias(&format!("l{s}.b{b}.bn3"), out));
            if b == 0 {
                layers.push(conv(&format!("l{s}.b{b}.down"), 1, 1, in_ch, out));
            }
        }
        cin = out;
    }
    layers.push(LayerShape::new("fc", 2048, 1000));
    layers.push(bias("fc.b", 1000));
    NetworkInventory { name: "resnet50".into(), layers }
}

/// DeepLabv3 with ResNet-50 backbone: backbone + ASPP + classifier.
pub fn deeplabv3_r50() -> NetworkInventory {
    let mut inv = resnet50();
    inv.name = "deeplabv3_r50".into();
    // drop the imagenet fc head
    inv.layers.retain(|l| !l.name.starts_with("fc"));
    // ASPP over the 2048-channel feature map: 1x1 + three dilated 3x3 +
    // image-pool branch, all to 256 channels
    inv.layers.push(conv("aspp.c0", 1, 1, 2048, 256));
    for (i, _rate) in [12usize, 24, 36].iter().enumerate() {
        inv.layers.push(conv(&format!("aspp.c{}", i + 1), 3, 3, 2048, 256));
    }
    inv.layers.push(conv("aspp.pool", 1, 1, 2048, 256));
    inv.layers.push(conv("aspp.project", 1, 1, 5 * 256, 256));
    inv.layers.push(conv("head.conv", 3, 3, 256, 256));
    inv.layers.push(conv("head.cls", 1, 1, 256, 21));
    inv.layers.push(bias("head.cls.b", 21));
    inv
}

/// Mask-RCNN with ResNet-50-FPN backbone (torchvision maskrcnn_resnet50_fpn).
pub fn maskrcnn_r50() -> NetworkInventory {
    let mut inv = resnet50();
    inv.name = "maskrcnn_r50".into();
    inv.layers.retain(|l| !l.name.starts_with("fc"));
    // FPN: lateral 1x1 from each stage + 3x3 output convs
    for (i, ch) in [256usize, 512, 1024, 2048].iter().enumerate() {
        inv.layers.push(conv(&format!("fpn.lat{i}"), 1, 1, *ch, 256));
        inv.layers.push(conv(&format!("fpn.out{i}"), 3, 3, 256, 256));
    }
    // RPN head
    inv.layers.push(conv("rpn.conv", 3, 3, 256, 256));
    inv.layers.push(conv("rpn.cls", 1, 1, 256, 3));
    inv.layers.push(conv("rpn.bbox", 1, 1, 256, 12));
    // box head: two FC layers over 256x7x7 ROI features
    inv.layers.push(LayerShape::new("box.fc1", 256 * 7 * 7, 1024));
    inv.layers.push(bias("box.fc1.b", 1024));
    inv.layers.push(LayerShape::new("box.fc2", 1024, 1024));
    inv.layers.push(bias("box.fc2.b", 1024));
    inv.layers.push(LayerShape::new("box.cls", 1024, 91));
    inv.layers.push(LayerShape::new("box.reg", 1024, 364));
    // mask head: four 3x3 convs + deconv + predictor
    for i in 0..4 {
        inv.layers.push(conv(&format!("mask.c{i}"), 3, 3, 256, 256));
    }
    inv.layers.push(conv("mask.deconv", 2, 2, 256, 256));
    inv.layers.push(conv("mask.pred", 1, 1, 256, 91));
    inv
}

pub fn by_name(name: &str) -> Option<NetworkInventory> {
    match name {
        "resnet18" => Some(resnet18()),
        "resnet50" => Some(resnet50()),
        "deeplabv3" | "deeplabv3_r50" => Some(deeplabv3_r50()),
        "maskrcnn" | "maskrcnn_r50" => Some(maskrcnn_r50()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_param_count_close_to_torchvision() {
        // torchvision resnet50: 25.56M params; we fold BN into 1-D biases
        // (one per BN instead of weight+bias+stats) so accept 23-27M.
        let n = resnet50().param_count();
        assert!((23_000_000..27_000_000).contains(&n), "{n}");
    }

    #[test]
    fn resnet18_param_count_close_to_torchvision() {
        // torchvision resnet18: 11.69M
        let n = resnet18().param_count();
        assert!((10_500_000..12_500_000).contains(&n), "{n}");
    }

    #[test]
    fn deeplab_has_aspp_and_no_fc() {
        let d = deeplabv3_r50();
        assert!(d.layers.iter().any(|l| l.name.starts_with("aspp")));
        assert!(!d.layers.iter().any(|l| l.name == "fc"));
        // ~39M params in the torchvision deeplabv3_resnet50 backbone+head
        let n = d.param_count();
        assert!((35_000_000..45_000_000).contains(&n), "{n}");
    }

    #[test]
    fn maskrcnn_has_heads() {
        let m = maskrcnn_r50();
        for prefix in ["fpn", "rpn", "box", "mask"] {
            assert!(m.layers.iter().any(|l| l.name.starts_with(prefix)), "{prefix}");
        }
        // torchvision maskrcnn_resnet50_fpn: ~44M
        let n = m.param_count();
        assert!((39_000_000..49_000_000).contains(&n), "{n}");
    }

    #[test]
    fn conv_collapse_rule() {
        let c = conv("x", 3, 3, 64, 128);
        assert_eq!((c.m, c.n), (9 * 64, 128));
        assert!(c.preconditioned());
        assert!(!bias("b", 64).preconditioned());
    }

    #[test]
    fn blocking_preserves_param_count() {
        let r = resnet50();
        let b = r.blocked(1024);
        assert_eq!(r.param_count(), b.param_count());
        for l in &b.layers {
            if l.preconditioned() {
                assert!(l.m <= 1024 && l.n <= 1024, "{:?}", l);
            }
        }
        // the 12544-row box.fc1 of maskrcnn must split
        let mb = maskrcnn_r50().blocked(1024);
        assert!(mb.layers.iter().filter(|l| l.name.starts_with("box.fc1.blk")).count() >= 13);
    }

    #[test]
    fn blocking_noop_for_small_nets() {
        // largest resnet18 dim is 9*512 = 4608, so 8192-blocking is a noop
        let r = resnet18();
        let b = r.blocked(8192);
        assert_eq!(r.layers.len(), b.layers.len());
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("resnet50").is_some());
        assert!(by_name("deeplabv3").is_some());
        assert!(by_name("nope").is_none());
    }
}
