//! Native CNN — mirror of `model.make_cnn` (the ResNet-50/ImageNet
//! stand-in): three SAME 3x3 conv + ReLU + 2x2 max-pool stages over
//! 32x32x3 inputs, then a 512 -> 64 -> 10 classifier head.

use super::ops::{
    accuracy, col2im, col_sums, im2col, maxpool2, maxpool2_bwd, relu_bwd_inplace, softmax_xent,
    Conv,
};
use super::{he, zeros, BatchRef, ModelSpec, NativeModel};
use crate::runtime::manifest::Dtype;
use crate::tensor::{matmul_bias, matmul_bias_relu, matmul_nt, matmul_tn, Matrix};
use crate::trace::{self, Phase};

pub const CNN_HW: usize = 32;
pub const CNN_CIN: usize = 3;
pub const CNN_CLASSES: usize = 10;
const CHANNELS: [usize; 3] = [8, 16, 32];
const FC_HIDDEN: usize = 64;

/// Conv stage shapes: 32x32x3 -> 16x16x8 -> 8x8x16 -> (pool) 4x4x32.
fn conv_stages() -> [Conv; 3] {
    [
        Conv { h: 32, w: 32, cin: CNN_CIN, cout: CHANNELS[0], k: 3 },
        Conv { h: 16, w: 16, cin: CHANNELS[0], cout: CHANNELS[1], k: 3 },
        Conv { h: 8, w: 8, cin: CHANNELS[1], cout: CHANNELS[2], k: 3 },
    ]
}

const FLAT: usize = 4 * 4 * CHANNELS[2];

pub struct Cnn {
    spec: ModelSpec,
}

impl Cnn {
    pub fn new() -> Cnn {
        let mut params = Vec::new();
        for (i, cv) in conv_stages().iter().enumerate() {
            params.push(he(&format!("conv{}.w", i + 1), cv.patch(), cv.cout));
            params.push(zeros(&format!("conv{}.b", i + 1), cv.cout, 1));
        }
        params.push(he("fc1.w", FLAT, FC_HIDDEN));
        params.push(zeros("fc1.b", FC_HIDDEN, 1));
        params.push(he("fc2.w", FC_HIDDEN, CNN_CLASSES));
        params.push(zeros("fc2.b", CNN_CLASSES, 1));
        let spec = ModelSpec {
            name: "cnn",
            metric: "accuracy",
            batch: 32,
            eval_batch: 128,
            x_dtype: Dtype::F32,
            x_sample: vec![CNN_HW, CNN_HW, CNN_CIN],
            y_sample: vec![],
            params,
        };
        Cnn { spec }
    }
}

impl Default for Cnn {
    fn default() -> Self {
        Cnn::new()
    }
}

/// Per-stage forward cache. `post` is the fused conv+bias+ReLU output;
/// it doubles as the ReLU mask in the backward pass, so the
/// pre-activation is never materialised.
struct StageCache {
    col: Matrix,
    post: Matrix,
    argmax: Vec<usize>,
    in_len: usize,
}

impl NativeModel for Cnn {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn loss_grad(&self, params: &[Matrix], batch: &BatchRef) -> (Vec<Matrix>, f64, f64) {
        let b = batch.batch;
        let stages = conv_stages();

        // forward through the conv tower
        let fwd_scope = trace::scope(Phase::Forward);
        let mut act: Vec<f32> = batch.x_f32.to_vec();
        let mut caches: Vec<StageCache> = Vec::with_capacity(3);
        for (si, cv) in stages.iter().enumerate() {
            let in_len = act.len();
            let col = im2col(&act, b, cv);
            let post = matmul_bias_relu(&col, &params[2 * si], &params[2 * si + 1]);
            let (pooled, argmax) = maxpool2(&post.data, b, cv.h, cv.w, cv.cout);
            act = pooled;
            caches.push(StageCache { col, post, argmax, in_len });
        }

        // classifier head
        let hf = Matrix::from_vec(b, FLAT, act);
        let (fc1w, fc1b, fc2w, fc2b) = (&params[6], &params[7], &params[8], &params[9]);
        let af = matmul_bias_relu(&hf, fc1w, fc1b);
        let logits = matmul_bias(&af, fc2w, fc2b);

        let out = softmax_xent(&logits, batch.y);
        let acc = accuracy(&out.preds, batch.y);
        drop(fwd_scope);

        // backward through the head (transpose-free variants)
        let _bwd_scope = trace::scope(Phase::Backward);
        let dlogits = out.dlogits;
        let dfc2w = matmul_tn(&af, &dlogits);
        let dfc2b = col_sums(&dlogits);
        let mut daf = matmul_nt(&dlogits, fc2w);
        relu_bwd_inplace(&mut daf, &af);
        let dfc1w = matmul_tn(&hf, &daf);
        let dfc1b = col_sums(&daf);
        let dhf = matmul_nt(&daf, fc1w);

        // backward through the conv tower (reverse stage order)
        let mut grads: Vec<Matrix> = vec![Matrix::zeros(1, 1); 6];
        let mut dpooled: Vec<f32> = dhf.data;
        for si in (0..3).rev() {
            let cv = &stages[si];
            let cache = &caches[si];
            let dpost = maxpool2_bwd(&dpooled, &cache.argmax, cache.post.data.len());
            let mut dpre = Matrix::from_vec(b * cv.h * cv.w, cv.cout, dpost);
            relu_bwd_inplace(&mut dpre, &cache.post);
            grads[2 * si] = matmul_tn(&cache.col, &dpre);
            grads[2 * si + 1] = col_sums(&dpre);
            if si > 0 {
                let dcol = matmul_nt(&dpre, &params[2 * si]);
                dpooled = col2im(&dcol, b, cv);
                debug_assert_eq!(dpooled.len(), cache.in_len);
            }
        }

        grads.extend([dfc1w, dfc1b, dfc2w, dfc2b]);
        (grads, out.loss, acc)
    }

    fn loss_metric(&self, params: &[Matrix], batch: &BatchRef) -> (f64, f64) {
        let b = batch.batch;
        let mut act: Vec<f32> = batch.x_f32.to_vec();
        for (si, cv) in conv_stages().iter().enumerate() {
            let col = im2col(&act, b, cv);
            let post = matmul_bias_relu(&col, &params[2 * si], &params[2 * si + 1]);
            let (pooled, _) = maxpool2(&post.data, b, cv.h, cv.w, cv.cout);
            act = pooled;
        }
        let hf = Matrix::from_vec(b, FLAT, act);
        let af = matmul_bias_relu(&hf, &params[6], &params[7]);
        let logits = matmul_bias(&af, &params[8], &params[9]);
        let out = softmax_xent(&logits, batch.y);
        (out.loss, accuracy(&out.preds, batch.y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil::{grad_check, overfits_one_batch};

    #[test]
    fn spec_matches_l2_inventory() {
        let c = Cnn::new();
        let want = 27 * 8 + 8 + 72 * 16 + 16 + 144 * 32 + 32 + 512 * 64 + 64 + 64 * 10 + 10;
        assert_eq!(c.spec().param_count(), want);
        assert_eq!(c.spec().x_len(), 32 * 32 * 3);
    }

    #[test]
    fn gradients_match_finite_differences() {
        grad_check(&Cnn::new(), 2, CNN_CLASSES, 3);
    }

    #[test]
    fn overfits_a_small_batch() {
        overfits_one_batch(&Cnn::new(), 4, CNN_CLASSES, 40);
    }
}
