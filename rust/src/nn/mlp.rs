//! Native MLP — mirror of `model.make_mlp` (the Mask-RCNN "third
//! benchmark" slot): 128 -> 256 -> 128 -> 10 with ReLU and softmax
//! cross-entropy.

use super::ops::{accuracy, col_sums, relu_bwd_inplace, softmax_xent};
use super::{he, zeros, BatchRef, ModelSpec, NativeModel};
use crate::runtime::manifest::Dtype;
use crate::tensor::{matmul_bias, matmul_bias_relu, matmul_nt, matmul_tn, Matrix};
use crate::trace::{self, Phase};

pub const MLP_IN: usize = 128;
pub const MLP_H1: usize = 256;
pub const MLP_H2: usize = 128;
pub const MLP_CLASSES: usize = 10;

pub struct Mlp {
    spec: ModelSpec,
}

impl Mlp {
    pub fn new() -> Mlp {
        let spec = ModelSpec {
            name: "mlp",
            metric: "accuracy",
            batch: 64,
            eval_batch: 256,
            x_dtype: Dtype::F32,
            x_sample: vec![MLP_IN],
            y_sample: vec![],
            params: vec![
                he("w1", MLP_IN, MLP_H1),
                zeros("b1", MLP_H1, 1),
                he("w2", MLP_H1, MLP_H2),
                zeros("b2", MLP_H2, 1),
                he("w3", MLP_H2, MLP_CLASSES),
                zeros("b3", MLP_CLASSES, 1),
            ],
        };
        Mlp { spec }
    }
}

impl Default for Mlp {
    fn default() -> Self {
        Mlp::new()
    }
}

impl NativeModel for Mlp {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn loss_grad(&self, params: &[Matrix], batch: &BatchRef) -> (Vec<Matrix>, f64, f64) {
        let b = batch.batch;
        let (w1, b1, w2, b2, w3, b3) =
            (&params[0], &params[1], &params[2], &params[3], &params[4], &params[5]);
        let x = Matrix::from_vec(b, MLP_IN, batch.x_f32.to_vec());

        // forward — bias + ReLU fused into the GEMM epilogue, so only
        // the post-activations are materialised (they double as the
        // ReLU masks in the backward pass)
        let fwd_scope = trace::scope(Phase::Forward);
        let a1 = matmul_bias_relu(&x, w1, b1);
        let a2 = matmul_bias_relu(&a1, w2, b2);
        let logits = matmul_bias(&a2, w3, b3);

        let out = softmax_xent(&logits, batch.y);
        let acc = accuracy(&out.preds, batch.y);
        drop(fwd_scope);

        // backward — transpose-free GEMM variants, no `.t()` copies
        let _bwd_scope = trace::scope(Phase::Backward);
        let dlogits = out.dlogits;
        let dw3 = matmul_tn(&a2, &dlogits);
        let db3 = col_sums(&dlogits);
        let mut da2 = matmul_nt(&dlogits, w3);
        relu_bwd_inplace(&mut da2, &a2);
        let dw2 = matmul_tn(&a1, &da2);
        let db2 = col_sums(&da2);
        let mut da1 = matmul_nt(&da2, w2);
        relu_bwd_inplace(&mut da1, &a1);
        let dw1 = matmul_tn(&x, &da1);
        let db1 = col_sums(&da1);

        (vec![dw1, db1, dw2, db2, dw3, db3], out.loss, acc)
    }

    fn loss_metric(&self, params: &[Matrix], batch: &BatchRef) -> (f64, f64) {
        let b = batch.batch;
        let (w1, b1, w2, b2, w3, b3) =
            (&params[0], &params[1], &params[2], &params[3], &params[4], &params[5]);
        let x = Matrix::from_vec(b, MLP_IN, batch.x_f32.to_vec());
        let a1 = matmul_bias_relu(&x, w1, b1);
        let a2 = matmul_bias_relu(&a1, w2, b2);
        let logits = matmul_bias(&a2, w3, b3);
        let out = softmax_xent(&logits, batch.y);
        (out.loss, accuracy(&out.preds, batch.y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil::{grad_check, overfits_one_batch};

    #[test]
    fn spec_matches_l2_inventory() {
        let m = Mlp::new();
        // 128*256 + 256 + 256*128 + 128 + 128*10 + 10
        assert_eq!(m.spec().param_count(), 128 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10);
        assert_eq!(m.spec().y_len(), 1);
    }

    #[test]
    fn gradients_match_finite_differences() {
        grad_check(&Mlp::new(), 4, MLP_CLASSES, 5);
    }

    #[test]
    fn overfits_a_small_batch() {
        overfits_one_batch(&Mlp::new(), 8, MLP_CLASSES, 40);
    }
}
