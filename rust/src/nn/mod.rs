//! Native (pure-Rust) trainable models — the execution substrate behind
//! [`crate::runtime::NativeBackend`].
//!
//! Each model mirrors one of the L2/JAX workloads in
//! `python/compile/model.py` at the same simulator scale (the transformer
//! is scaled to d=128/2 layers so a CPU-only CI box trains it in
//! seconds): forward pass, analytic backward pass, loss and metric, all
//! on the `tensor` substrate. Every parameter is a 2-D matrix (conv
//! kernels collapsed to `(kh*kw*cin, cout)`, biases/gains to `(n, 1)`) —
//! the layout §3 of the paper prescribes for two-sided preconditioning,
//! and exactly what the native optimizer mirrors in [`crate::optim`]
//! consume.

pub mod cnn;
pub mod mlp;
pub mod ops;
pub mod segnet;
pub mod transformer;

pub use cnn::Cnn;
pub use mlp::Mlp;
pub use segnet::Segnet;
pub use transformer::Transformer;

use crate::runtime::manifest::{Dtype, Init};
use crate::tensor::Matrix;

/// One 2-D parameter slot with its manifest init rule.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub init: Init,
}

/// Static description of a workload: parameter inventory + batch I/O.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    pub metric: &'static str,
    pub batch: usize,
    pub eval_batch: usize,
    pub x_dtype: Dtype,
    /// Per-sample x dims (batch dim excluded), e.g. `[128]` or `[32, 32, 3]`.
    pub x_sample: Vec<usize>,
    /// Per-sample y dims; empty for a single class label.
    pub y_sample: Vec<usize>,
    pub params: Vec<ParamSpec>,
}

impl ModelSpec {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.rows * p.cols).sum()
    }

    pub fn shapes(&self) -> Vec<(usize, usize)> {
        self.params.iter().map(|p| (p.rows, p.cols)).collect()
    }

    /// Labels per sample (1 for classification, H*W for segmentation...).
    pub fn y_len(&self) -> usize {
        self.y_sample.iter().product::<usize>().max(1)
    }

    /// Floats per sample in x (0 for token inputs).
    pub fn x_len(&self) -> usize {
        self.x_sample.iter().product::<usize>().max(1)
    }
}

/// A borrowed host-side batch, dtype split like [`crate::data::Batch`].
pub struct BatchRef<'a> {
    pub batch: usize,
    pub x_f32: &'a [f32],
    pub x_i32: &'a [i32],
    pub y: &'a [i32],
}

/// A trainable native model: forward, analytic backward, loss + metric.
pub trait NativeModel: Send + Sync {
    fn spec(&self) -> &ModelSpec;

    /// Forward + backward on one batch. Returns (grads in param order,
    /// mean loss, metric).
    fn loss_grad(&self, params: &[Matrix], batch: &BatchRef) -> (Vec<Matrix>, f64, f64);

    /// Forward only: (mean loss, metric).
    fn loss_metric(&self, params: &[Matrix], batch: &BatchRef) -> (f64, f64) {
        let (_, loss, metric) = self.loss_grad(params, batch);
        (loss, metric)
    }
}

/// All model slots the native backend serves.
pub const MODEL_NAMES: &[&str] = &["mlp", "cnn", "segnet", "transformer"];

/// Build the native model for a workload slot.
pub fn for_model(name: &str) -> Result<Box<dyn NativeModel>, String> {
    match name {
        "mlp" => Ok(Box::new(Mlp::new())),
        "cnn" => Ok(Box::new(Cnn::new())),
        "segnet" => Ok(Box::new(Segnet::new())),
        "transformer" => Ok(Box::new(Transformer::default_lm())),
        other => Err(format!("no native model for {other:?}")),
    }
}

// -- spec construction helpers ----------------------------------------------

pub(crate) fn he(name: &str, rows: usize, cols: usize) -> ParamSpec {
    ParamSpec {
        name: name.to_string(),
        rows,
        cols,
        init: Init::He { fan_in: rows, scale: 1.0 },
    }
}

pub(crate) fn he_scaled(name: &str, rows: usize, cols: usize, scale: f32) -> ParamSpec {
    ParamSpec { name: name.to_string(), rows, cols, init: Init::He { fan_in: rows, scale } }
}

pub(crate) fn zeros(name: &str, rows: usize, cols: usize) -> ParamSpec {
    ParamSpec { name: name.to_string(), rows, cols, init: Init::Zeros }
}

pub(crate) fn ones(name: &str, rows: usize, cols: usize) -> ParamSpec {
    ParamSpec { name: name.to_string(), rows, cols, init: Init::Ones }
}

pub(crate) fn normal(name: &str, rows: usize, cols: usize, std: f32) -> ParamSpec {
    ParamSpec { name: name.to_string(), rows, cols, init: Init::Normal { std } }
}

// -- shared test machinery ---------------------------------------------------

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::optim::{Hyper, Optimizer, Sgd, StepCtx};
    use crate::rngx::Rng;
    use crate::runtime::manifest::{IoSpec, Role};
    use crate::runtime::HostTensor;

    /// Initialise params from the manifest init rules (same path the
    /// trainer uses).
    pub fn init_params(spec: &ModelSpec, seed: u64) -> Vec<Matrix> {
        let mut rng = Rng::new(seed);
        spec.params
            .iter()
            .map(|p| {
                let io = IoSpec {
                    name: p.name.clone(),
                    shape: vec![p.rows, p.cols],
                    dtype: Dtype::F32,
                    role: Role::Param,
                    init: Some(p.init.clone()),
                };
                let t = HostTensor::from_init(&io, &mut rng).unwrap();
                Matrix::from_vec(p.rows, p.cols, t.as_f32().unwrap().to_vec())
            })
            .collect()
    }

    /// A random learnable-ish batch: gaussian x (or uniform tokens) and
    /// uniform labels in `[0, classes)`.
    pub struct OwnedBatch {
        pub batch: usize,
        pub x_f32: Vec<f32>,
        pub x_i32: Vec<i32>,
        pub y: Vec<i32>,
    }

    impl OwnedBatch {
        pub fn view(&self) -> BatchRef<'_> {
            BatchRef { batch: self.batch, x_f32: &self.x_f32, x_i32: &self.x_i32, y: &self.y }
        }
    }

    pub fn random_batch(spec: &ModelSpec, b: usize, classes: usize, seed: u64) -> OwnedBatch {
        let mut rng = Rng::new(seed);
        let (mut x_f32, mut x_i32) = (Vec::new(), Vec::new());
        match spec.x_dtype {
            Dtype::F32 => {
                x_f32 = vec![0.0; b * spec.x_len()];
                rng.fill_normal(&mut x_f32, 0.0, 1.0);
            }
            Dtype::I32 => {
                x_i32 = (0..b * spec.x_len()).map(|_| rng.below(classes as u64) as i32).collect();
            }
        }
        let y = (0..b * spec.y_len()).map(|_| rng.below(classes as u64) as i32).collect();
        OwnedBatch { batch: b, x_f32, x_i32, y }
    }

    /// Central-difference gradient check over sampled coordinates of
    /// every parameter. Analytic-vs-numeric agreement to `rel_tol` (with
    /// a small absolute floor for f32 roundoff).
    pub fn grad_check(model: &dyn NativeModel, b: usize, classes: usize, per_param: usize) {
        let spec = model.spec().clone();
        let mut params = init_params(&spec, 11);
        let batch = random_batch(&spec, b, classes, 23);
        let (grads, loss, _) = model.loss_grad(&params, &batch.view());
        assert!(loss.is_finite(), "loss not finite");
        let mut rng = Rng::new(7);
        for pi in 0..params.len() {
            let n = params[pi].data.len();
            for _ in 0..per_param.min(n) {
                let ci = rng.below(n as u64) as usize;
                let w0 = params[pi].data[ci];
                let h = 2e-3f32 * w0.abs().max(0.5);
                params[pi].data[ci] = w0 + h;
                let (lp, _) = model.loss_metric(&params, &batch.view());
                params[pi].data[ci] = w0 - h;
                let (lm, _) = model.loss_metric(&params, &batch.view());
                params[pi].data[ci] = w0;
                let num = ((lp - lm) / (2.0 * h as f64)) as f32;
                let ana = grads[pi].data[ci];
                // loose enough to absorb f32 roundoff and the odd relu
                // kink; a wrong backward pass is off by orders of
                // magnitude, not 5%
                let tol = 5e-2 * ana.abs().max(num.abs()).max(0.1);
                assert!(
                    (num - ana).abs() <= tol,
                    "{} param {pi} ({}) coord {ci}: numeric {num} vs analytic {ana}",
                    spec.name,
                    spec.params[pi].name
                );
            }
        }
    }

    /// Repeated SGD steps on a fixed batch must reduce the loss.
    pub fn overfits_one_batch(model: &dyn NativeModel, b: usize, classes: usize, steps: usize) {
        let spec = model.spec().clone();
        let mut params = init_params(&spec, 3);
        let batch = random_batch(&spec, b, classes, 5);
        let mut opt = Sgd::new(&spec.shapes(), Hyper::default());
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for step in 0..steps {
            let (grads, loss, _) = model.loss_grad(&params, &batch.view());
            assert!(loss.is_finite(), "step {step}: loss not finite");
            if step == 0 {
                first = loss;
            }
            last = loss;
            opt.step(
                &mut params,
                &grads,
                StepCtx { lr: 0.05, weight_decay: 0.0, update_precond: true },
            );
        }
        assert!(last < 0.8 * first, "{}: no learning ({first} -> {last})", spec.name);
    }
}
