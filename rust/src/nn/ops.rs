//! Shared neural-net primitives for the native models: dense layers,
//! softmax cross-entropy, SAME-padded conv via im2col, 2x2 max-pool,
//! layer norm, GELU and row-wise (causal) softmax — each with its
//! analytic backward pass.
//!
//! Activations live in [`Matrix`] with rows = positions (`B`, `B*S` or
//! `B*H*W`) and cols = features, so a flat row-major matrix *is* the
//! NHWC buffer — conv, pool and flatten need no transposes.

use crate::tensor::Matrix;

// (bias broadcast + ReLU live in the GEMM epilogue now — see
// `tensor::gemm::{matmul_bias, matmul_bias_relu}`)

/// `d *= (pre > 0)` — mask a gradient by the activation sign. The mask
/// is identical whether `pre` is the pre-activation or the ReLU output
/// (`relu(z) > 0 ⇔ z > 0`), so callers that fuse ReLU into the GEMM
/// epilogue pass the post-activation and skip storing `z` entirely.
pub fn relu_bwd_inplace(d: &mut Matrix, pre: &Matrix) {
    assert_eq!(d.data.len(), pre.data.len());
    for (dv, pv) in d.data.iter_mut().zip(&pre.data) {
        if *pv <= 0.0 {
            *dv = 0.0;
        }
    }
}

/// Column sums as a `(cols, 1)` matrix — the bias gradient.
pub fn col_sums(d: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(d.cols, 1);
    for r in 0..d.rows {
        let row = &d.data[r * d.cols..(r + 1) * d.cols];
        for (o, v) in out.data.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

/// Mean softmax cross-entropy over rows, with gradient and predictions.
pub struct XentOut {
    pub loss: f64,
    /// d(loss)/d(logits), already divided by the row count.
    pub dlogits: Matrix,
    pub preds: Vec<i32>,
}

pub fn softmax_xent(logits: &Matrix, y: &[i32]) -> XentOut {
    let (rows, cols) = (logits.rows, logits.cols);
    assert_eq!(rows, y.len(), "one label per logit row");
    let mut dlogits = Matrix::zeros(rows, cols);
    let mut preds = vec![0i32; rows];
    let mut loss = 0.0f64;
    let inv_rows = 1.0f32 / rows as f32;
    for r in 0..rows {
        let row = &logits.data[r * cols..(r + 1) * cols];
        let mut mx = f32::NEG_INFINITY;
        let mut arg = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > mx {
                mx = v;
                arg = j;
            }
        }
        preds[r] = arg as i32;
        let mut sum = 0.0f32;
        let drow = &mut dlogits.data[r * cols..(r + 1) * cols];
        for (d, &v) in drow.iter_mut().zip(row) {
            let e = (v - mx).exp();
            *d = e;
            sum += e;
        }
        let label = y[r] as usize;
        assert!(label < cols, "label {label} out of range for {cols} classes");
        loss -= ((row[label] - mx) as f64) - (sum as f64).ln();
        let inv_sum = 1.0 / sum;
        for d in drow.iter_mut() {
            *d *= inv_sum * inv_rows;
        }
        drow[label] -= inv_rows;
    }
    XentOut { loss: loss / rows as f64, dlogits, preds }
}

pub fn accuracy(preds: &[i32], y: &[i32]) -> f64 {
    if preds.is_empty() {
        return 0.0;
    }
    let hits = preds.iter().zip(y).filter(|(p, t)| p == t).count();
    hits as f64 / preds.len() as f64
}

/// Mean IoU over classes with non-empty union (the paper's seg metric).
pub fn mean_iou(preds: &[i32], y: &[i32], classes: usize) -> f64 {
    let mut inter = vec![0usize; classes];
    let mut pcount = vec![0usize; classes];
    let mut lcount = vec![0usize; classes];
    for (&p, &l) in preds.iter().zip(y) {
        let (p, l) = (p as usize, l as usize);
        pcount[p] += 1;
        lcount[l] += 1;
        if p == l {
            inter[p] += 1;
        }
    }
    let mut iou_sum = 0.0f64;
    let mut weight = 0.0f64;
    for c in 0..classes {
        let union = pcount[c] + lcount[c] - inter[c];
        if union > 0 {
            iou_sum += inter[c] as f64 / union as f64;
            weight += 1.0;
        }
    }
    if weight > 0.0 {
        iou_sum / weight
    } else {
        0.0
    }
}

// -- convolution (SAME padding, stride 1, square kernel) ---------------------

/// Static shape of one conv layer over an NHWC input.
#[derive(Clone, Copy, Debug)]
pub struct Conv {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
}

impl Conv {
    pub fn patch(&self) -> usize {
        self.k * self.k * self.cin
    }
}

/// Unfold one NHWC sample into `h*w` patch rows (the per-batch body of
/// [`im2col`]; `x` and `out` are that sample's slices).
fn im2col_sample(x: &[f32], cv: &Conv, out: &mut [f32]) {
    let (h, w, cin, k) = (cv.h, cv.w, cv.cin, cv.k);
    let pad = k / 2;
    for oy in 0..h {
        for ox in 0..w {
            let r = oy * w + ox;
            let out_row = &mut out[r * cv.patch()..(r + 1) * cv.patch()];
            for ky in 0..k {
                let iy = oy + ky;
                if iy < pad || iy - pad >= h {
                    continue;
                }
                let iy = iy - pad;
                for kx in 0..k {
                    let ix = ox + kx;
                    if ix < pad || ix - pad >= w {
                        continue;
                    }
                    let ix = ix - pad;
                    let src = (iy * w + ix) * cin;
                    let dst = (ky * k + kx) * cin;
                    out_row[dst..dst + cin].copy_from_slice(&x[src..src + cin]);
                }
            }
        }
    }
}

/// Batches below this many output floats run inline; above it, samples
/// split across the worker pool (each sample's rows are disjoint).
const IM2COL_PAR_MIN: usize = 1 << 15;

/// Unfold NHWC input into a `(b*h*w, k*k*cin)` patch matrix whose column
/// order matches the `(kh*kw*cin, cout)` collapsed weight layout.
/// Threaded over the batch when the patch matrix is large enough.
pub fn im2col(x: &[f32], b: usize, cv: &Conv) -> Matrix {
    let (h, w, cin) = (cv.h, cv.w, cv.cin);
    assert_eq!(x.len(), b * h * w * cin, "im2col input length");
    let mut col = Matrix::zeros(b * h * w, cv.patch());
    let per_out = h * w * cv.patch();
    let per_in = h * w * cin;
    if b > 1 && col.data.len() >= IM2COL_PAR_MIN && crate::tensor::pool_size() > 1 {
        crate::tensor::parallel_chunks(&mut col.data, per_out, |bi, out| {
            im2col_sample(&x[bi * per_in..(bi + 1) * per_in], cv, out);
        });
    } else {
        for bi in 0..b {
            let out = &mut col.data[bi * per_out..(bi + 1) * per_out];
            im2col_sample(&x[bi * per_in..(bi + 1) * per_in], cv, out);
        }
    }
    col
}

/// Fold one sample's patch-row gradients back onto its NHWC input (the
/// per-batch body of [`col2im`]).
fn col2im_sample(dcol_rows: &[f32], cv: &Conv, dx: &mut [f32]) {
    let (h, w, cin, k) = (cv.h, cv.w, cv.cin, cv.k);
    let pad = k / 2;
    for oy in 0..h {
        for ox in 0..w {
            let r = oy * w + ox;
            let in_row = &dcol_rows[r * cv.patch()..(r + 1) * cv.patch()];
            for ky in 0..k {
                let iy = oy + ky;
                if iy < pad || iy - pad >= h {
                    continue;
                }
                let iy = iy - pad;
                for kx in 0..k {
                    let ix = ox + kx;
                    if ix < pad || ix - pad >= w {
                        continue;
                    }
                    let ix = ix - pad;
                    let dst = (iy * w + ix) * cin;
                    let src = (ky * k + kx) * cin;
                    for c in 0..cin {
                        dx[dst + c] += in_row[src + c];
                    }
                }
            }
        }
    }
}

/// Fold patch-matrix gradients back onto the NHWC input (adjoint of
/// [`im2col`]). Threaded over the batch: each sample's `dx` region is
/// written by exactly one task.
pub fn col2im(dcol: &Matrix, b: usize, cv: &Conv) -> Vec<f32> {
    let (h, w, cin) = (cv.h, cv.w, cv.cin);
    assert_eq!(dcol.rows, b * h * w);
    assert_eq!(dcol.cols, cv.patch());
    let mut dx = vec![0.0f32; b * h * w * cin];
    let per_out = h * w * cin;
    let per_in = h * w * cv.patch();
    if b > 1 && dcol.data.len() >= IM2COL_PAR_MIN && crate::tensor::pool_size() > 1 {
        crate::tensor::parallel_chunks(&mut dx, per_out, |bi, out| {
            col2im_sample(&dcol.data[bi * per_in..(bi + 1) * per_in], cv, out);
        });
    } else {
        for bi in 0..b {
            let out = &mut dx[bi * per_out..(bi + 1) * per_out];
            col2im_sample(&dcol.data[bi * per_in..(bi + 1) * per_in], cv, out);
        }
    }
    dx
}

// -- 2x2 max pool, stride 2 --------------------------------------------------

/// Returns the pooled NHWC buffer and, per output element, the flat
/// input index of its maximum (for the backward pass).
pub fn maxpool2(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> (Vec<f32>, Vec<usize>) {
    assert_eq!(x.len(), b * h * w * c);
    assert!(h % 2 == 0 && w % 2 == 0, "maxpool2 needs even dims");
    let (ho, wo) = (h / 2, w / 2);
    let mut out = vec![0.0f32; b * ho * wo * c];
    let mut arg = vec![0usize; b * ho * wo * c];
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_i = 0usize;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let idx = ((bi * h + 2 * oy + dy) * w + 2 * ox + dx) * c + ch;
                            if x[idx] > best {
                                best = x[idx];
                                best_i = idx;
                            }
                        }
                    }
                    let o = ((bi * ho + oy) * wo + ox) * c + ch;
                    out[o] = best;
                    arg[o] = best_i;
                }
            }
        }
    }
    (out, arg)
}

pub fn maxpool2_bwd(dout: &[f32], argmax: &[usize], in_len: usize) -> Vec<f32> {
    assert_eq!(dout.len(), argmax.len());
    let mut dx = vec![0.0f32; in_len];
    for (&d, &i) in dout.iter().zip(argmax) {
        dx[i] += d;
    }
    dx
}

// -- layer norm (per row, learned gain, no bias) -----------------------------

const LN_EPS: f32 = 1e-5;

pub struct LnCache {
    pub y: Matrix,
    pub xhat: Matrix,
    pub istd: Vec<f32>,
}

pub fn layernorm_fwd(x: &Matrix, gain: &Matrix) -> LnCache {
    assert_eq!(gain.rows, x.cols, "layernorm gain per feature");
    let (rows, cols) = (x.rows, x.cols);
    let mut y = Matrix::zeros(rows, cols);
    let mut xhat = Matrix::zeros(rows, cols);
    let mut istd = vec![0.0f32; rows];
    let inv_cols = 1.0f32 / cols as f32;
    for r in 0..rows {
        let row = &x.data[r * cols..(r + 1) * cols];
        let mut mean = 0.0f32;
        for &v in row {
            mean += v;
        }
        mean *= inv_cols;
        let mut var = 0.0f32;
        for &v in row {
            let d = v - mean;
            var += d * d;
        }
        var *= inv_cols;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        istd[r] = inv;
        let xh = &mut xhat.data[r * cols..(r + 1) * cols];
        let yr = &mut y.data[r * cols..(r + 1) * cols];
        for j in 0..cols {
            xh[j] = (row[j] - mean) * inv;
            yr[j] = xh[j] * gain.data[j];
        }
    }
    LnCache { y, xhat, istd }
}

/// Backward through layer norm: returns (dx, dgain).
pub fn layernorm_bwd(cache: &LnCache, gain: &Matrix, dy: &Matrix) -> (Matrix, Matrix) {
    let (rows, cols) = (dy.rows, dy.cols);
    let mut dx = Matrix::zeros(rows, cols);
    let mut dgain = Matrix::zeros(gain.rows, 1);
    let inv_cols = 1.0f32 / cols as f32;
    for r in 0..rows {
        let dyr = &dy.data[r * cols..(r + 1) * cols];
        let xh = &cache.xhat.data[r * cols..(r + 1) * cols];
        let mut m1 = 0.0f32; // mean_j(dy_j * g_j)
        let mut m2 = 0.0f32; // mean_j(dy_j * g_j * xhat_j)
        for j in 0..cols {
            let dxh = dyr[j] * gain.data[j];
            m1 += dxh;
            m2 += dxh * xh[j];
            dgain.data[j] += dyr[j] * xh[j];
        }
        m1 *= inv_cols;
        m2 *= inv_cols;
        let inv = cache.istd[r];
        let dxr = &mut dx.data[r * cols..(r + 1) * cols];
        for j in 0..cols {
            let dxh = dyr[j] * gain.data[j];
            dxr[j] = inv * (dxh - m1 - xh[j] * m2);
        }
    }
    (dx, dgain)
}

// -- GELU (tanh approximation) -----------------------------------------------

const GELU_C1: f32 = 0.044715;

fn gelu_c0() -> f32 {
    (2.0f32 / std::f32::consts::PI).sqrt()
}

pub fn gelu(u: &Matrix) -> Matrix {
    let c0 = gelu_c0();
    let mut out = u.clone();
    for v in out.data.iter_mut() {
        let x = *v;
        let t = (c0 * (x + GELU_C1 * x * x * x)).tanh();
        *v = 0.5 * x * (1.0 + t);
    }
    out
}

/// `d *= gelu'(u)` elementwise.
pub fn gelu_bwd_inplace(d: &mut Matrix, u: &Matrix) {
    let c0 = gelu_c0();
    for (dv, &x) in d.data.iter_mut().zip(&u.data) {
        let inner = c0 * (x + GELU_C1 * x * x * x);
        let t = inner.tanh();
        let dinner = c0 * (1.0 + 3.0 * GELU_C1 * x * x);
        let grad = 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * dinner;
        *dv *= grad;
    }
}

// -- row softmax (causal) ----------------------------------------------------

/// In-place causal softmax over a square score matrix: row `i` attends
/// to columns `0..=i`; later columns get probability 0.
pub fn causal_softmax_inplace(scores: &mut Matrix) {
    assert!(scores.is_square(), "causal softmax needs square scores");
    let s = scores.rows;
    for i in 0..s {
        let row = &mut scores.data[i * s..(i + 1) * s];
        let valid = i + 1;
        let mut mx = f32::NEG_INFINITY;
        for &v in &row[..valid] {
            mx = mx.max(v);
        }
        let mut sum = 0.0f32;
        for v in row[..valid].iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row[..valid].iter_mut() {
            *v *= inv;
        }
        for v in row[valid..].iter_mut() {
            *v = 0.0;
        }
    }
}

/// Backward through a row-wise softmax: `ds = p ⊙ (dp - rowsum(dp ⊙ p))`.
/// Masked positions carry `p = 0` and therefore get zero gradient.
pub fn softmax_rows_bwd(p: &Matrix, dp: &Matrix) -> Matrix {
    assert_eq!(p.shape(), dp.shape());
    let (rows, cols) = (p.rows, p.cols);
    let mut ds = Matrix::zeros(rows, cols);
    for r in 0..rows {
        let pr = &p.data[r * cols..(r + 1) * cols];
        let dpr = &dp.data[r * cols..(r + 1) * cols];
        let mut dot = 0.0f32;
        for (pv, dv) in pr.iter().zip(dpr) {
            dot += pv * dv;
        }
        let dsr = &mut ds.data[r * cols..(r + 1) * cols];
        for j in 0..cols {
            dsr[j] = pr[j] * (dpr[j] - dot);
        }
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    #[test]
    fn softmax_xent_uniform_logits() {
        let logits = Matrix::zeros(4, 10);
        let y = vec![0, 3, 7, 9];
        let out = softmax_xent(&logits, &y);
        assert!((out.loss - (10.0f64).ln()).abs() < 1e-6);
        // gradient rows sum to 0
        for r in 0..4 {
            let s: f32 = out.dlogits.data[r * 10..(r + 1) * 10].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_xent_gradient_matches_fd() {
        let mut rng = Rng::new(0);
        let mut logits = Matrix::randn(3, 5, 1.0, &mut rng);
        let y = vec![1, 4, 2];
        let out = softmax_xent(&logits, &y);
        let h = 1e-3f32;
        for ci in [0usize, 4, 7, 14] {
            let w0 = logits.data[ci];
            logits.data[ci] = w0 + h;
            let lp = softmax_xent(&logits, &y).loss;
            logits.data[ci] = w0 - h;
            let lm = softmax_xent(&logits, &y).loss;
            logits.data[ci] = w0;
            let num = ((lp - lm) / (2.0 * h as f64)) as f32;
            assert!((num - out.dlogits.data[ci]).abs() < 1e-3, "coord {ci}");
        }
    }

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), d> == <x, col2im(d)> for random x, d
        let cv = Conv { h: 4, w: 4, cin: 2, cout: 3, k: 3 };
        let mut rng = Rng::new(1);
        let mut x = vec![0.0f32; 2 * 4 * 4 * 2];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let col = im2col(&x, 2, &cv);
        let d = Matrix::randn(col.rows, col.cols, 1.0, &mut rng);
        let dx = col2im(&d, 2, &cv);
        let lhs: f64 = col.data.iter().zip(&d.data).map(|(a, b)| (a * b) as f64).sum();
        let rhs: f64 = x.iter().zip(&dx).map(|(a, b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn conv_1x1_is_matmul() {
        let cv = Conv { h: 3, w: 3, cin: 4, cout: 2, k: 1 };
        let mut rng = Rng::new(2);
        let mut x = vec![0.0f32; 9 * 4];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let col = im2col(&x, 1, &cv);
        assert_eq!((col.rows, col.cols), (9, 4));
        assert_eq!(col.data, x);
    }

    #[test]
    fn maxpool_selects_max_and_routes_grad() {
        // one channel, 2x2 -> 1x1
        let x = vec![1.0f32, 5.0, 2.0, 3.0];
        let (out, arg) = maxpool2(&x, 1, 2, 2, 1);
        assert_eq!(out, vec![5.0]);
        assert_eq!(arg, vec![1]);
        let dx = maxpool2_bwd(&[2.5], &arg, 4);
        assert_eq!(dx, vec![0.0, 2.5, 0.0, 0.0]);
    }

    #[test]
    fn layernorm_normalizes_and_bwd_matches_fd() {
        let mut rng = Rng::new(3);
        let x = Matrix::randn(5, 8, 2.0, &mut rng);
        let gain = Matrix::from_vec(8, 1, (0..8).map(|i| 0.5 + 0.1 * i as f32).collect());
        let cache = layernorm_fwd(&x, &gain);
        // per-row mean ~0, var ~1 of xhat
        for r in 0..5 {
            let row = &cache.xhat.data[r * 8..(r + 1) * 8];
            let mean: f32 = row.iter().sum::<f32>() / 8.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 8.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-3);
        }
        // fd check of dx through a fixed projection loss L = <w, ln(x)>
        let w = Matrix::randn(5, 8, 1.0, &mut rng);
        let loss = |x: &Matrix| -> f64 {
            let c = layernorm_fwd(x, &gain);
            c.y.data.iter().zip(&w.data).map(|(a, b)| (a * b) as f64).sum()
        };
        let (dx, _) = layernorm_bwd(&cache, &gain, &w);
        let mut xp = x.clone();
        for ci in [0usize, 9, 17, 33] {
            let w0 = xp.data[ci];
            let h = 1e-3f32;
            xp.data[ci] = w0 + h;
            let lp = loss(&xp);
            xp.data[ci] = w0 - h;
            let lm = loss(&xp);
            xp.data[ci] = w0;
            let num = ((lp - lm) / (2.0 * h as f64)) as f32;
            assert!((num - dx.data[ci]).abs() < 2e-2 * num.abs().max(1.0), "coord {ci}");
        }
    }

    #[test]
    fn gelu_bwd_matches_fd() {
        let mut rng = Rng::new(4);
        let u = Matrix::randn(3, 7, 1.5, &mut rng);
        let mut d = Matrix::from_vec(3, 7, vec![1.0; 21]);
        gelu_bwd_inplace(&mut d, &u);
        for ci in [0usize, 5, 13, 20] {
            let h = 1e-3f32;
            let mut up = u.clone();
            up.data[ci] += h;
            let mut um = u.clone();
            um.data[ci] -= h;
            let num = (gelu(&up).data[ci] - gelu(&um).data[ci]) / (2.0 * h);
            assert!((num - d.data[ci]).abs() < 1e-2, "coord {ci}: {num} vs {}", d.data[ci]);
        }
    }

    #[test]
    fn causal_softmax_rows_are_distributions() {
        let mut rng = Rng::new(5);
        let mut s = Matrix::randn(6, 6, 1.0, &mut rng);
        causal_softmax_inplace(&mut s);
        for i in 0..6 {
            let row = &s.data[i * 6..(i + 1) * 6];
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            for &v in &row[i + 1..] {
                assert_eq!(v, 0.0);
            }
        }
    }

    #[test]
    fn mean_iou_perfect_and_disjoint() {
        assert!((mean_iou(&[0, 1, 2], &[0, 1, 2], 3) - 1.0).abs() < 1e-12);
        // disjoint predictions: every present class has IoU 0
        assert_eq!(mean_iou(&[1, 1], &[0, 0], 3), 0.0);
    }
}
