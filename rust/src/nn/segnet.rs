//! Native SegNet — mirror of `model.make_segnet` (the DeepLabv3/MS-COCO
//! stand-in): two SAME 3x3 convs + ReLU and a 1x1 head predicting 8
//! classes per pixel of a 16x16x3 input; mean-IoU metric.

use super::ops::{col2im, col_sums, im2col, mean_iou, relu_bwd_inplace, softmax_xent, Conv};
use super::{he, zeros, BatchRef, ModelSpec, NativeModel};
use crate::runtime::manifest::Dtype;
use crate::tensor::{matmul_bias, matmul_bias_relu, matmul_nt, matmul_tn, Matrix};
use crate::trace::{self, Phase};

pub const SEG_HW: usize = 16;
pub const SEG_CIN: usize = 3;
pub const SEG_CLASSES: usize = 8;
const SEG_CH: usize = 16;

fn seg_stages() -> [Conv; 3] {
    [
        Conv { h: SEG_HW, w: SEG_HW, cin: SEG_CIN, cout: SEG_CH, k: 3 },
        Conv { h: SEG_HW, w: SEG_HW, cin: SEG_CH, cout: SEG_CH, k: 3 },
        Conv { h: SEG_HW, w: SEG_HW, cin: SEG_CH, cout: SEG_CLASSES, k: 1 },
    ]
}

pub struct Segnet {
    spec: ModelSpec,
}

impl Segnet {
    pub fn new() -> Segnet {
        let stages = seg_stages();
        let names = ["conv1", "conv2", "head"];
        let mut params = Vec::new();
        for (cv, name) in stages.iter().zip(names) {
            params.push(he(&format!("{name}.w"), cv.patch(), cv.cout));
            params.push(zeros(&format!("{name}.b"), cv.cout, 1));
        }
        let spec = ModelSpec {
            name: "segnet",
            metric: "iou",
            batch: 16,
            eval_batch: 64,
            x_dtype: Dtype::F32,
            x_sample: vec![SEG_HW, SEG_HW, SEG_CIN],
            y_sample: vec![SEG_HW, SEG_HW],
            params,
        };
        Segnet { spec }
    }
}

impl Default for Segnet {
    fn default() -> Self {
        Segnet::new()
    }
}

impl NativeModel for Segnet {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn loss_grad(&self, params: &[Matrix], batch: &BatchRef) -> (Vec<Matrix>, f64, f64) {
        let b = batch.batch;
        let stages = seg_stages();

        // forward: conv1+relu, conv2+relu, 1x1 head (no relu) — bias and
        // ReLU fused into the GEMM epilogue; the stored activations
        // double as the ReLU masks in the backward pass, so each stage
        // reads the previous stage's output in place (no copies)
        let fwd_scope = trace::scope(Phase::Forward);
        let mut cols: Vec<Matrix> = Vec::with_capacity(3);
        let mut acts: Vec<Matrix> = Vec::with_capacity(3);
        for (si, cv) in stages.iter().enumerate() {
            let input: &[f32] = if si == 0 { batch.x_f32 } else { &acts[si - 1].data };
            let col = im2col(input, b, cv);
            let post = if si < 2 {
                matmul_bias_relu(&col, &params[2 * si], &params[2 * si + 1])
            } else {
                matmul_bias(&col, &params[2 * si], &params[2 * si + 1])
            };
            cols.push(col);
            acts.push(post);
        }

        // per-pixel softmax cross-entropy over the head logits
        let head = acts.pop().expect("three conv stages");
        let logits = Matrix::from_vec(b * SEG_HW * SEG_HW, SEG_CLASSES, head.data);
        let out = softmax_xent(&logits, batch.y);
        let iou = mean_iou(&out.preds, batch.y, SEG_CLASSES);
        drop(fwd_scope);

        // backward (transpose-free variants)
        let _bwd_scope = trace::scope(Phase::Backward);
        let mut grads: Vec<Matrix> = vec![Matrix::zeros(1, 1); 6];
        let mut dpre = out.dlogits;
        for si in (0..3).rev() {
            let cv = &stages[si];
            if si < 2 {
                relu_bwd_inplace(&mut dpre, &acts[si]);
            }
            grads[2 * si] = matmul_tn(&cols[si], &dpre);
            grads[2 * si + 1] = col_sums(&dpre);
            if si > 0 {
                let dcol = matmul_nt(&dpre, &params[2 * si]);
                let dact = col2im(&dcol, b, cv);
                dpre = Matrix::from_vec(b * cv.h * cv.w, cv.cin, dact);
            }
        }

        (grads, out.loss, iou)
    }

    fn loss_metric(&self, params: &[Matrix], batch: &BatchRef) -> (f64, f64) {
        let b = batch.batch;
        let mut act: Vec<f32> = Vec::new();
        for (si, cv) in seg_stages().iter().enumerate() {
            let input: &[f32] = if si == 0 { batch.x_f32 } else { &act };
            let col = im2col(input, b, cv);
            let post = if si < 2 {
                matmul_bias_relu(&col, &params[2 * si], &params[2 * si + 1])
            } else {
                matmul_bias(&col, &params[2 * si], &params[2 * si + 1])
            };
            act = post.data;
        }
        let logits = Matrix::from_vec(b * SEG_HW * SEG_HW, SEG_CLASSES, act);
        let out = softmax_xent(&logits, batch.y);
        (out.loss, mean_iou(&out.preds, batch.y, SEG_CLASSES))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil::{grad_check, init_params};
    use crate::optim::{Hyper, Optimizer, Sgd, StepCtx};
    use crate::rngx::Rng;

    #[test]
    fn spec_matches_l2_inventory() {
        let s = Segnet::new();
        let want = 27 * 16 + 16 + 144 * 16 + 16 + 16 * 8 + 8;
        assert_eq!(s.spec().param_count(), want);
        assert_eq!(s.spec().y_len(), 256);
    }

    #[test]
    fn gradients_match_finite_differences() {
        grad_check(&Segnet::new(), 2, SEG_CLASSES, 4);
    }

    #[test]
    fn learns_a_pointwise_rule() {
        // per-pixel label = binary code of the three channel signs — a
        // rule the conv stack can fit quickly, unlike random labels
        let s = Segnet::new();
        let b = 2;
        let mut rng = Rng::new(5);
        let mut x = vec![0.0f32; b * SEG_HW * SEG_HW * 3];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let mut y = vec![0i32; b * SEG_HW * SEG_HW];
        for (pi, yo) in y.iter_mut().enumerate() {
            let mut c = 0i32;
            for ch in 0..3 {
                if x[pi * 3 + ch] > 0.0 {
                    c |= 1 << ch;
                }
            }
            *yo = c;
        }
        let batch = BatchRef { batch: b, x_f32: &x, x_i32: &[], y: &y };
        let mut params = init_params(s.spec(), 3);
        let mut opt = Sgd::new(&s.spec().shapes(), Hyper::default());
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for step in 0..80 {
            let (grads, loss, _) = s.loss_grad(&params, &batch);
            assert!(loss.is_finite(), "step {step}");
            if step == 0 {
                first = loss;
            }
            last = loss;
            opt.step(
                &mut params,
                &grads,
                StepCtx { lr: 0.05, weight_decay: 0.0, update_precond: true },
            );
        }
        assert!(last < 0.8 * first, "segnet: no learning ({first} -> {last})");
    }
}
