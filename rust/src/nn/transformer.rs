//! Native decoder-only transformer LM — mirror of
//! `model.make_transformer`, scaled to simulator size (d=128, 2 layers)
//! so the CPU-only native backend trains it in seconds. Pre-LN blocks
//! with learned gains, multi-head causal attention, GELU MLP, weight
//! tying off, softmax cross-entropy over all positions.

use super::ops::{
    accuracy, causal_softmax_inplace, gelu, gelu_bwd_inplace, layernorm_bwd, layernorm_fwd,
    softmax_rows_bwd, softmax_xent, LnCache,
};
use super::{he_scaled, normal, ones, BatchRef, ModelSpec, NativeModel, ParamSpec};
use crate::runtime::manifest::Dtype;
use crate::tensor::{matmul, matmul_nt, matmul_tn, Matrix};
use crate::trace::{self, Phase};

pub struct Transformer {
    vocab: usize,
    seq: usize,
    d: usize,
    layers: usize,
    heads: usize,
    spec: ModelSpec,
}

impl Transformer {
    pub fn new(
        vocab: usize,
        seq: usize,
        d: usize,
        layers: usize,
        heads: usize,
        ff: usize,
        batch: usize,
        eval_batch: usize,
    ) -> Transformer {
        assert!(d % heads == 0, "d must be divisible by heads");
        let mut params: Vec<ParamSpec> = vec![
            normal("embed", vocab, d, 0.02),
            normal("pos", seq, d, 0.02),
        ];
        for l in 0..layers {
            params.push(ones(&format!("l{l}.ln1_g"), d, 1));
            params.push(he_scaled(&format!("l{l}.wq"), d, d, 0.5));
            params.push(he_scaled(&format!("l{l}.wk"), d, d, 0.5));
            params.push(he_scaled(&format!("l{l}.wv"), d, d, 0.5));
            params.push(he_scaled(&format!("l{l}.wo"), d, d, 0.5));
            params.push(ones(&format!("l{l}.ln2_g"), d, 1));
            params.push(he_scaled(&format!("l{l}.w1"), d, ff, 0.5));
            params.push(he_scaled(&format!("l{l}.w2"), ff, d, 0.5));
        }
        params.push(ones("lnf_g", d, 1));
        params.push(he_scaled("head", d, vocab, 0.5));
        let spec = ModelSpec {
            name: "transformer",
            metric: "token_acc",
            batch,
            eval_batch,
            x_dtype: Dtype::I32,
            x_sample: vec![seq],
            y_sample: vec![seq],
            params,
        };
        Transformer { vocab, seq, d, layers, heads, spec }
    }

    /// The workload configuration the native backend serves.
    pub fn default_lm() -> Transformer {
        Transformer::new(512, 64, 128, 2, 4, 512, 8, 16)
    }

    /// A miniature instance for gradient checks.
    pub fn tiny() -> Transformer {
        Transformer::new(13, 6, 8, 1, 2, 16, 2, 4)
    }

    fn lidx(&self, l: usize, j: usize) -> usize {
        2 + l * 8 + j
    }
}

/// Per-head `(S, dh)` slice of a `(B*S, D)` activation matrix.
fn slice_head(m: &Matrix, bi: usize, s: usize, off: usize, dh: usize) -> Matrix {
    let mut out = Matrix::zeros(s, dh);
    for i in 0..s {
        let base = (bi * s + i) * m.cols + off;
        out.data[i * dh..(i + 1) * dh].copy_from_slice(&m.data[base..base + dh]);
    }
    out
}

/// Accumulate a `(S, dh)` head block back into a `(B*S, D)` matrix.
fn add_head(dst: &mut Matrix, blk: &Matrix, bi: usize, s: usize, off: usize) {
    let dh = blk.cols;
    for i in 0..s {
        let base = (bi * s + i) * dst.cols + off;
        let d = &mut dst.data[base..base + dh];
        for (dv, bv) in d.iter_mut().zip(&blk.data[i * dh..(i + 1) * dh]) {
            *dv += bv;
        }
    }
}

struct LayerCache {
    ln1: LnCache,
    q: Matrix,
    k: Matrix,
    v: Matrix,
    /// Attention probabilities, one `(S, S)` matrix per (batch, head).
    probs: Vec<Matrix>,
    /// Concatenated head outputs, pre-`wo`.
    o: Matrix,
    ln2: LnCache,
    /// Pre-GELU FFN activation.
    u: Matrix,
    /// Post-GELU FFN activation.
    a: Matrix,
}

struct Fwd {
    layer_caches: Vec<LayerCache>,
    lnf: LnCache,
    logits: Matrix,
}

impl Transformer {
    fn forward(&self, params: &[Matrix], batch: &BatchRef) -> Fwd {
        let (b, s, d, dh) = (batch.batch, self.seq, self.d, self.d / self.heads);
        let scale = 1.0 / (dh as f32).sqrt();
        let embed = &params[0];
        let pos = &params[1];

        // token + position embeddings
        let mut x = Matrix::zeros(b * s, d);
        for bi in 0..b {
            for si in 0..s {
                let tok = batch.x_i32[bi * s + si] as usize;
                assert!(tok < self.vocab, "token {tok} out of range");
                let row = &mut x.data[(bi * s + si) * d..(bi * s + si + 1) * d];
                for j in 0..d {
                    row[j] = embed.data[tok * d + j] + pos.data[si * d + j];
                }
            }
        }

        let mut layer_caches = Vec::with_capacity(self.layers);
        for l in 0..self.layers {
            let ln1 = layernorm_fwd(&x, &params[self.lidx(l, 0)]);
            let q = matmul(&ln1.y, &params[self.lidx(l, 1)]);
            let k = matmul(&ln1.y, &params[self.lidx(l, 2)]);
            let v = matmul(&ln1.y, &params[self.lidx(l, 3)]);
            let mut probs = Vec::with_capacity(b * self.heads);
            let mut o = Matrix::zeros(b * s, d);
            for bi in 0..b {
                for hd in 0..self.heads {
                    let off = hd * dh;
                    let qb = slice_head(&q, bi, s, off, dh);
                    let kb = slice_head(&k, bi, s, off, dh);
                    let vb = slice_head(&v, bi, s, off, dh);
                    // q @ k^T without materialising the per-head transpose
                    let mut scores = matmul_nt(&qb, &kb);
                    scores.scale_inplace(scale);
                    causal_softmax_inplace(&mut scores);
                    let o_bh = matmul(&scores, &vb);
                    add_head(&mut o, &o_bh, bi, s, off);
                    probs.push(scores);
                }
            }
            let attn_out = matmul(&o, &params[self.lidx(l, 4)]);
            for (xv, av) in x.data.iter_mut().zip(&attn_out.data) {
                *xv += av;
            }

            let ln2 = layernorm_fwd(&x, &params[self.lidx(l, 5)]);
            let u = matmul(&ln2.y, &params[self.lidx(l, 6)]);
            let a = gelu(&u);
            let f = matmul(&a, &params[self.lidx(l, 7)]);
            for (xv, fv) in x.data.iter_mut().zip(&f.data) {
                *xv += fv;
            }

            layer_caches.push(LayerCache { ln1, q, k, v, probs, o, ln2, u, a });
        }

        let lnf = layernorm_fwd(&x, &params[2 + self.layers * 8]);
        let logits = matmul(&lnf.y, &params[3 + self.layers * 8]);
        Fwd { layer_caches, lnf, logits }
    }
}

impl NativeModel for Transformer {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn loss_grad(&self, params: &[Matrix], batch: &BatchRef) -> (Vec<Matrix>, f64, f64) {
        let (b, s, d, dh) = (batch.batch, self.seq, self.d, self.d / self.heads);
        let scale = 1.0 / (dh as f32).sqrt();
        let fwd_scope = trace::scope(Phase::Forward);
        let fwd = self.forward(params, batch);

        let out = softmax_xent(&fwd.logits, batch.y);
        let acc = accuracy(&out.preds, batch.y);
        drop(fwd_scope);

        let _bwd_scope = trace::scope(Phase::Backward);
        let mut grads: Vec<Matrix> =
            params.iter().map(|p| Matrix::zeros(p.rows, p.cols)).collect();

        // head + final layer norm (transpose-free GEMM variants
        // throughout the backward pass — no `.t()` copies)
        let head_i = 3 + self.layers * 8;
        let lnf_i = 2 + self.layers * 8;
        grads[head_i] = matmul_tn(&fwd.lnf.y, &out.dlogits);
        let dxf = matmul_nt(&out.dlogits, &params[head_i]);
        let (mut dx, dgf) = layernorm_bwd(&fwd.lnf, &params[lnf_i], &dxf);
        grads[lnf_i] = dgf;

        for l in (0..self.layers).rev() {
            let cache = &fwd.layer_caches[l];

            // FFN block: x_out = x_mid + gelu(ln2(x_mid)) @ w2
            let df = &dx; // residual pass-through
            grads[self.lidx(l, 7)] = matmul_tn(&cache.a, df);
            let mut du = matmul_nt(df, &params[self.lidx(l, 7)]);
            gelu_bwd_inplace(&mut du, &cache.u);
            grads[self.lidx(l, 6)] = matmul_tn(&cache.ln2.y, &du);
            let dh2 = matmul_nt(&du, &params[self.lidx(l, 6)]);
            let (dx_ln2, dg2) = layernorm_bwd(&cache.ln2, &params[self.lidx(l, 5)], &dh2);
            grads[self.lidx(l, 5)] = dg2;
            for (xv, av) in dx.data.iter_mut().zip(&dx_ln2.data) {
                *xv += av;
            }

            // attention block: x_mid = x_in + (heads(ln1(x_in))) @ wo
            let dattn = &dx;
            grads[self.lidx(l, 4)] = matmul_tn(&cache.o, dattn);
            let do_all = matmul_nt(dattn, &params[self.lidx(l, 4)]);
            let mut dq = Matrix::zeros(b * s, d);
            let mut dk = Matrix::zeros(b * s, d);
            let mut dv = Matrix::zeros(b * s, d);
            for bi in 0..b {
                for hd in 0..self.heads {
                    let off = hd * dh;
                    let p = &cache.probs[bi * self.heads + hd];
                    let do_bh = slice_head(&do_all, bi, s, off, dh);
                    let vb = slice_head(&cache.v, bi, s, off, dh);
                    let qb = slice_head(&cache.q, bi, s, off, dh);
                    let kb = slice_head(&cache.k, bi, s, off, dh);
                    let dp = matmul_nt(&do_bh, &vb);
                    let dv_bh = matmul_tn(p, &do_bh);
                    let mut ds = softmax_rows_bwd(p, &dp);
                    ds.scale_inplace(scale);
                    let dq_bh = matmul(&ds, &kb);
                    let dk_bh = matmul_tn(&ds, &qb);
                    add_head(&mut dq, &dq_bh, bi, s, off);
                    add_head(&mut dk, &dk_bh, bi, s, off);
                    add_head(&mut dv, &dv_bh, bi, s, off);
                }
            }
            grads[self.lidx(l, 1)] = matmul_tn(&cache.ln1.y, &dq);
            grads[self.lidx(l, 2)] = matmul_tn(&cache.ln1.y, &dk);
            grads[self.lidx(l, 3)] = matmul_tn(&cache.ln1.y, &dv);
            let mut dh1 = matmul_nt(&dq, &params[self.lidx(l, 1)]);
            let dh_k = matmul_nt(&dk, &params[self.lidx(l, 2)]);
            let dh_v = matmul_nt(&dv, &params[self.lidx(l, 3)]);
            for i in 0..dh1.data.len() {
                dh1.data[i] += dh_k.data[i] + dh_v.data[i];
            }
            let (dx_ln1, dg1) = layernorm_bwd(&cache.ln1, &params[self.lidx(l, 0)], &dh1);
            grads[self.lidx(l, 0)] = dg1;
            for (xv, av) in dx.data.iter_mut().zip(&dx_ln1.data) {
                *xv += av;
            }
        }

        // embeddings: scatter-add token rows, accumulate positions
        for bi in 0..b {
            for si in 0..s {
                let tok = batch.x_i32[bi * s + si] as usize;
                let row = &dx.data[(bi * s + si) * d..(bi * s + si + 1) * d];
                let erow = &mut grads[0].data[tok * d..(tok + 1) * d];
                for (ev, rv) in erow.iter_mut().zip(row) {
                    *ev += rv;
                }
                let prow = &mut grads[1].data[si * d..(si + 1) * d];
                for (pv, rv) in prow.iter_mut().zip(row) {
                    *pv += rv;
                }
            }
        }

        (grads, out.loss, acc)
    }

    fn loss_metric(&self, params: &[Matrix], batch: &BatchRef) -> (f64, f64) {
        let fwd = self.forward(params, batch);
        let out = softmax_xent(&fwd.logits, batch.y);
        (out.loss, accuracy(&out.preds, batch.y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil::{grad_check, init_params, overfits_one_batch, random_batch};

    #[test]
    fn default_spec_shapes() {
        let t = Transformer::default_lm();
        assert_eq!(t.spec().params.len(), 2 + 2 * 8 + 2);
        assert_eq!(t.spec().x_len(), 64);
        assert_eq!(t.spec().y_len(), 64);
        assert!(t.spec().param_count() > 400_000, "{}", t.spec().param_count());
    }

    #[test]
    fn gradients_match_finite_differences() {
        grad_check(&Transformer::tiny(), 2, 13, 4);
    }

    #[test]
    fn overfits_a_small_batch() {
        overfits_one_batch(&Transformer::tiny(), 2, 13, 60);
    }

    #[test]
    fn causal_mask_blocks_future_tokens() {
        // changing a future token must not change the logits of earlier
        // positions
        let t = Transformer::tiny();
        let params = init_params(t.spec(), 1);
        let batch = random_batch(t.spec(), 1, 13, 2);
        let fwd_a = t.forward(&params, &batch.view());
        let mut batch_b = batch;
        let last = batch_b.x_i32.len() - 1;
        batch_b.x_i32[last] = (batch_b.x_i32[last] + 1) % 13;
        let fwd_b = t.forward(&params, &batch_b.view());
        let cols = fwd_a.logits.cols;
        for r in 0..last {
            for c in 0..cols {
                let a = fwd_a.logits.data[r * cols + c];
                let b2 = fwd_b.logits.data[r * cols + c];
                assert!((a - b2).abs() < 1e-5, "position {r} leaked future info");
            }
        }
    }
}
