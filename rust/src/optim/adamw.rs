//! AdamW with decoupled weight decay (mirrors `optim_jax.make_adamw`).

use super::{AdamWParams, Hyper, Optimizer, StepCtx};
use crate::tensor::Matrix;

pub struct AdamW {
    p: AdamWParams,
    exp_avg: Vec<Matrix>,
    exp_avg_sq: Vec<Matrix>,
    t: u64,
}

impl AdamW {
    pub fn new(shapes: &[(usize, usize)], hyper: Hyper) -> Self {
        Self::with_params(shapes, (&hyper).into())
    }

    pub fn with_params(shapes: &[(usize, usize)], p: AdamWParams) -> Self {
        AdamW {
            p,
            exp_avg: shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect(),
            exp_avg_sq: shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect(),
            t: 0,
        }
    }
}

impl Optimizer for AdamW {
    fn name(&self) -> &'static str {
        "adamw"
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], ctx: StepCtx) {
        self.t += 1;
        let (b1, b2, eps) = (self.p.beta1, self.p.beta2, self.p.eps);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for (((p, g), m), v) in params
            .iter_mut()
            .zip(grads)
            .zip(&mut self.exp_avg)
            .zip(&mut self.exp_avg_sq)
        {
            for i in 0..p.data.len() {
                let gi = g.data[i];
                m.data[i] = b1 * m.data[i] + (1.0 - b1) * gi;
                v.data[i] = b2 * v.data[i] + (1.0 - b2) * gi * gi;
                let m_hat = m.data[i] / bc1;
                let v_hat = v.data[i] / bc2;
                p.data[i] -= ctx.lr * (m_hat / (v_hat.sqrt() + eps))
                    + ctx.lr * ctx.weight_decay * p.data[i];
            }
        }
    }

    fn state_floats(&self) -> usize {
        self.exp_avg.iter().map(|m| m.data.len()).sum::<usize>() * 2 + 1
    }

    fn state_mut(&mut self) -> Vec<&mut Matrix> {
        self.exp_avg.iter_mut().chain(self.exp_avg_sq.iter_mut()).collect()
    }

    fn n_layers(&self) -> usize {
        self.exp_avg.len()
    }

    fn step_count(&self) -> u64 {
        self.t
    }

    fn set_step_count(&mut self, t: u64) {
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn ctx(lr: f32, wd: f32) -> StepCtx {
        StepCtx { lr, weight_decay: wd, update_precond: true }
    }

    #[test]
    fn first_step_is_signed_lr() {
        let mut rng = Rng::new(0);
        let mut p = vec![Matrix::randn(5, 5, 1.0, &mut rng)];
        let p0 = p[0].clone();
        let g = vec![Matrix::randn(5, 5, 0.3, &mut rng)];
        let mut opt = AdamW::new(&[(5, 5)], Hyper::default());
        opt.step(&mut p, &g, ctx(1e-3, 0.0));
        for i in 0..25 {
            let delta = p0.data[i] - p[0].data[i];
            // first bias-corrected step ≈ lr * sign(g)
            assert!((delta - 1e-3 * g[0].data[i].signum()).abs() < 2e-4, "{delta}");
        }
    }

    #[test]
    fn decoupled_wd_with_zero_grad() {
        let mut p = vec![Matrix::from_vec(1, 1, vec![2.0])];
        let g = vec![Matrix::zeros(1, 1)];
        let mut opt = AdamW::new(&[(1, 1)], Hyper::default());
        opt.step(&mut p, &g, ctx(1e-2, 0.5));
        assert!((p[0].data[0] - 2.0 * (1.0 - 1e-2 * 0.5)).abs() < 1e-6);
    }

    #[test]
    fn adapts_to_gradient_scale() {
        // two coordinates, gradient 100x apart -> updates nearly equal
        let mut p = vec![Matrix::zeros(1, 2)];
        let g = vec![Matrix::from_vec(1, 2, vec![1.0, 100.0])];
        let mut opt = AdamW::new(&[(1, 2)], Hyper::default());
        for _ in 0..5 {
            opt.step(&mut p, &g, ctx(1e-3, 0.0));
        }
        let r = p[0].data[1] / p[0].data[0];
        assert!((r - 1.0).abs() < 0.05, "ratio {r}");
    }

    #[test]
    fn memory_is_2x_params() {
        let opt = AdamW::new(&[(10, 10), (10, 1)], Hyper::default());
        assert_eq!(opt.state_floats(), 2 * 110 + 1);
    }
}
