//! Jorge — the paper's optimizer (Algorithm 2 + App. A.1/A.2), native
//! mirror of `optim_jax.make_jorge` / the Pallas kernels.
//!
//! Per 2-D layer: inverse-fourth-root estimates `L^`, `R^` updated with
//! the inverse-free truncated-binomial rule, preconditioning `L^ G R^`,
//! grafted momentum update with decoupled weight decay. 1-D layers
//! (biases/gains) take the grafted SGD update directly.
//!
//! The per-layer step factors into [`refresh_layer`] (gram + inverse-free
//! preconditioner refresh — the shardable owner-computes half; a no-op on
//! skip steps, Jorge keeps no extra statistics) and [`apply_layer`]
//! (preconditioned grafted update). The fused [`Optimizer::step`] runs
//! both back to back, so refresh-then-apply through the trait's split
//! protocol is bitwise identical to the serial step.

use super::{
    for_each_layer, grafted_update, max_dim, GuardReport, Hyper, JorgeParams, Optimizer, StepCtx,
    INNER_PAR_DIM,
};
use crate::tensor::{gram_left, gram_right, jorge_update, matmul, Matrix};
use crate::trace::{self, Phase};

struct LayerState {
    /// None for unpreconditioned (1-D) layers.
    l_hat: Option<Matrix>,
    r_hat: Option<Matrix>,
    mom: Matrix,
    gmom: Matrix,
    guard: GuardReport,
}

pub struct Jorge {
    p: JorgeParams,
    layers: Vec<LayerState>,
}

impl Jorge {
    pub fn new(shapes: &[(usize, usize)], hyper: Hyper) -> Self {
        Self::with_params(shapes, (&hyper).into())
    }

    pub fn with_params(shapes: &[(usize, usize)], p: JorgeParams) -> Self {
        let scale = p.eps.powf(-0.25);
        let layers = shapes
            .iter()
            .map(|&(m, n)| {
                let precond = m > 1 && n > 1;
                LayerState {
                    l_hat: precond.then(|| Matrix::eye(m, scale)),
                    r_hat: precond.then(|| Matrix::eye(n, scale)),
                    mom: Matrix::zeros(m, n),
                    gmom: Matrix::zeros(m, n),
                    guard: GuardReport::default(),
                }
            })
            .collect();
        Jorge { p, layers }
    }

    /// Expose a preconditioner for tests/analysis.
    pub fn left_preconditioner(&self, layer: usize) -> Option<&Matrix> {
        self.layers[layer].l_hat.as_ref()
    }
}

/// Owner-computes half: inverse-free truncated-binomial refresh of both
/// preconditioner estimates. Jorge accumulates no separate statistics,
/// so skip steps do nothing here.
///
/// Guardrails (zero-cost on healthy inputs beyond an `all_finite` scan):
/// a non-finite gradient keeps the stale estimates; non-finite estimates
/// (e.g. a corrupted import) self-heal to the eps-identity before the
/// refresh; a non-finite refresh result is retried once with a damped
/// gram, and only then falls back to stale.
fn refresh_layer(eps: f32, st: &mut LayerState, g: &Matrix, update: bool) {
    if !update || st.l_hat.is_none() {
        return;
    }
    if !g.all_finite() {
        st.guard.nonfinite_grads += 1;
        st.guard.stale_preconds += 1;
        return;
    }
    let heal = {
        let (Some(l_hat), Some(r_hat)) = (&st.l_hat, &st.r_hat) else { return };
        !l_hat.all_finite() || !r_hat.all_finite()
    };
    if heal {
        let scale = eps.powf(-0.25);
        let (m, n) = (st.mom.rows, st.mom.cols);
        st.l_hat = Some(Matrix::eye(m, scale));
        st.r_hat = Some(Matrix::eye(n, scale));
        st.guard.precond_resets += 1;
    }
    let (Some(l_hat), Some(r_hat)) = (&mut st.l_hat, &mut st.r_hat) else { return };
    let gl = gram_left(g);
    let gr = gram_right(g);
    if gl.all_finite() && gr.all_finite() {
        let new_l = jorge_update(l_hat, &gl);
        let new_r = jorge_update(r_hat, &gr);
        if new_l.all_finite() && new_r.all_finite() {
            *l_hat = new_l;
            *r_hat = new_r;
            return;
        }
    }
    // Damped retry: rebuild the grams from the max-abs-normalized
    // gradient. Jorge's update normalizes by ||P^4 S||, so it is nearly
    // scale-invariant in S — damping tames the overflow without changing
    // the fixed point the estimate converges to.
    st.guard.damped_retries += 1;
    let gd = g.scale(1.0 / g.max_abs().max(1e-30));
    let retry_l = jorge_update(l_hat, &gram_left(&gd));
    let retry_r = jorge_update(r_hat, &gram_right(&gd));
    if retry_l.all_finite() && retry_r.all_finite() {
        *l_hat = retry_l;
        *r_hat = retry_r;
    } else {
        st.guard.stale_preconds += 1;
    }
}

/// Apply half: precondition with the current estimates and take the
/// grafted update (decoupled weight decay). Never refreshes.
///
/// Guardrails: a non-finite gradient freezes the layer for the step (no
/// momentum EMA, no decay); a non-finite preconditioned gradient falls
/// back to the grafted first-order direction.
fn apply_layer(p: JorgeParams, st: &mut LayerState, param: &mut Matrix, g: &Matrix, ctx: StepCtx) {
    if !g.all_finite() {
        st.guard.nonfinite_grads += 1;
        st.guard.skipped_updates += 1;
        return;
    }
    match (&st.l_hat, &st.r_hat) {
        (Some(l_hat), Some(r_hat)) => {
            let gtilde = matmul(&matmul(l_hat, g), r_hat);
            if gtilde.all_finite() {
                grafted_update(param, g, &gtilde, &mut st.mom, &mut st.gmom, ctx, p.graft, true);
            } else {
                st.guard.graft_fallbacks += 1;
                grafted_update(param, g, g, &mut st.mom, &mut st.gmom, ctx, p.graft, true);
            }
        }
        _ => {
            grafted_update(param, g, g, &mut st.mom, &mut st.gmom, ctx, p.graft, true);
        }
    }
}

impl Optimizer for Jorge {
    fn name(&self) -> &'static str {
        "jorge"
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], ctx: StepCtx) {
        assert_eq!(params.len(), self.layers.len());
        // Layers are independent: fan the per-layer updates (grams,
        // inverse-free preconditioner refresh, preconditioned GEMM)
        // across the worker pool; GEMMs inside a task run inline. On
        // refresh steps dominated by one large preconditioner, stay
        // serial so that layer's GEMMs get the pool instead.
        let p = self.p;
        let body = |li: usize, param: &mut Matrix, st: &mut LayerState| {
            let g = &grads[li];
            refresh_layer(p.eps, st, g, ctx.update_precond);
            apply_layer(p, st, param, g, ctx);
        };
        let dims = self.layers.iter().flat_map(|s| [s.l_hat.as_ref(), s.r_hat.as_ref()]);
        let serial = ctx.update_precond && max_dim(dims) >= INNER_PAR_DIM;
        for_each_layer(params, &mut self.layers, serial, body);
    }

    fn state_floats(&self) -> usize {
        self.layers
            .iter()
            .map(|s| {
                s.mom.data.len()
                    + s.gmom.data.len()
                    + s.l_hat.as_ref().map_or(0, |m| m.data.len())
                    + s.r_hat.as_ref().map_or(0, |m| m.data.len())
            })
            .sum()
    }

    fn state_mut(&mut self) -> Vec<&mut Matrix> {
        let mut out = Vec::new();
        for s in &mut self.layers {
            if let Some(l) = &mut s.l_hat {
                out.push(l);
            }
            if let Some(r) = &mut s.r_hat {
                out.push(r);
            }
            out.push(&mut s.mom);
            out.push(&mut s.gmom);
        }
        out
    }

    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn refresh_flops(&self, layer: usize) -> f64 {
        let st = &self.layers[layer];
        let (Some(l), Some(r)) = (&st.l_hat, &st.r_hat) else { return 0.0 };
        let (m, n) = (l.rows as f64, r.rows as f64);
        let mn = st.mom.data.len() as f64; // m*n
        // grams (2 m^2 n + 2 n^2 m) + ~5 square GEMMs per side for the
        // truncated-binomial update
        2.0 * m * mn + 2.0 * n * mn + 10.0 * (m * m * m + n * n * n)
    }

    fn refresh_layers(&mut self, layers: &[usize], grads: &[Matrix], update_precond: bool) {
        let _scope = trace::scope(Phase::PrecondRefresh);
        let traced = trace::enabled();
        for &li in layers {
            let t0 = traced.then(std::time::Instant::now);
            refresh_layer(self.p.eps, &mut self.layers[li], &grads[li], update_precond);
            if let Some(t0) = t0 {
                let dt = t0.elapsed().as_secs_f64();
                trace::add_gauge(&format!("trace.layer.{li}.refresh_s"), dt);
            }
        }
    }

    fn guard_report(&self) -> GuardReport {
        let mut total = GuardReport::default();
        for s in &self.layers {
            total.merge(&s.guard);
        }
        total
    }

    fn apply_update(&mut self, params: &mut [Matrix], grads: &[Matrix], ctx: StepCtx) {
        let _scope = trace::scope(Phase::Apply);
        assert_eq!(params.len(), self.layers.len());
        let p = self.p;
        let traced = trace::enabled();
        let body = |li: usize, param: &mut Matrix, st: &mut LayerState| {
            let t0 = traced.then(std::time::Instant::now);
            apply_layer(p, st, param, &grads[li], ctx);
            if let Some(t0) = t0 {
                let dt = t0.elapsed().as_secs_f64();
                trace::add_gauge(&format!("trace.layer.{li}.apply_s"), dt);
            }
        };
        for_each_layer(params, &mut self.layers, false, body);
    }

    fn export_preconditioners(&self, layers: &[usize]) -> Vec<f32> {
        let mut out = Vec::new();
        for &li in layers {
            let st = &self.layers[li];
            if let (Some(l), Some(r)) = (&st.l_hat, &st.r_hat) {
                out.extend_from_slice(&l.data);
                out.extend_from_slice(&r.data);
            }
        }
        out
    }

    fn import_preconditioners(&mut self, layers: &[usize], data: &[f32]) -> usize {
        let mut off = 0;
        for &li in layers {
            let st = &mut self.layers[li];
            if let (Some(l), Some(r)) = (&mut st.l_hat, &mut st.r_hat) {
                l.data.copy_from_slice(&data[off..off + l.data.len()]);
                off += l.data.len();
                r.data.copy_from_slice(&data[off..off + r.data.len()]);
                off += r.data.len();
            }
        }
        off
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn ctx(lr: f32, wd: f32, upd: bool) -> StepCtx {
        StepCtx { lr, weight_decay: wd, update_precond: upd }
    }

    #[test]
    fn skip_step_leaves_preconditioners_untouched() {
        let mut rng = Rng::new(0);
        let mut p = vec![Matrix::randn(6, 4, 1.0, &mut rng)];
        let g = vec![Matrix::randn(6, 4, 0.1, &mut rng)];
        let mut opt = Jorge::new(&[(6, 4)], Hyper::default());
        let l0 = opt.left_preconditioner(0).unwrap().clone();
        opt.step(&mut p, &g, ctx(0.1, 0.0, false));
        assert_eq!(opt.left_preconditioner(0).unwrap(), &l0);
        opt.step(&mut p, &g, ctx(0.1, 0.0, true));
        assert_ne!(opt.left_preconditioner(0).unwrap(), &l0);
    }

    #[test]
    fn unpreconditioned_bias_layers() {
        let mut rng = Rng::new(1);
        let mut p = vec![Matrix::randn(4, 1, 1.0, &mut rng)];
        let g = vec![Matrix::randn(4, 1, 0.1, &mut rng)];
        let mut opt = Jorge::new(&[(4, 1)], Hyper::default());
        assert!(opt.left_preconditioner(0).is_none());
        let p0 = p[0].clone();
        opt.step(&mut p, &g, ctx(0.1, 0.0, true));
        // grafted SGD: first step = lr * ||g|| * g/||g|| = lr * g
        let want = p0.sub(&g[0].scale(0.1));
        assert!(p[0].max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn first_step_magnitude_matches_sgd_grafting() {
        let mut rng = Rng::new(2);
        let mut p = vec![Matrix::randn(8, 5, 1.0, &mut rng)];
        let p0 = p[0].clone();
        let g = vec![Matrix::randn(8, 5, 0.2, &mut rng)];
        let mut opt = Jorge::new(&[(8, 5)], Hyper::default());
        opt.step(&mut p, &g, ctx(0.05, 0.0, true));
        let step_norm = p[0].sub(&p0).frobenius();
        let want = 0.05 * g[0].frobenius();
        assert!((step_norm - want).abs() / want < 1e-3);
    }

    #[test]
    fn decoupled_weight_decay_applies() {
        let mut p = vec![Matrix::from_vec(2, 2, vec![1.0; 4])];
        let g = vec![Matrix::zeros(2, 2)];
        let mut opt = Jorge::new(&[(2, 2)], Hyper::default());
        opt.step(&mut p, &g, ctx(0.1, 0.5, true));
        // zero grads => gtilde = 0, mom = 0 => only decay: p *= (1 - lr*wd)
        for v in &p[0].data {
            assert!((v - (1.0 - 0.1 * 0.5)).abs() < 1e-6, "{v}");
        }
    }

    #[test]
    fn memory_accounting_matches_a6() {
        // (m,n) layer: L(m^2) + R(n^2) + 2mn; bias: 2n
        let opt = Jorge::new(&[(8, 4), (4, 1)], Hyper::default());
        assert_eq!(opt.state_floats(), 64 + 16 + 2 * 32 + 2 * 4);
    }

    #[test]
    fn preconditioners_stay_finite_and_symmetric_over_training() {
        let mut rng = Rng::new(3);
        let mut p = vec![Matrix::randn(10, 6, 1.0, &mut rng)];
        let mut opt = Jorge::new(&[(10, 6)], Hyper::default());
        for i in 0..30 {
            let g = vec![Matrix::randn(10, 6, 0.5, &mut rng)];
            opt.step(&mut p, &g, ctx(0.01, 1e-3, i % 2 == 0));
            let l = opt.left_preconditioner(0).unwrap();
            assert!(l.all_finite(), "step {i}");
            let asym = l.sub(&l.t()).max_abs() / l.max_abs().max(1e-12);
            assert!(asym < 0.05, "step {i}: asym {asym}");
        }
    }

    #[test]
    fn nan_gradient_freezes_layer_and_keeps_state_finite() {
        let mut rng = Rng::new(8);
        let mut p = vec![Matrix::randn(6, 4, 1.0, &mut rng)];
        let mut opt = Jorge::new(&[(6, 4)], Hyper::default());
        // healthy step first so state is non-trivial
        let g_ok = vec![Matrix::randn(6, 4, 0.3, &mut rng)];
        opt.step(&mut p, &g_ok, ctx(0.05, 1e-3, true));
        assert_eq!(opt.guard_report().total(), 0, "healthy run must not trip guards");
        let p_before = p[0].clone();
        let l_before = opt.left_preconditioner(0).unwrap().clone();
        let mut g_bad = Matrix::randn(6, 4, 0.3, &mut rng);
        g_bad.data[5] = f32::NAN;
        opt.step(&mut p, &[g_bad], ctx(0.05, 1e-3, true));
        // layer frozen, preconditioner stale, everything still finite
        assert_eq!(p[0], p_before);
        assert_eq!(opt.left_preconditioner(0).unwrap(), &l_before);
        let rep = opt.guard_report();
        assert!(rep.nonfinite_grads >= 1);
        assert_eq!(rep.skipped_updates, 1);
        assert_eq!(rep.stale_preconds, 1);
        // training continues cleanly afterwards
        let g2 = vec![Matrix::randn(6, 4, 0.3, &mut rng)];
        opt.step(&mut p, &g2, ctx(0.05, 1e-3, true));
        assert!(p[0].all_finite());
        assert!(opt.left_preconditioner(0).unwrap().all_finite());
    }

    #[test]
    fn corrupted_preconditioner_self_heals_on_refresh() {
        let mut rng = Rng::new(9);
        let mut p = vec![Matrix::randn(6, 4, 1.0, &mut rng)];
        let mut opt = Jorge::new(&[(6, 4)], Hyper::default());
        // poison the estimate the way a corrupted all-gather import would
        let n_l = 36;
        let mut blob = opt.export_preconditioners(&[0]);
        blob[n_l / 2] = f32::NAN;
        opt.import_preconditioners(&[0], &blob);
        assert!(!opt.left_preconditioner(0).unwrap().all_finite());
        let g = vec![Matrix::randn(6, 4, 0.3, &mut rng)];
        opt.step(&mut p, &g, ctx(0.05, 0.0, true));
        assert!(opt.left_preconditioner(0).unwrap().all_finite(), "must self-heal");
        assert!(p[0].all_finite());
        assert_eq!(opt.guard_report().precond_resets, 1);
    }

    #[test]
    fn overflowing_gradient_takes_damped_retry() {
        let mut rng = Rng::new(10);
        let mut p = vec![Matrix::randn(6, 4, 1.0, &mut rng)];
        let mut opt = Jorge::new(&[(6, 4)], Hyper::default());
        // finite but huge: the gram (entrywise ~1e40) overflows f32
        let g = vec![Matrix::randn(6, 4, 1.0, &mut rng).scale(1e20)];
        assert!(g[0].all_finite());
        opt.refresh_layers(&[0], &g, true);
        let rep = opt.guard_report();
        assert_eq!(rep.damped_retries, 1);
        assert!(opt.left_preconditioner(0).unwrap().all_finite());
    }

    #[test]
    fn converges_on_quadratic_faster_than_plain_direction() {
        // sanity: jorge minimises ||W - T||^2 quickly
        let mut rng = Rng::new(4);
        let target = Matrix::randn(8, 6, 1.0, &mut rng);
        let mut p = vec![Matrix::zeros(8, 6)];
        let mut opt = Jorge::new(&[(8, 6)], Hyper::default());
        let mut last = f64::INFINITY;
        for step in 0..80 {
            let g = vec![p[0].sub(&target)];
            let loss = g[0].frobenius_sq();
            if step > 0 {
                assert!(loss.is_finite());
            }
            last = loss;
            opt.step(&mut p, &g, ctx(0.1, 0.0, true));
        }
        let init = target.frobenius_sq();
        assert!(last < 0.05 * init, "{init} -> {last}");
    }
}
