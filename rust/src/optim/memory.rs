//! Optimizer memory accounting (App. A.6 of the paper).
//!
//! Computes the optimizer-state footprint per optimizer for a given
//! network inventory. Reproduces the paper's claim: Adam holds 2 f32
//! states per parameter; Jorge holds 3 (L^, R^, momentum) rising to 4
//! with grafting; Shampoo holds statistics *and* roots, so more.

use crate::models::NetworkInventory;

/// Memory accounting keys on the algorithm alone — sharding moves
/// refresh work between workers, not state between optimizers.
pub use crate::optim::OptAlgo as OptKind;

/// Optimizer state floats for `net`, with/without grafting for the
/// second-order methods.
pub fn state_floats(net: &NetworkInventory, opt: OptKind, grafting: bool) -> usize {
    let pcount = net.param_count();
    match opt {
        OptKind::Sgd => pcount,
        OptKind::AdamW => 2 * pcount,
        OptKind::Jorge => {
            let mut total = pcount; // momentum
            if grafting {
                total += pcount; // sgd momentum
            }
            for l in &net.layers {
                if l.preconditioned() {
                    total += l.m * l.m + l.n * l.n; // L^, R^
                }
            }
            total
        }
        OptKind::Shampoo => {
            let mut total = pcount;
            if grafting {
                total += pcount;
            }
            for l in &net.layers {
                if l.preconditioned() {
                    total += 2 * (l.m * l.m + l.n * l.n); // stats + roots
                }
            }
            total
        }
    }
}

/// Bytes (f32) for a human-readable report.
pub fn state_bytes(net: &NetworkInventory, opt: OptKind, grafting: bool) -> usize {
    4 * state_floats(net, opt, grafting)
}

/// Ratio of an optimizer's state to Adam's (the paper's A.6 metric).
pub fn ratio_vs_adam(net: &NetworkInventory, opt: OptKind, grafting: bool) -> f64 {
    state_floats(net, opt, grafting) as f64 / state_floats(net, OptKind::AdamW, false) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{resnet50, LayerShape, NetworkInventory};

    #[test]
    fn adam_is_2x_params() {
        let net = resnet50();
        assert_eq!(state_floats(&net, OptKind::AdamW, false), 2 * net.param_count());
    }

    #[test]
    fn jorge_on_resnet50_is_in_paper_band() {
        // Paper A.6: Jorge = 1.5x Adam without grafting, 2x with — counting
        // L^+R^ as one param-worth of state, which holds in the square-
        // blocked limit (m^2 + n^2 -> 2mn at m = n). With the standard
        // 512-blocking, ResNet-50 lands at ~1.6x / ~2.1x.
        let net = resnet50().blocked(512);
        let without = ratio_vs_adam(&net, OptKind::Jorge, false);
        let with = ratio_vs_adam(&net, OptKind::Jorge, true);
        assert!(without < with);
        assert!((1.4..=1.8).contains(&without), "{without}");
        assert!((1.9..=2.3).contains(&with), "{with}");
    }

    #[test]
    fn shampoo_heavier_than_jorge() {
        let net = resnet50().blocked(1024);
        assert!(
            state_floats(&net, OptKind::Shampoo, true)
                > state_floats(&net, OptKind::Jorge, true)
        );
    }

    #[test]
    fn square_layer_worst_case() {
        // single square layer (n,n): jorge+grafting = 2n^2 (momenta) + 2n^2
        // (precond) = 4n^2 = 2x Adam — the paper's upper bound.
        let net = NetworkInventory {
            name: "square".into(),
            layers: vec![LayerShape::new("w", 64, 64)],
        };
        let r = ratio_vs_adam(&net, OptKind::Jorge, true);
        assert!((r - 2.0).abs() < 1e-9, "{r}");
    }

    #[test]
    fn parse_names() {
        assert_eq!(OptKind::parse("jorge"), Some(OptKind::Jorge));
        assert_eq!(OptKind::parse("adam"), Some(OptKind::AdamW));
        assert_eq!(OptKind::parse("x"), None);
    }
}
