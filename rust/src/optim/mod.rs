//! Native optimizer mirrors of the four L2/JAX optimizers.
//!
//! These exist for three reasons:
//! 1. **cross-validation** — integration tests drive identical inputs
//!    through the HLO artifacts (via `runtime`) and these mirrors and
//!    assert agreement, which pins the artifact semantics;
//! 2. **microbenchmarks** — Table 1 runs the per-iteration optimizer op
//!    mix over the paper's real layer inventories (`models`), where the
//!    HLO artifacts (fixed shapes) cannot;
//! 3. **the `--native` coordinator path** — data-parallel runs apply the
//!    optimizer natively after the gradient all-reduce, and the sharded
//!    variants (`shampoo_sharded` / `jorge_sharded`) partition the
//!    preconditioner refreshes across workers through the split
//!    refresh/apply protocol on [`Optimizer`].
//!
//! The semantics mirror `python/compile/optim_jax.py` exactly, including
//! the grafted weight update (App. A.2), dynamic beta2 (App. A.1),
//! decoupled-vs-coupled weight decay and the skip-step behaviour.

pub mod adamw;
pub mod jorge;
pub mod memory;
pub mod schedules;
pub mod sgd;
pub mod shampoo;

pub use adamw::AdamW;
pub use jorge::Jorge;
pub use schedules::Schedule;
pub use sgd::Sgd;
pub use shampoo::Shampoo;

use crate::tensor::Matrix;
use std::fmt;
use std::str::FromStr;

// ---------------------------------------------------------------------------
// Typed optimizer selection
// ---------------------------------------------------------------------------

/// The optimizer algorithm family — pure math, no execution-mode bits.
/// This is what artifact names, memory accounting and the perf model key
/// on (re-exported as `memory::OptKind` for those callers).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OptAlgo {
    Sgd,
    AdamW,
    Shampoo,
    Jorge,
}

impl OptAlgo {
    /// Canonical name; also the artifact-name component.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Sgd => "sgd",
            Self::AdamW => "adamw",
            Self::Shampoo => "shampoo",
            Self::Jorge => "jorge",
        }
    }

    /// Parse a bare algorithm name (`adam` accepted as an alias).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sgd" => Some(Self::Sgd),
            "adamw" | "adam" => Some(Self::AdamW),
            "shampoo" => Some(Self::Shampoo),
            "jorge" => Some(Self::Jorge),
            _ => None,
        }
    }

    /// Second-order methods keep per-layer preconditioners, so they have
    /// `_skip` executable variants and shardable refresh work.
    pub fn second_order(&self) -> bool {
        matches!(self, Self::Shampoo | Self::Jorge)
    }
}

/// Typed optimizer selection: the algorithm plus whether preconditioner
/// refresh work is sharded across data-parallel workers (dist-Shampoo
/// style owner-computes; see `coordinator::trainer`). Sharding changes
/// *where* refreshes run, never the math — trajectories are bitwise
/// identical to the serial algorithm at any worker count.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct OptimizerKind {
    pub algo: OptAlgo,
    pub sharded: bool,
}

impl OptimizerKind {
    pub const SGD: Self = OptimizerKind { algo: OptAlgo::Sgd, sharded: false };
    pub const ADAMW: Self = OptimizerKind { algo: OptAlgo::AdamW, sharded: false };
    pub const SHAMPOO: Self = OptimizerKind { algo: OptAlgo::Shampoo, sharded: false };
    pub const JORGE: Self = OptimizerKind { algo: OptAlgo::Jorge, sharded: false };
    pub const SHAMPOO_SHARDED: Self = OptimizerKind { algo: OptAlgo::Shampoo, sharded: true };
    pub const JORGE_SHARDED: Self = OptimizerKind { algo: OptAlgo::Jorge, sharded: true };

    /// Every accepted kind, for help strings and validation errors.
    pub const ALL: [Self; 6] = [
        Self::SGD,
        Self::ADAMW,
        Self::SHAMPOO,
        Self::JORGE,
        Self::SHAMPOO_SHARDED,
        Self::JORGE_SHARDED,
    ];

    /// The same algorithm without sharding.
    pub fn serial(self) -> Self {
        OptimizerKind { sharded: false, ..self }
    }

    /// Artifact/manifest name component. Sharding never changes the math,
    /// so sharded kinds load the same executables as their serial base.
    pub fn base_name(self) -> &'static str {
        self.algo.name()
    }

    /// Whether `train_*_skip` / `apply_*_skip` executables exist.
    pub fn has_skip(self) -> bool {
        self.algo.second_order()
    }

    /// `"sgd | adamw | ... | jorge_sharded"` for CLI help and errors.
    pub fn choices() -> String {
        Self::ALL.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(" | ")
    }
}

impl FromStr for OptimizerKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        let (base, sharded) = match s.strip_suffix("_sharded") {
            Some(b) => (b, true),
            None => (s, false),
        };
        let algo = OptAlgo::parse(base).ok_or_else(|| {
            format!("unknown optimizer {s:?} (choose {})", Self::choices())
        })?;
        if sharded && !algo.second_order() {
            return Err(format!(
                "{s:?}: only the second-order optimizers (shampoo, jorge) shard \
                 preconditioner work"
            ));
        }
        Ok(OptimizerKind { algo, sharded })
    }
}

impl fmt::Display for OptimizerKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.algo.name())?;
        if self.sharded {
            f.write_str("_sharded")?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Hyperparameters: flat wire format + typed per-optimizer views
// ---------------------------------------------------------------------------

/// Hyperparameters shared with the artifacts (manifest `hyper` section).
/// This is the flat *wire format*; the optimizers themselves hold the
/// typed views below ([`SgdParams`], [`AdamWParams`], [`ShampooParams`],
/// [`JorgeParams`]), and `From<&Hyper>` conversions keep configs and the
/// SGD-to-Jorge bootstrap rule working unchanged.
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub beta1: f32,
    pub sgd_momentum: f32,
    pub shampoo_beta2: f32,
    pub precond_eps: f32,
    pub newton_iters: usize,
    pub adam_beta1: f32,
    pub adam_beta2: f32,
    pub adam_eps: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            beta1: 0.9,
            sgd_momentum: 0.9,
            shampoo_beta2: 0.95,
            precond_eps: 1e-6,
            newton_iters: 15,
            adam_beta1: 0.9,
            adam_beta2: 0.999,
            adam_eps: 1e-8,
        }
    }
}

impl Hyper {
    /// Assemble a `Hyper` from the typed per-optimizer param structs.
    pub fn builder() -> HyperBuilder {
        HyperBuilder { h: Hyper::default() }
    }
}

/// Builder assembling the flat [`Hyper`] wire format from typed params.
/// The grafting knobs (`beta1`, `sgd_momentum`) and `precond_eps` are
/// shared between Shampoo and Jorge in the wire format, so when both are
/// set the last setter wins for those fields.
#[derive(Clone, Copy, Debug)]
pub struct HyperBuilder {
    h: Hyper,
}

impl HyperBuilder {
    pub fn sgd(mut self, p: SgdParams) -> Self {
        self.h.sgd_momentum = p.momentum;
        self
    }

    pub fn adamw(mut self, p: AdamWParams) -> Self {
        self.h.adam_beta1 = p.beta1;
        self.h.adam_beta2 = p.beta2;
        self.h.adam_eps = p.eps;
        self
    }

    pub fn shampoo(mut self, p: ShampooParams) -> Self {
        self.h.beta1 = p.graft.beta1;
        self.h.sgd_momentum = p.graft.sgd_momentum;
        self.h.shampoo_beta2 = p.beta2;
        self.h.precond_eps = p.eps;
        self.h.newton_iters = p.newton_iters;
        self
    }

    pub fn jorge(mut self, p: JorgeParams) -> Self {
        self.h.beta1 = p.graft.beta1;
        self.h.sgd_momentum = p.graft.sgd_momentum;
        self.h.precond_eps = p.eps;
        self
    }

    pub fn build(self) -> Hyper {
        self.h
    }
}

/// Grafting knobs for the shared weight update (App. A.2, Algorithm 3):
/// direction momentum rate + heavy-ball magnitude momentum rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GraftParams {
    pub beta1: f32,
    pub sgd_momentum: f32,
}

/// Heavy-ball SGD.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SgdParams {
    pub momentum: f32,
}

/// AdamW with decoupled weight decay.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdamWParams {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

/// Shampoo: gram-statistic EMA + inverse fourth roots.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShampooParams {
    pub graft: GraftParams,
    /// Gram-statistic EMA rate (Alg. 1).
    pub beta2: f32,
    pub eps: f32,
    pub newton_iters: usize,
}

/// Jorge: inverse-free truncated-binomial preconditioner refresh.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JorgeParams {
    pub graft: GraftParams,
    pub eps: f32,
}

impl From<&Hyper> for GraftParams {
    fn from(h: &Hyper) -> Self {
        GraftParams { beta1: h.beta1, sgd_momentum: h.sgd_momentum }
    }
}

impl From<&Hyper> for SgdParams {
    fn from(h: &Hyper) -> Self {
        SgdParams { momentum: h.sgd_momentum }
    }
}

impl From<&Hyper> for AdamWParams {
    fn from(h: &Hyper) -> Self {
        AdamWParams { beta1: h.adam_beta1, beta2: h.adam_beta2, eps: h.adam_eps }
    }
}

impl From<&Hyper> for ShampooParams {
    fn from(h: &Hyper) -> Self {
        ShampooParams {
            graft: h.into(),
            beta2: h.shampoo_beta2,
            eps: h.precond_eps,
            newton_iters: h.newton_iters,
        }
    }
}

impl From<&Hyper> for JorgeParams {
    fn from(h: &Hyper) -> Self {
        JorgeParams { graft: h.into(), eps: h.precond_eps }
    }
}

impl Default for SgdParams {
    fn default() -> Self {
        (&Hyper::default()).into()
    }
}

impl Default for AdamWParams {
    fn default() -> Self {
        (&Hyper::default()).into()
    }
}

impl Default for ShampooParams {
    fn default() -> Self {
        (&Hyper::default()).into()
    }
}

impl Default for JorgeParams {
    fn default() -> Self {
        (&Hyper::default()).into()
    }
}

impl Default for GraftParams {
    fn default() -> Self {
        (&Hyper::default()).into()
    }
}

/// Outer-vs-inner parallelism pivot for the second-order optimizers: on
/// preconditioner-refresh steps, a layer whose stat/preconditioner edge
/// reaches this size dominates the step, so layers run serially and that
/// layer's GEMMs get the whole pool. Below it (e.g. the 256-blocked
/// paper inventories), independent layers fan out across the pool and
/// dynamic task claiming load-balances the tail.
pub(crate) const INNER_PAR_DIM: usize = 384;

/// Largest square edge among per-layer optional matrices (preconditioner
/// or gram-stat slots) — the size [`INNER_PAR_DIM`] gates on.
pub(crate) fn max_dim<'a>(mats: impl Iterator<Item = Option<&'a Matrix>>) -> usize {
    mats.flatten().map(|m| m.rows).max().unwrap_or(0)
}

/// Apply an independent per-layer update: serially when `serial` (a
/// dominant refresh wants the pool for its own GEMMs), otherwise fanned
/// across the worker pool.
pub(crate) fn for_each_layer<S: Send>(
    params: &mut [Matrix],
    states: &mut [S],
    serial: bool,
    f: impl Fn(usize, &mut Matrix, &mut S) + Sync,
) {
    if serial {
        for (li, (p, st)) in params.iter_mut().zip(states.iter_mut()).enumerate() {
            f(li, p, st);
        }
    } else {
        crate::tensor::parallel_zip_mut(params, states, f);
    }
}

// ---------------------------------------------------------------------------
// Numerical guardrails
// ---------------------------------------------------------------------------

/// Counters for the second-order numerical guardrails: every recovery
/// action taken instead of propagating a NaN/Inf (or panicking). Summed
/// across layers by [`Optimizer::guard_report`] and surfaced in the
/// coordinator's `RunResult`. All zeros on a healthy run — the guarded
/// paths are float-for-float identical to the unguarded ones for finite
/// inputs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GuardReport {
    /// Layer-steps where the incoming gradient was non-finite.
    pub nonfinite_grads: usize,
    /// Gram statistics rejected before the EMA (Shampoo — one poisoned
    /// stat would otherwise contaminate every later refresh).
    pub rejected_stats: usize,
    /// Refreshes redone once with extra damping (downscaled gram /
    /// bumped ridge) after the first attempt went non-finite.
    pub damped_retries: usize,
    /// Refreshes abandoned entirely — the stale preconditioner was kept
    /// (sound degradation; Anil et al. 2021).
    pub stale_preconds: usize,
    /// Non-finite preconditioner estimates self-healed by resetting to
    /// the eps-identity initialization.
    pub precond_resets: usize,
    /// Applies that fell back to the grafted first-order direction
    /// because the preconditioned gradient was non-finite.
    pub graft_fallbacks: usize,
    /// Layer updates skipped outright (non-finite gradient: no momentum
    /// EMA, no decay — the layer freezes for that step).
    pub skipped_updates: usize,
}

impl GuardReport {
    pub fn merge(&mut self, o: &GuardReport) {
        self.nonfinite_grads += o.nonfinite_grads;
        self.rejected_stats += o.rejected_stats;
        self.damped_retries += o.damped_retries;
        self.stale_preconds += o.stale_preconds;
        self.precond_resets += o.precond_resets;
        self.graft_fallbacks += o.graft_fallbacks;
        self.skipped_updates += o.skipped_updates;
    }

    /// Total recovery actions (0 ⇔ nothing fired).
    pub fn total(&self) -> usize {
        self.nonfinite_grads
            + self.rejected_stats
            + self.damped_retries
            + self.stale_preconds
            + self.precond_resets
            + self.graft_fallbacks
            + self.skipped_updates
    }

    /// Every counter as a `(name, value)` pair, in declaration order —
    /// how the trace registry folds guardrails into the unified metrics.
    pub fn counter_pairs(&self) -> [(&'static str, usize); 7] {
        [
            ("nonfinite_grads", self.nonfinite_grads),
            ("rejected_stats", self.rejected_stats),
            ("damped_retries", self.damped_retries),
            ("stale_preconds", self.stale_preconds),
            ("precond_resets", self.precond_resets),
            ("graft_fallbacks", self.graft_fallbacks),
            ("skipped_updates", self.skipped_updates),
        ]
    }
}

impl fmt::Display for GuardReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "nonfinite_grads={} rejected_stats={} damped_retries={} stale_preconds={} \
             precond_resets={} graft_fallbacks={} skipped_updates={}",
            self.nonfinite_grads,
            self.rejected_stats,
            self.damped_retries,
            self.stale_preconds,
            self.precond_resets,
            self.graft_fallbacks,
            self.skipped_updates
        )
    }
}

/// A training-step context supplied by the coordinator.
#[derive(Clone, Copy, Debug)]
pub struct StepCtx {
    pub lr: f32,
    pub weight_decay: f32,
    /// Whether this step refreshes the preconditioners (update-interval
    /// policy lives in the coordinator, matching the paper §3).
    pub update_precond: bool,
}

/// Common interface over the four optimizers.
///
/// Beyond the fused [`step`](Optimizer::step), second-order optimizers
/// implement the split refresh/apply protocol that the sharded
/// coordinator path uses: `refresh_layers(all layers)` followed by
/// `apply_update` must be bitwise identical to `step`, because per-layer
/// work is independent and each half runs float-for-float the same ops
/// the fused step would. First-order optimizers have no refresh work and
/// inherit the no-op defaults.
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;

    /// Apply one step in place. `params[i]` and `grads[i]` are the 2-D
    /// collapsed matrices in model order.
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], ctx: StepCtx);

    /// Total optimizer-state floats currently held (App. A.6 accounting).
    fn state_floats(&self) -> usize;

    /// Expose flat state for checkpointing / cross-validation.
    fn state_mut(&mut self) -> Vec<&mut Matrix>;

    /// Scalar step counter, for optimizers with bias correction (AdamW).
    /// The native backend round-trips it alongside the matrix state so
    /// stateless step execution preserves trajectories exactly.
    fn step_count(&self) -> u64 {
        0
    }

    /// Restore the step counter (no-op for counter-free optimizers).
    fn set_step_count(&mut self, _t: u64) {}

    /// Number of per-layer slots (== the `params.len()` passed to `step`).
    fn n_layers(&self) -> usize;

    /// FLOPs of one preconditioner refresh for `layer`; 0 when the layer
    /// carries no preconditioner. Drives the owner-computes assignment's
    /// load balancing in the sharded coordinator path.
    fn refresh_flops(&self, _layer: usize) -> f64 {
        0.0
    }

    /// Owner-computes half of a step, restricted to `layers`: accumulate
    /// gram statistics (every call, where the algorithm does) and, when
    /// `update_precond`, refresh those layers' preconditioners.
    fn refresh_layers(&mut self, _layers: &[usize], _grads: &[Matrix], _update_precond: bool) {}

    /// Apply half of a step: the parameter update using the current
    /// preconditioners, never refreshing or re-accumulating statistics.
    /// The default covers first-order optimizers, where the whole step
    /// *is* the apply.
    fn apply_update(&mut self, params: &mut [Matrix], grads: &[Matrix], ctx: StepCtx) {
        self.step(params, grads, StepCtx { update_precond: false, ..ctx });
    }

    /// Flat-serialise the preconditioners of `layers`, in the given
    /// order — the all-gather payload. Empty for first-order optimizers
    /// and for layers without preconditioners.
    fn export_preconditioners(&self, _layers: &[usize]) -> Vec<f32> {
        Vec::new()
    }

    /// Inverse of [`export_preconditioners`](Optimizer::export_preconditioners);
    /// returns the number of floats consumed from `data`.
    fn import_preconditioners(&mut self, _layers: &[usize], _data: &[f32]) -> usize {
        0
    }

    /// Accumulated numerical-guardrail counters (all zero for the
    /// first-order optimizers and on healthy second-order runs).
    fn guard_report(&self) -> GuardReport {
        GuardReport::default()
    }
}

/// Construct an optimizer for a parameter inventory. The `sharded` flag
/// on `kind` selects the coordinator's execution mode, not different
/// math, so it does not change the state built here.
pub fn build(kind: OptimizerKind, shapes: &[(usize, usize)], hyper: Hyper) -> Box<dyn Optimizer> {
    match kind.algo {
        OptAlgo::Sgd => Box::new(Sgd::new(shapes, hyper)),
        OptAlgo::AdamW => Box::new(AdamW::new(shapes, hyper)),
        OptAlgo::Shampoo => Box::new(Shampoo::new(shapes, hyper)),
        OptAlgo::Jorge => Box::new(Jorge::new(shapes, hyper)),
    }
}

/// Shared grafted weight update (App. A.2, Algorithm 3):
/// direction from the preconditioned momentum, magnitude from heavy-ball
/// SGD momentum; weight decay either decoupled (Jorge) or coupled L2
/// folded into the grafting gradient (Shampoo/SGD).
#[allow(clippy::too_many_arguments)]
pub(crate) fn grafted_update(
    p: &mut Matrix,
    g: &Matrix,
    gtilde: &Matrix,
    mom: &mut Matrix,
    gmom: &mut Matrix,
    ctx: StepCtx,
    graft: GraftParams,
    decoupled: bool,
) {
    // g_sgd = g (+ wd * p when coupled)
    // mom   = b1 mom + (1-b1) gtilde
    // gmom  = b_sgd gmom + g_sgd
    // p    -= lr * ||gmom|| * mom / ||mom||   (- lr * wd * p when decoupled)
    let n = p.data.len();
    for i in 0..n {
        let gs = if decoupled { g.data[i] } else { g.data[i] + ctx.weight_decay * p.data[i] };
        mom.data[i] = graft.beta1 * mom.data[i] + (1.0 - graft.beta1) * gtilde.data[i];
        gmom.data[i] = graft.sgd_momentum * gmom.data[i] + gs;
    }
    let gnorm = gmom.frobenius() as f32;
    let mnorm = (mom.frobenius() as f32).max(1e-16);
    let scale = ctx.lr * gnorm / mnorm;
    let wd_mult = if decoupled { 1.0 - ctx.lr * ctx.weight_decay } else { 1.0 };
    for i in 0..n {
        p.data[i] = p.data[i] * wd_mult - scale * mom.data[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_kinds() {
        let shapes = [(8, 4), (4, 1)];
        for kind in OptimizerKind::ALL {
            let o = build(kind, &shapes, Hyper::default());
            assert_eq!(o.name(), kind.base_name());
            assert_eq!(o.n_layers(), 2);
        }
    }

    #[test]
    fn kind_parses_and_displays_round_trip() {
        for kind in OptimizerKind::ALL {
            let s = kind.to_string();
            assert_eq!(s.parse::<OptimizerKind>().unwrap(), kind, "{s}");
        }
        assert_eq!("adam".parse::<OptimizerKind>().unwrap(), OptimizerKind::ADAMW);
        assert!("nope".parse::<OptimizerKind>().is_err());
        // first-order methods have no preconditioners to shard
        assert!("sgd_sharded".parse::<OptimizerKind>().is_err());
        assert!("adamw_sharded".parse::<OptimizerKind>().is_err());
        assert_eq!(OptimizerKind::JORGE_SHARDED.serial(), OptimizerKind::JORGE);
        assert_eq!(OptimizerKind::JORGE_SHARDED.base_name(), "jorge");
        assert!(OptimizerKind::choices().contains("jorge_sharded"));
    }

    #[test]
    fn hyper_builder_matches_flat_defaults() {
        let h = Hyper::builder()
            .sgd(SgdParams::default())
            .adamw(AdamWParams::default())
            .shampoo(ShampooParams::default())
            .jorge(JorgeParams::default())
            .build();
        let d = Hyper::default();
        assert_eq!(h.beta1, d.beta1);
        assert_eq!(h.sgd_momentum, d.sgd_momentum);
        assert_eq!(h.shampoo_beta2, d.shampoo_beta2);
        assert_eq!(h.precond_eps, d.precond_eps);
        assert_eq!(h.newton_iters, d.newton_iters);
        assert_eq!(h.adam_beta1, d.adam_beta1);
        assert_eq!(h.adam_beta2, d.adam_beta2);
        assert_eq!(h.adam_eps, d.adam_eps);
    }

    #[test]
    fn hyper_builder_routes_typed_params() {
        let h = Hyper::builder()
            .adamw(AdamWParams { beta1: 0.8, beta2: 0.95, eps: 1e-7 })
            .jorge(JorgeParams {
                graft: GraftParams { beta1: 0.85, sgd_momentum: 0.8 },
                eps: 1e-5,
            })
            .build();
        assert_eq!(h.adam_beta1, 0.8);
        assert_eq!(h.adam_beta2, 0.95);
        assert_eq!(h.adam_eps, 1e-7);
        assert_eq!(h.beta1, 0.85);
        assert_eq!(h.sgd_momentum, 0.8);
        assert_eq!(h.precond_eps, 1e-5);
        // round-trips back through the typed views
        assert_eq!(JorgeParams::from(&h).graft.beta1, 0.85);
        assert_eq!(AdamWParams::from(&h).beta2, 0.95);
    }

    #[test]
    fn grafted_first_step_magnitude_is_sgd() {
        let mut rng = crate::rngx::Rng::new(0);
        let mut p = Matrix::randn(6, 4, 1.0, &mut rng);
        let p0 = p.clone();
        let g = Matrix::randn(6, 4, 0.1, &mut rng);
        let gtilde = Matrix::randn(6, 4, 3.0, &mut rng); // arbitrary direction
        let mut mom = Matrix::zeros(6, 4);
        let mut gmom = Matrix::zeros(6, 4);
        let ctx = StepCtx { lr: 0.05, weight_decay: 0.0, update_precond: true };
        grafted_update(&mut p, &g, &gtilde, &mut mom, &mut gmom, ctx, GraftParams::default(), true);
        let step_norm = p.sub(&p0).frobenius();
        let want = 0.05 * g.frobenius();
        assert!(
            (step_norm - want).abs() / want < 1e-4,
            "{step_norm} vs {want}"
        );
    }

    #[test]
    fn grafted_direction_is_gtilde_on_first_step() {
        let mut rng = crate::rngx::Rng::new(1);
        let mut p = Matrix::zeros(5, 3);
        let g = Matrix::randn(5, 3, 0.1, &mut rng);
        let gtilde = Matrix::randn(5, 3, 1.0, &mut rng);
        let mut mom = Matrix::zeros(5, 3);
        let mut gmom = Matrix::zeros(5, 3);
        let ctx = StepCtx { lr: 1.0, weight_decay: 0.0, update_precond: true };
        grafted_update(&mut p, &g, &gtilde, &mut mom, &mut gmom, ctx, GraftParams::default(), true);
        // p = -c * gtilde for some c > 0
        let c = -p.data[0] / gtilde.data[0];
        assert!(c > 0.0);
        for i in 0..p.data.len() {
            assert!((p.data[i] + c * gtilde.data[i]).abs() < 1e-5 * c.max(1.0));
        }
    }

    #[test]
    fn split_refresh_apply_matches_fused_step_bitwise() {
        // The contract the sharded coordinator path rests on:
        // refresh_layers(all) + apply_update == step, float for float.
        let shapes = [(6usize, 4usize), (4, 1), (5, 3)];
        let all: Vec<usize> = (0..shapes.len()).collect();
        for kind in [OptimizerKind::SHAMPOO, OptimizerKind::JORGE] {
            let mut fused = build(kind, &shapes, Hyper::default());
            let mut split = build(kind, &shapes, Hyper::default());
            let mut rng = crate::rngx::Rng::new(11);
            let mut p_a: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 1.0, &mut rng)).collect();
            let mut p_b = p_a.clone();
            let mut grng = crate::rngx::Rng::new(12);
            for step in 0..6 {
                let grads: Vec<Matrix> =
                    shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut grng)).collect();
                let ctx = StepCtx {
                    lr: 0.05,
                    weight_decay: 1e-3,
                    update_precond: step % 2 == 0,
                };
                fused.step(&mut p_a, &grads, ctx);
                split.refresh_layers(&all, &grads, ctx.update_precond);
                split.apply_update(&mut p_b, &grads, ctx);
                for (a, b) in p_a.iter().zip(&p_b) {
                    assert_eq!(a.data, b.data, "{kind} step {step} diverged");
                }
            }
        }
    }

    #[test]
    fn preconditioner_export_import_round_trips() {
        let shapes = [(6usize, 4usize), (4, 1), (5, 3)];
        for kind in [OptimizerKind::SHAMPOO, OptimizerKind::JORGE] {
            let mut opt = build(kind, &shapes, Hyper::default());
            let mut rng = crate::rngx::Rng::new(3);
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();
            opt.refresh_layers(&[0, 1, 2], &grads, true);
            let blob = opt.export_preconditioners(&[0, 2]);
            assert!(!blob.is_empty(), "{kind}");
            // bias layer (index 1) contributes nothing
            assert!(opt.export_preconditioners(&[1]).is_empty(), "{kind}");
            let used = opt.import_preconditioners(&[0, 2], &blob);
            assert_eq!(used, blob.len(), "{kind}");
            assert_eq!(opt.export_preconditioners(&[0, 2]), blob, "{kind}");
            // refresh cost: preconditioned layers > 0, bias layer == 0
            assert!(opt.refresh_flops(0) > 0.0, "{kind}");
            assert_eq!(opt.refresh_flops(1), 0.0, "{kind}");
        }
    }
}
