//! Native optimizer mirrors of the four L2/JAX optimizers.
//!
//! These exist for three reasons:
//! 1. **cross-validation** — integration tests drive identical inputs
//!    through the HLO artifacts (via `runtime`) and these mirrors and
//!    assert agreement, which pins the artifact semantics;
//! 2. **microbenchmarks** — Table 1 runs the per-iteration optimizer op
//!    mix over the paper's real layer inventories (`models`), where the
//!    HLO artifacts (fixed shapes) cannot;
//! 3. **the `--native` coordinator path** — data-parallel runs apply the
//!    optimizer natively after the gradient all-reduce.
//!
//! The semantics mirror `python/compile/optim_jax.py` exactly, including
//! the grafted weight update (App. A.2), dynamic beta2 (App. A.1),
//! decoupled-vs-coupled weight decay and the skip-step behaviour.

pub mod adamw;
pub mod jorge;
pub mod memory;
pub mod schedules;
pub mod sgd;
pub mod shampoo;

pub use adamw::AdamW;
pub use jorge::Jorge;
pub use schedules::Schedule;
pub use sgd::Sgd;
pub use shampoo::Shampoo;

use crate::tensor::Matrix;

/// Hyperparameters shared with the artifacts (manifest `hyper` section).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub beta1: f32,
    pub sgd_momentum: f32,
    pub shampoo_beta2: f32,
    pub precond_eps: f32,
    pub newton_iters: usize,
    pub adam_beta1: f32,
    pub adam_beta2: f32,
    pub adam_eps: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            beta1: 0.9,
            sgd_momentum: 0.9,
            shampoo_beta2: 0.95,
            precond_eps: 1e-6,
            newton_iters: 15,
            adam_beta1: 0.9,
            adam_beta2: 0.999,
            adam_eps: 1e-8,
        }
    }
}

/// Outer-vs-inner parallelism pivot for the second-order optimizers: on
/// preconditioner-refresh steps, a layer whose stat/preconditioner edge
/// reaches this size dominates the step, so layers run serially and that
/// layer's GEMMs get the whole pool. Below it (e.g. the 256-blocked
/// paper inventories), independent layers fan out across the pool and
/// dynamic task claiming load-balances the tail.
pub(crate) const INNER_PAR_DIM: usize = 384;

/// Largest square edge among per-layer optional matrices (preconditioner
/// or gram-stat slots) — the size [`INNER_PAR_DIM`] gates on.
pub(crate) fn max_dim<'a>(mats: impl Iterator<Item = Option<&'a Matrix>>) -> usize {
    mats.flatten().map(|m| m.rows).max().unwrap_or(0)
}

/// Apply an independent per-layer update: serially when `serial` (a
/// dominant refresh wants the pool for its own GEMMs), otherwise fanned
/// across the worker pool.
pub(crate) fn for_each_layer<S: Send>(
    params: &mut [Matrix],
    states: &mut [S],
    serial: bool,
    f: impl Fn(usize, &mut Matrix, &mut S) + Sync,
) {
    if serial {
        for (li, (p, st)) in params.iter_mut().zip(states.iter_mut()).enumerate() {
            f(li, p, st);
        }
    } else {
        crate::tensor::parallel_zip_mut(params, states, f);
    }
}

/// A training-step context supplied by the coordinator.
#[derive(Clone, Copy, Debug)]
pub struct StepCtx {
    pub lr: f32,
    pub weight_decay: f32,
    /// Whether this step refreshes the preconditioners (update-interval
    /// policy lives in the coordinator, matching the paper §3).
    pub update_precond: bool,
}

/// Common interface over the four optimizers.
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;

    /// Apply one step in place. `params[i]` and `grads[i]` are the 2-D
    /// collapsed matrices in model order.
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], ctx: StepCtx);

    /// Total optimizer-state floats currently held (App. A.6 accounting).
    fn state_floats(&self) -> usize;

    /// Expose flat state for checkpointing / cross-validation.
    fn state_mut(&mut self) -> Vec<&mut Matrix>;

    /// Scalar step counter, for optimizers with bias correction (AdamW).
    /// The native backend round-trips it alongside the matrix state so
    /// stateless step execution preserves trajectories exactly.
    fn step_count(&self) -> u64 {
        0
    }

    /// Restore the step counter (no-op for counter-free optimizers).
    fn set_step_count(&mut self, _t: u64) {}
}

/// Construct an optimizer by name for a given parameter inventory.
pub fn build(
    name: &str,
    shapes: &[(usize, usize)],
    hyper: Hyper,
) -> Result<Box<dyn Optimizer>, String> {
    match name {
        "sgd" => Ok(Box::new(Sgd::new(shapes, hyper))),
        "adamw" => Ok(Box::new(AdamW::new(shapes, hyper))),
        "shampoo" => Ok(Box::new(Shampoo::new(shapes, hyper))),
        "jorge" => Ok(Box::new(Jorge::new(shapes, hyper))),
        other => Err(format!("unknown optimizer {other:?}")),
    }
}

/// Shared grafted weight update (App. A.2, Algorithm 3):
/// direction from the preconditioned momentum, magnitude from heavy-ball
/// SGD momentum; weight decay either decoupled (Jorge) or coupled L2
/// folded into the grafting gradient (Shampoo/SGD).
#[allow(clippy::too_many_arguments)]
pub(crate) fn grafted_update(
    p: &mut Matrix,
    g: &Matrix,
    gtilde: &Matrix,
    mom: &mut Matrix,
    gmom: &mut Matrix,
    ctx: StepCtx,
    hyper: Hyper,
    decoupled: bool,
) {
    // g_sgd = g (+ wd * p when coupled)
    // mom   = b1 mom + (1-b1) gtilde
    // gmom  = b_sgd gmom + g_sgd
    // p    -= lr * ||gmom|| * mom / ||mom||   (- lr * wd * p when decoupled)
    let n = p.data.len();
    for i in 0..n {
        let gs = if decoupled { g.data[i] } else { g.data[i] + ctx.weight_decay * p.data[i] };
        mom.data[i] = hyper.beta1 * mom.data[i] + (1.0 - hyper.beta1) * gtilde.data[i];
        gmom.data[i] = hyper.sgd_momentum * gmom.data[i] + gs;
    }
    let gnorm = gmom.frobenius() as f32;
    let mnorm = (mom.frobenius() as f32).max(1e-16);
    let scale = ctx.lr * gnorm / mnorm;
    let wd_mult = if decoupled { 1.0 - ctx.lr * ctx.weight_decay } else { 1.0 };
    for i in 0..n {
        p.data[i] = p.data[i] * wd_mult - scale * mom.data[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_by_name() {
        let shapes = [(8, 4), (4, 1)];
        for name in ["sgd", "adamw", "shampoo", "jorge"] {
            let o = build(name, &shapes, Hyper::default()).unwrap();
            assert_eq!(o.name(), name);
        }
        assert!(build("nope", &shapes, Hyper::default()).is_err());
    }

    #[test]
    fn grafted_first_step_magnitude_is_sgd() {
        let mut rng = crate::rngx::Rng::new(0);
        let mut p = Matrix::randn(6, 4, 1.0, &mut rng);
        let p0 = p.clone();
        let g = Matrix::randn(6, 4, 0.1, &mut rng);
        let gtilde = Matrix::randn(6, 4, 3.0, &mut rng); // arbitrary direction
        let mut mom = Matrix::zeros(6, 4);
        let mut gmom = Matrix::zeros(6, 4);
        let ctx = StepCtx { lr: 0.05, weight_decay: 0.0, update_precond: true };
        grafted_update(&mut p, &g, &gtilde, &mut mom, &mut gmom, ctx, Hyper::default(), true);
        let step_norm = p.sub(&p0).frobenius();
        let want = 0.05 * g.frobenius();
        assert!(
            (step_norm - want).abs() / want < 1e-4,
            "{step_norm} vs {want}"
        );
    }

    #[test]
    fn grafted_direction_is_gtilde_on_first_step() {
        let mut rng = crate::rngx::Rng::new(1);
        let mut p = Matrix::zeros(5, 3);
        let g = Matrix::randn(5, 3, 0.1, &mut rng);
        let gtilde = Matrix::randn(5, 3, 1.0, &mut rng);
        let mut mom = Matrix::zeros(5, 3);
        let mut gmom = Matrix::zeros(5, 3);
        let ctx = StepCtx { lr: 1.0, weight_decay: 0.0, update_precond: true };
        grafted_update(&mut p, &g, &gtilde, &mut mom, &mut gmom, ctx, Hyper::default(), true);
        // p = -c * gtilde for some c > 0
        let c = -p.data[0] / gtilde.data[0];
        assert!(c > 0.0);
        for i in 0..p.data.len() {
            assert!((p.data[i] + c * gtilde.data[i]).abs() < 1e-5 * c.max(1.0));
        }
    }
}
