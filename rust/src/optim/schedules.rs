//! Learning-rate schedules (§4 / Fig. 1 / Fig. 4 of the paper).
//!
//! The Rust coordinator owns the schedule: the HLO train-step artifacts
//! take the learning rate as a runtime scalar. The paper's finding (Fig. 1)
//! is that Jorge needs *step decay* at 1/3 and 2/3 of the budget even when
//! the SGD baseline used cosine/poly — these schedules regenerate that
//! comparison.

use crate::config::ScheduleKind;

/// A fully-resolved schedule over a fixed training budget.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub kind: ScheduleKind,
    pub base_lr: f64,
    pub total_steps: usize,
    pub warmup_steps: usize,
    /// step-decay boundaries (absolute steps) and per-boundary factor
    pub decay_steps: Vec<usize>,
    pub decay_factor: f64,
    /// polynomial power (torchvision DeepLabv3 default 0.9)
    pub poly_power: f64,
}

impl Schedule {
    pub fn new(
        kind: ScheduleKind,
        base_lr: f64,
        total_steps: usize,
        warmup_steps: usize,
        decay_at_fracs: &[f64],
    ) -> Self {
        let decay_steps = decay_at_fracs
            .iter()
            .map(|f| ((total_steps as f64) * f).round() as usize)
            .collect();
        Schedule {
            kind,
            base_lr,
            total_steps: total_steps.max(1),
            warmup_steps,
            decay_steps,
            decay_factor: 0.1,
            poly_power: 0.9,
        }
    }

    /// §4 default for Jorge: step decay at 1/3 and 2/3, 10x each.
    pub fn jorge_default(base_lr: f64, total_steps: usize, warmup_steps: usize) -> Self {
        Schedule::new(
            ScheduleKind::Step,
            base_lr,
            total_steps,
            warmup_steps,
            &[1.0 / 3.0, 2.0 / 3.0],
        )
    }

    /// Learning rate at `step` (0-based).
    pub fn lr_at(&self, step: usize) -> f64 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            // linear warmup from base_lr/warmup to base_lr
            return self.base_lr * (step as f64 + 1.0) / self.warmup_steps as f64;
        }
        let t = step.min(self.total_steps) as f64;
        let span = (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f64;
        let progress = ((t - self.warmup_steps as f64) / span).clamp(0.0, 1.0);
        match self.kind {
            ScheduleKind::Constant => self.base_lr,
            ScheduleKind::Step => {
                let crossed = self.decay_steps.iter().filter(|&&d| step >= d).count();
                self.base_lr * self.decay_factor.powi(crossed as i32)
            }
            ScheduleKind::Cosine => {
                self.base_lr * 0.5 * (1.0 + (std::f64::consts::PI * progress).cos())
            }
            ScheduleKind::Poly => self.base_lr * (1.0 - progress).max(0.0).powf(self.poly_power),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::new(ScheduleKind::Constant, 0.4, 100, 0, &[]);
        assert_eq!(s.lr_at(0), 0.4);
        assert_eq!(s.lr_at(99), 0.4);
    }

    #[test]
    fn step_decay_boundaries() {
        let s = Schedule::new(ScheduleKind::Step, 1.0, 90, 0, &[1.0 / 3.0, 2.0 / 3.0]);
        assert_eq!(s.lr_at(0), 1.0);
        assert_eq!(s.lr_at(29), 1.0);
        assert!((s.lr_at(30) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(59) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(60) - 0.01).abs() < 1e-12);
        assert!((s.lr_at(89) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn cosine_endpoints_and_monotone() {
        let s = Schedule::new(ScheduleKind::Cosine, 1.0, 100, 0, &[]);
        assert!((s.lr_at(0) - 1.0).abs() < 1e-3);
        assert!(s.lr_at(100) < 1e-3);
        for i in 1..100 {
            assert!(s.lr_at(i) <= s.lr_at(i - 1) + 1e-12);
        }
    }

    #[test]
    fn poly_power_09() {
        let s = Schedule::new(ScheduleKind::Poly, 1.0, 100, 0, &[]);
        assert!((s.lr_at(0) - 1.0).abs() < 1e-9);
        let half = s.lr_at(50);
        assert!((half - 0.5f64.powf(0.9)).abs() < 1e-2, "{half}");
        assert!(s.lr_at(100) < 1e-6);
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::new(ScheduleKind::Step, 1.0, 100, 10, &[0.5]);
        assert!((s.lr_at(0) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(4) - 0.5).abs() < 1e-12);
        assert!((s.lr_at(9) - 1.0).abs() < 1e-12);
        assert!((s.lr_at(10) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn warmup_then_decay() {
        let s = Schedule::new(ScheduleKind::Step, 1.0, 100, 10, &[0.5]);
        assert!((s.lr_at(49) - 1.0).abs() < 1e-12);
        assert!((s.lr_at(50) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn jorge_default_matches_paper() {
        let s = Schedule::jorge_default(0.4, 90, 0);
        assert_eq!(s.kind, ScheduleKind::Step);
        assert_eq!(s.decay_steps, vec![30, 60]);
        assert_eq!(s.decay_factor, 0.1);
    }

    #[test]
    fn all_schedules_nonnegative_and_bounded() {
        for kind in [
            ScheduleKind::Constant,
            ScheduleKind::Step,
            ScheduleKind::Cosine,
            ScheduleKind::Poly,
        ] {
            let s = Schedule::new(kind, 0.4, 77, 5, &[0.33, 0.66]);
            for step in 0..=80 {
                let lr = s.lr_at(step);
                assert!(lr >= 0.0 && lr <= 0.4 + 1e-12, "{kind:?}@{step}: {lr}");
            }
        }
    }
}
