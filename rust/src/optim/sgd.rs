//! Heavy-ball SGD with coupled L2 weight decay — the torchvision baseline
//! (mirrors `optim_jax.make_sgd`).

use super::{Hyper, Optimizer, SgdParams, StepCtx};
use crate::tensor::Matrix;

pub struct Sgd {
    p: SgdParams,
    momentum: Vec<Matrix>,
}

impl Sgd {
    pub fn new(shapes: &[(usize, usize)], hyper: Hyper) -> Self {
        Self::with_params(shapes, (&hyper).into())
    }

    pub fn with_params(shapes: &[(usize, usize)], p: SgdParams) -> Self {
        Sgd {
            p,
            momentum: shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect(),
        }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], ctx: StepCtx) {
        assert_eq!(params.len(), self.momentum.len());
        assert_eq!(params.len(), grads.len());
        for ((p, g), mom) in params.iter_mut().zip(grads).zip(&mut self.momentum) {
            for i in 0..p.data.len() {
                let gi = g.data[i] + ctx.weight_decay * p.data[i]; // coupled L2
                mom.data[i] = self.p.momentum * mom.data[i] + gi;
                p.data[i] -= ctx.lr * mom.data[i];
            }
        }
    }

    fn state_floats(&self) -> usize {
        self.momentum.iter().map(|m| m.data.len()).sum()
    }

    fn state_mut(&mut self) -> Vec<&mut Matrix> {
        self.momentum.iter_mut().collect()
    }

    fn n_layers(&self) -> usize {
        self.momentum.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn ctx(lr: f32, wd: f32) -> StepCtx {
        StepCtx { lr, weight_decay: wd, update_precond: true }
    }

    #[test]
    fn first_step_is_lr_times_grad() {
        let mut rng = Rng::new(0);
        let mut p = vec![Matrix::randn(4, 3, 1.0, &mut rng)];
        let p0 = p[0].clone();
        let g = vec![Matrix::randn(4, 3, 1.0, &mut rng)];
        let mut opt = Sgd::new(&[(4, 3)], Hyper::default());
        opt.step(&mut p, &g, ctx(0.1, 0.0));
        let want = p0.sub(&g[0].scale(0.1));
        assert!(p[0].max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn momentum_grows_step_size() {
        let mut p = vec![Matrix::zeros(2, 2)];
        let g = vec![Matrix::from_vec(2, 2, vec![1.0; 4])];
        let mut opt = Sgd::new(&[(2, 2)], Hyper::default());
        opt.step(&mut p, &g, ctx(0.1, 0.0));
        let after1 = p[0].data[0]; // -0.1
        opt.step(&mut p, &g, ctx(0.1, 0.0));
        let d2 = p[0].data[0] - after1; // -(0.1 * 1.9)
        assert!((after1 + 0.1).abs() < 1e-6);
        assert!((d2 + 0.19).abs() < 1e-6);
    }

    #[test]
    fn coupled_wd_decays_towards_zero() {
        let mut p = vec![Matrix::from_vec(1, 1, vec![1.0])];
        let g = vec![Matrix::zeros(1, 1)];
        let mut opt = Sgd::new(&[(1, 1)], Hyper::default());
        for _ in 0..10 {
            opt.step(&mut p, &g, ctx(0.1, 0.1));
        }
        assert!(p[0].data[0] < 1.0 && p[0].data[0] > 0.0);
    }

    #[test]
    fn state_floats_equals_param_count() {
        let opt = Sgd::new(&[(8, 4), (4, 1)], Hyper::default());
        assert_eq!(opt.state_floats(), 8 * 4 + 4);
    }
}
