//! Shampoo (Gupta et al. 2018) — the exact second-order baseline the
//! paper approximates. Mirror of `optim_jax.make_shampoo`.
//!
//! Gram statistics accumulate by EMA every step; inverse fourth roots are
//! recomputed only on `update_precond` steps, via either the coupled
//! Newton iteration (default — matches the HLO artifact) or the exact
//! Jacobi eigensolver (`RootMethod::Eigh`, the cuSOLVER-style baseline
//! costed in Table 1).
//!
//! The per-layer step factors into [`refresh_layer`] (stat EMAs + root
//! recompute — the shardable owner-computes half) and [`apply_layer`]
//! (preconditioned grafted update). The fused [`Optimizer::step`] runs
//! both back to back, so refresh-then-apply through the trait's split
//! protocol is bitwise identical to the serial step.

use super::{
    for_each_layer, grafted_update, max_dim, GuardReport, Hyper, Optimizer, ShampooParams, StepCtx,
    INNER_PAR_DIM,
};
use crate::tensor::{gram_left, gram_right, inv_fourth_root_eigh, inv_fourth_root_newton};
use crate::tensor::{matmul, Matrix};
use crate::trace::{self, Phase};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RootMethod {
    Newton,
    Eigh,
}

struct LayerState {
    lstat: Option<Matrix>,
    rstat: Option<Matrix>,
    pl: Option<Matrix>,
    pr: Option<Matrix>,
    mom: Matrix,
    gmom: Matrix,
    guard: GuardReport,
}

pub struct Shampoo {
    p: ShampooParams,
    pub root_method: RootMethod,
    layers: Vec<LayerState>,
}

impl Shampoo {
    pub fn new(shapes: &[(usize, usize)], hyper: Hyper) -> Self {
        Self::with_params(shapes, (&hyper).into(), RootMethod::Newton)
    }

    pub fn with_root(shapes: &[(usize, usize)], hyper: Hyper, root_method: RootMethod) -> Self {
        Self::with_params(shapes, (&hyper).into(), root_method)
    }

    pub fn with_params(
        shapes: &[(usize, usize)],
        p: ShampooParams,
        root_method: RootMethod,
    ) -> Self {
        let eps = p.eps;
        let pscale = eps.powf(-0.25);
        let layers = shapes
            .iter()
            .map(|&(m, n)| {
                let precond = m > 1 && n > 1;
                LayerState {
                    lstat: precond.then(|| Matrix::eye(m, eps)),
                    rstat: precond.then(|| Matrix::eye(n, eps)),
                    pl: precond.then(|| Matrix::eye(m, pscale)),
                    pr: precond.then(|| Matrix::eye(n, pscale)),
                    mom: Matrix::zeros(m, n),
                    gmom: Matrix::zeros(m, n),
                    guard: GuardReport::default(),
                }
            })
            .collect();
        Shampoo { p, root_method, layers }
    }
}

fn root_of(method: RootMethod, p: ShampooParams, a: &Matrix) -> Matrix {
    match method {
        RootMethod::Newton => inv_fourth_root_newton(a, p.newton_iters, p.eps),
        RootMethod::Eigh => inv_fourth_root_eigh(a, p.eps),
    }
}

/// Owner-computes half: EMA both gram stats (every step, Alg. 1 lines
/// 5-8), then recompute the inverse fourth roots on update steps.
///
/// Guardrails (zero-cost on healthy inputs beyond an `all_finite` scan):
/// a non-finite gradient or gram is rejected *before* the EMA — one
/// poisoned stat would otherwise contaminate every later refresh — and
/// the roots stay stale for the step; non-finite stats (corrupted
/// import) self-heal to the eps-identity; a non-finite root recompute is
/// retried once with a bumped ridge (DASH-style damping of the
/// ill-conditioned inverse root) before falling back to stale roots.
fn refresh_layer(
    p: ShampooParams,
    method: RootMethod,
    st: &mut LayerState,
    g: &Matrix,
    update: bool,
) {
    if st.lstat.is_none() {
        return;
    }
    let (gl, gr) = if g.all_finite() {
        let gl = gram_left(g);
        let gr = gram_right(g);
        if gl.all_finite() && gr.all_finite() {
            (Some(gl), Some(gr))
        } else {
            (None, None)
        }
    } else {
        st.guard.nonfinite_grads += 1;
        (None, None)
    };
    let Some(lstat) = st.lstat.as_mut() else { return };
    let Some(rstat) = st.rstat.as_mut() else { return };
    // self-heal stats a corrupted import left non-finite
    if !lstat.all_finite() || !rstat.all_finite() {
        *lstat = Matrix::eye(st.mom.rows, p.eps);
        *rstat = Matrix::eye(st.mom.cols, p.eps);
        st.guard.precond_resets += 1;
    }
    match (gl, gr) {
        (Some(gl), Some(gr)) => {
            let b2 = p.beta2;
            for i in 0..lstat.data.len() {
                lstat.data[i] = b2 * lstat.data[i] + (1.0 - b2) * gl.data[i];
            }
            for i in 0..rstat.data.len() {
                rstat.data[i] = b2 * rstat.data[i] + (1.0 - b2) * gr.data[i];
            }
        }
        _ => st.guard.rejected_stats += 1,
    }
    if update {
        let new_pl = root_of(method, p, lstat);
        let new_pr = root_of(method, p, rstat);
        if new_pl.all_finite() && new_pr.all_finite() {
            st.pl = Some(new_pl);
            st.pr = Some(new_pr);
            return;
        }
        // damped retry: bump the ridge and redo once
        st.guard.damped_retries += 1;
        let damped = ShampooParams { eps: (p.eps * 1e4).max(1e-8), ..p };
        let retry_pl = root_of(method, damped, lstat);
        let retry_pr = root_of(method, damped, rstat);
        if retry_pl.all_finite() && retry_pr.all_finite() {
            st.pl = Some(retry_pl);
            st.pr = Some(retry_pr);
        } else {
            st.guard.stale_preconds += 1;
        }
    }
}

/// Apply half: precondition with the current roots and take the grafted
/// update (coupled L2). Never touches stats or roots.
///
/// Guardrails: a non-finite gradient freezes the layer for the step; a
/// non-finite preconditioned gradient falls back to the grafted
/// first-order direction.
fn apply_layer(
    p: ShampooParams,
    st: &mut LayerState,
    param: &mut Matrix,
    g: &Matrix,
    ctx: StepCtx,
) {
    if !g.all_finite() {
        st.guard.nonfinite_grads += 1;
        st.guard.skipped_updates += 1;
        return;
    }
    match (&st.pl, &st.pr) {
        (Some(pl), Some(pr)) => {
            let gtilde = matmul(&matmul(pl, g), pr);
            if gtilde.all_finite() {
                grafted_update(param, g, &gtilde, &mut st.mom, &mut st.gmom, ctx, p.graft, false);
            } else {
                st.guard.graft_fallbacks += 1;
                grafted_update(param, g, g, &mut st.mom, &mut st.gmom, ctx, p.graft, false);
            }
        }
        _ => {
            grafted_update(param, g, g, &mut st.mom, &mut st.gmom, ctx, p.graft, false);
        }
    }
}

impl Optimizer for Shampoo {
    fn name(&self) -> &'static str {
        "shampoo"
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], ctx: StepCtx) {
        assert_eq!(params.len(), self.layers.len());
        // Layers are independent: fan the per-layer work (gram EMAs,
        // inverse-root refresh, preconditioned GEMM) across the pool.
        // The expensive roots dominate on `update_precond` steps; when
        // one large stat dominates those, stay serial so its root's
        // GEMMs get the pool instead (inner beats outer there).
        let p = self.p;
        let method = self.root_method;
        let body = |li: usize, param: &mut Matrix, st: &mut LayerState| {
            let g = &grads[li];
            refresh_layer(p, method, st, g, ctx.update_precond);
            apply_layer(p, st, param, g, ctx);
        };
        let dims = self.layers.iter().flat_map(|s| [s.lstat.as_ref(), s.rstat.as_ref()]);
        let serial = ctx.update_precond && max_dim(dims) >= INNER_PAR_DIM;
        for_each_layer(params, &mut self.layers, serial, body);
    }

    fn state_floats(&self) -> usize {
        self.layers
            .iter()
            .map(|s| {
                s.mom.data.len()
                    + s.gmom.data.len()
                    + [&s.lstat, &s.rstat, &s.pl, &s.pr]
                        .iter()
                        .map(|o| o.as_ref().map_or(0, |m| m.data.len()))
                        .sum::<usize>()
            })
            .sum()
    }

    fn state_mut(&mut self) -> Vec<&mut Matrix> {
        let mut out = Vec::new();
        for s in &mut self.layers {
            for o in [&mut s.lstat, &mut s.rstat, &mut s.pl, &mut s.pr] {
                if let Some(m) = o {
                    out.push(m);
                }
            }
            out.push(&mut s.mom);
            out.push(&mut s.gmom);
        }
        out
    }

    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn refresh_flops(&self, layer: usize) -> f64 {
        let st = &self.layers[layer];
        let (Some(l), Some(r)) = (&st.lstat, &st.rstat) else { return 0.0 };
        let (m, n) = (l.rows as f64, r.rows as f64);
        let mn = st.mom.data.len() as f64; // m*n
        // grams (2 m^2 n + 2 n^2 m) + Newton roots (~8 GEMMs/iter per side)
        2.0 * m * mn + 2.0 * n * mn + 8.0 * self.p.newton_iters as f64 * (m * m * m + n * n * n)
    }

    fn refresh_layers(&mut self, layers: &[usize], grads: &[Matrix], update_precond: bool) {
        let _scope = trace::scope(Phase::PrecondRefresh);
        let p = self.p;
        let method = self.root_method;
        let traced = trace::enabled();
        for &li in layers {
            let t0 = traced.then(std::time::Instant::now);
            refresh_layer(p, method, &mut self.layers[li], &grads[li], update_precond);
            if let Some(t0) = t0 {
                let dt = t0.elapsed().as_secs_f64();
                trace::add_gauge(&format!("trace.layer.{li}.refresh_s"), dt);
            }
        }
    }

    fn guard_report(&self) -> GuardReport {
        let mut total = GuardReport::default();
        for s in &self.layers {
            total.merge(&s.guard);
        }
        total
    }

    fn apply_update(&mut self, params: &mut [Matrix], grads: &[Matrix], ctx: StepCtx) {
        let _scope = trace::scope(Phase::Apply);
        assert_eq!(params.len(), self.layers.len());
        let p = self.p;
        let traced = trace::enabled();
        let body = |li: usize, param: &mut Matrix, st: &mut LayerState| {
            let t0 = traced.then(std::time::Instant::now);
            apply_layer(p, st, param, &grads[li], ctx);
            if let Some(t0) = t0 {
                let dt = t0.elapsed().as_secs_f64();
                trace::add_gauge(&format!("trace.layer.{li}.apply_s"), dt);
            }
        };
        for_each_layer(params, &mut self.layers, false, body);
    }

    fn export_preconditioners(&self, layers: &[usize]) -> Vec<f32> {
        let mut out = Vec::new();
        for &li in layers {
            let st = &self.layers[li];
            if let (Some(pl), Some(pr)) = (&st.pl, &st.pr) {
                out.extend_from_slice(&pl.data);
                out.extend_from_slice(&pr.data);
            }
        }
        out
    }

    fn import_preconditioners(&mut self, layers: &[usize], data: &[f32]) -> usize {
        let mut off = 0;
        for &li in layers {
            let st = &mut self.layers[li];
            if let (Some(pl), Some(pr)) = (&mut st.pl, &mut st.pr) {
                pl.data.copy_from_slice(&data[off..off + pl.data.len()]);
                off += pl.data.len();
                pr.data.copy_from_slice(&data[off..off + pr.data.len()]);
                off += pr.data.len();
            }
        }
        off
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn ctx(lr: f32, wd: f32, upd: bool) -> StepCtx {
        StepCtx { lr, weight_decay: wd, update_precond: upd }
    }

    #[test]
    fn stats_accumulate_even_on_skip_steps() {
        let mut rng = Rng::new(0);
        let mut p = vec![Matrix::randn(6, 4, 1.0, &mut rng)];
        let g = vec![Matrix::randn(6, 4, 0.5, &mut rng)];
        let mut opt = Shampoo::new(&[(6, 4)], Hyper::default());
        let s0 = opt.layers[0].lstat.clone().unwrap();
        let pl0 = opt.layers[0].pl.clone().unwrap();
        opt.step(&mut p, &g, ctx(0.1, 0.0, false));
        assert!(opt.layers[0].lstat.as_ref().unwrap().max_abs_diff(&s0) > 0.0);
        assert_eq!(opt.layers[0].pl.as_ref().unwrap(), &pl0); // stale
        opt.step(&mut p, &g, ctx(0.1, 0.0, true));
        assert!(opt.layers[0].pl.as_ref().unwrap().max_abs_diff(&pl0) > 0.0);
    }

    #[test]
    fn refresh_accumulates_stats_on_skip_steps_too() {
        // the sharded path calls refresh_layers every step; Shampoo's
        // stat EMA must advance even when roots are not recomputed
        let mut rng = Rng::new(7);
        let g = vec![Matrix::randn(6, 4, 0.5, &mut rng)];
        let mut opt = Shampoo::new(&[(6, 4)], Hyper::default());
        let s0 = opt.layers[0].lstat.clone().unwrap();
        let pl0 = opt.layers[0].pl.clone().unwrap();
        opt.refresh_layers(&[0], &g, false);
        assert!(opt.layers[0].lstat.as_ref().unwrap().max_abs_diff(&s0) > 0.0);
        assert_eq!(opt.layers[0].pl.as_ref().unwrap(), &pl0);
    }

    #[test]
    fn newton_and_eigh_roots_agree_in_trajectory() {
        let mut rng = Rng::new(1);
        let shapes = [(8usize, 5usize)];
        let mut p_a = vec![Matrix::randn(8, 5, 1.0, &mut rng)];
        let mut p_b = p_a.clone();
        let mut newton = Shampoo::with_root(&shapes, Hyper::default(), RootMethod::Newton);
        let mut eigh = Shampoo::with_root(&shapes, Hyper::default(), RootMethod::Eigh);
        let mut r2 = Rng::new(2);
        for _ in 0..5 {
            let g = vec![Matrix::randn(8, 5, 0.3, &mut r2)];
            newton.step(&mut p_a, &g, ctx(0.05, 0.0, true));
            eigh.step(&mut p_b, &g, ctx(0.05, 0.0, true));
        }
        let rel = p_a[0].max_abs_diff(&p_b[0]) / p_a[0].max_abs();
        assert!(rel < 0.05, "newton vs eigh trajectories differ: {rel}");
    }

    #[test]
    fn first_step_magnitude_is_sgd_grafted() {
        let mut rng = Rng::new(3);
        let mut p = vec![Matrix::randn(8, 5, 1.0, &mut rng)];
        let p0 = p[0].clone();
        let g = vec![Matrix::randn(8, 5, 0.2, &mut rng)];
        let mut opt = Shampoo::new(&[(8, 5)], Hyper::default());
        opt.step(&mut p, &g, ctx(0.05, 0.0, true));
        let step_norm = p[0].sub(&p0).frobenius();
        let want = 0.05 * g[0].frobenius();
        assert!((step_norm - want).abs() / want < 1e-3);
    }

    #[test]
    fn memory_is_larger_than_jorge() {
        let shapes = [(16usize, 8usize), (8, 1)];
        let shampoo = Shampoo::new(&shapes, Hyper::default());
        let jorge = super::super::Jorge::new(&shapes, Hyper::default());
        assert!(shampoo.state_floats() > jorge.state_floats());
    }

    #[test]
    fn nan_gradient_never_poisons_the_stat_ema() {
        let mut rng = Rng::new(11);
        let mut p = vec![Matrix::randn(6, 4, 1.0, &mut rng)];
        let mut opt = Shampoo::new(&[(6, 4)], Hyper::default());
        let g_ok = vec![Matrix::randn(6, 4, 0.3, &mut rng)];
        opt.step(&mut p, &g_ok, ctx(0.05, 1e-3, true));
        assert_eq!(opt.guard_report().total(), 0, "healthy run must not trip guards");
        let stat_before = opt.layers[0].lstat.clone().unwrap();
        let p_before = p[0].clone();
        let mut g_bad = Matrix::randn(6, 4, 0.3, &mut rng);
        g_bad.data[0] = f32::NAN;
        opt.step(&mut p, &[g_bad], ctx(0.05, 1e-3, true));
        // the EMA was protected: one poisoned stat would stay poisoned forever
        assert_eq!(opt.layers[0].lstat.as_ref().unwrap(), &stat_before);
        assert_eq!(p[0], p_before, "layer must freeze on a NaN gradient");
        let rep = opt.guard_report();
        assert!(rep.nonfinite_grads >= 1);
        assert_eq!(rep.rejected_stats, 1);
        assert_eq!(rep.skipped_updates, 1);
        // next healthy step proceeds with finite state
        let g2 = vec![Matrix::randn(6, 4, 0.3, &mut rng)];
        opt.step(&mut p, &g2, ctx(0.05, 1e-3, true));
        assert!(p[0].all_finite());
        assert!(opt.layers[0].pl.as_ref().unwrap().all_finite());
    }

    #[test]
    fn corrupted_stat_self_heals_on_refresh() {
        let mut rng = Rng::new(12);
        let g = vec![Matrix::randn(6, 4, 0.3, &mut rng)];
        let mut opt = Shampoo::new(&[(6, 4)], Hyper::default());
        opt.layers[0].lstat.as_mut().unwrap().data[7] = f32::INFINITY;
        opt.refresh_layers(&[0], &g, true);
        assert!(opt.layers[0].lstat.as_ref().unwrap().all_finite());
        assert!(opt.layers[0].pl.as_ref().unwrap().all_finite());
        assert_eq!(opt.guard_report().precond_resets, 1);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut rng = Rng::new(5);
        let target = Matrix::randn(8, 6, 1.0, &mut rng);
        let mut p = vec![Matrix::zeros(8, 6)];
        let mut opt = Shampoo::new(&[(8, 6)], Hyper::default());
        for _ in 0..80 {
            let g = vec![p[0].sub(&target)];
            opt.step(&mut p, &g, ctx(0.1, 0.0, true));
        }
        let err = p[0].sub(&target).frobenius_sq();
        assert!(err < 0.05 * target.frobenius_sq());
    }
}
