//! Shampoo (Gupta et al. 2018) — the exact second-order baseline the
//! paper approximates. Mirror of `optim_jax.make_shampoo`.
//!
//! Gram statistics accumulate by EMA every step; inverse fourth roots are
//! recomputed only on `update_precond` steps, via either the coupled
//! Newton iteration (default — matches the HLO artifact) or the exact
//! Jacobi eigensolver (`RootMethod::Eigh`, the cuSOLVER-style baseline
//! costed in Table 1).
//!
//! The per-layer step factors into [`refresh_layer`] (stat EMAs + root
//! recompute — the shardable owner-computes half) and [`apply_layer`]
//! (preconditioned grafted update). The fused [`Optimizer::step`] runs
//! both back to back, so refresh-then-apply through the trait's split
//! protocol is bitwise identical to the serial step.

use super::{
    for_each_layer, grafted_update, max_dim, Hyper, Optimizer, ShampooParams, StepCtx,
    INNER_PAR_DIM,
};
use crate::tensor::{gram_left, gram_right, inv_fourth_root_eigh, inv_fourth_root_newton};
use crate::tensor::{matmul, Matrix};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RootMethod {
    Newton,
    Eigh,
}

struct LayerState {
    lstat: Option<Matrix>,
    rstat: Option<Matrix>,
    pl: Option<Matrix>,
    pr: Option<Matrix>,
    mom: Matrix,
    gmom: Matrix,
}

pub struct Shampoo {
    p: ShampooParams,
    pub root_method: RootMethod,
    layers: Vec<LayerState>,
}

impl Shampoo {
    pub fn new(shapes: &[(usize, usize)], hyper: Hyper) -> Self {
        Self::with_params(shapes, (&hyper).into(), RootMethod::Newton)
    }

    pub fn with_root(shapes: &[(usize, usize)], hyper: Hyper, root_method: RootMethod) -> Self {
        Self::with_params(shapes, (&hyper).into(), root_method)
    }

    pub fn with_params(
        shapes: &[(usize, usize)],
        p: ShampooParams,
        root_method: RootMethod,
    ) -> Self {
        let eps = p.eps;
        let pscale = eps.powf(-0.25);
        let layers = shapes
            .iter()
            .map(|&(m, n)| {
                let precond = m > 1 && n > 1;
                LayerState {
                    lstat: precond.then(|| Matrix::eye(m, eps)),
                    rstat: precond.then(|| Matrix::eye(n, eps)),
                    pl: precond.then(|| Matrix::eye(m, pscale)),
                    pr: precond.then(|| Matrix::eye(n, pscale)),
                    mom: Matrix::zeros(m, n),
                    gmom: Matrix::zeros(m, n),
                }
            })
            .collect();
        Shampoo { p, root_method, layers }
    }
}

fn root_of(method: RootMethod, p: ShampooParams, a: &Matrix) -> Matrix {
    match method {
        RootMethod::Newton => inv_fourth_root_newton(a, p.newton_iters, p.eps),
        RootMethod::Eigh => inv_fourth_root_eigh(a, p.eps),
    }
}

/// Owner-computes half: EMA both gram stats (every step, Alg. 1 lines
/// 5-8), then recompute the inverse fourth roots on update steps.
fn refresh_layer(
    p: ShampooParams,
    method: RootMethod,
    st: &mut LayerState,
    g: &Matrix,
    update: bool,
) {
    let Some(lstat) = st.lstat.as_mut() else { return };
    let b2 = p.beta2;
    let gl = gram_left(g);
    for i in 0..lstat.data.len() {
        lstat.data[i] = b2 * lstat.data[i] + (1.0 - b2) * gl.data[i];
    }
    let rstat = st.rstat.as_mut().unwrap();
    let gr = gram_right(g);
    for i in 0..rstat.data.len() {
        rstat.data[i] = b2 * rstat.data[i] + (1.0 - b2) * gr.data[i];
    }
    if update {
        st.pl = Some(root_of(method, p, st.lstat.as_ref().unwrap()));
        st.pr = Some(root_of(method, p, st.rstat.as_ref().unwrap()));
    }
}

/// Apply half: precondition with the current roots and take the grafted
/// update (coupled L2). Never touches stats or roots.
fn apply_layer(
    p: ShampooParams,
    st: &mut LayerState,
    param: &mut Matrix,
    g: &Matrix,
    ctx: StepCtx,
) {
    if st.pl.is_some() {
        let gtilde = matmul(&matmul(st.pl.as_ref().unwrap(), g), st.pr.as_ref().unwrap());
        grafted_update(param, g, &gtilde, &mut st.mom, &mut st.gmom, ctx, p.graft, false);
    } else {
        grafted_update(param, g, g, &mut st.mom, &mut st.gmom, ctx, p.graft, false);
    }
}

impl Optimizer for Shampoo {
    fn name(&self) -> &'static str {
        "shampoo"
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], ctx: StepCtx) {
        assert_eq!(params.len(), self.layers.len());
        // Layers are independent: fan the per-layer work (gram EMAs,
        // inverse-root refresh, preconditioned GEMM) across the pool.
        // The expensive roots dominate on `update_precond` steps; when
        // one large stat dominates those, stay serial so its root's
        // GEMMs get the pool instead (inner beats outer there).
        let p = self.p;
        let method = self.root_method;
        let body = |li: usize, param: &mut Matrix, st: &mut LayerState| {
            let g = &grads[li];
            refresh_layer(p, method, st, g, ctx.update_precond);
            apply_layer(p, st, param, g, ctx);
        };
        let dims = self.layers.iter().flat_map(|s| [s.lstat.as_ref(), s.rstat.as_ref()]);
        let serial = ctx.update_precond && max_dim(dims) >= INNER_PAR_DIM;
        for_each_layer(params, &mut self.layers, serial, body);
    }

    fn state_floats(&self) -> usize {
        self.layers
            .iter()
            .map(|s| {
                s.mom.data.len()
                    + s.gmom.data.len()
                    + [&s.lstat, &s.rstat, &s.pl, &s.pr]
                        .iter()
                        .map(|o| o.as_ref().map_or(0, |m| m.data.len()))
                        .sum::<usize>()
            })
            .sum()
    }

    fn state_mut(&mut self) -> Vec<&mut Matrix> {
        let mut out = Vec::new();
        for s in &mut self.layers {
            for o in [&mut s.lstat, &mut s.rstat, &mut s.pl, &mut s.pr] {
                if let Some(m) = o {
                    out.push(m);
                }
            }
            out.push(&mut s.mom);
            out.push(&mut s.gmom);
        }
        out
    }

    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn refresh_flops(&self, layer: usize) -> f64 {
        let st = &self.layers[layer];
        let (Some(l), Some(r)) = (&st.lstat, &st.rstat) else { return 0.0 };
        let (m, n) = (l.rows as f64, r.rows as f64);
        let mn = st.mom.data.len() as f64; // m*n
        // grams (2 m^2 n + 2 n^2 m) + Newton roots (~8 GEMMs/iter per side)
        2.0 * m * mn + 2.0 * n * mn + 8.0 * self.p.newton_iters as f64 * (m * m * m + n * n * n)
    }

    fn refresh_layers(&mut self, layers: &[usize], grads: &[Matrix], update_precond: bool) {
        let p = self.p;
        let method = self.root_method;
        for &li in layers {
            refresh_layer(p, method, &mut self.layers[li], &grads[li], update_precond);
        }
    }

    fn apply_update(&mut self, params: &mut [Matrix], grads: &[Matrix], ctx: StepCtx) {
        assert_eq!(params.len(), self.layers.len());
        let p = self.p;
        let body = |li: usize, param: &mut Matrix, st: &mut LayerState| {
            apply_layer(p, st, param, &grads[li], ctx);
        };
        for_each_layer(params, &mut self.layers, false, body);
    }

    fn export_preconditioners(&self, layers: &[usize]) -> Vec<f32> {
        let mut out = Vec::new();
        for &li in layers {
            let st = &self.layers[li];
            if let (Some(pl), Some(pr)) = (&st.pl, &st.pr) {
                out.extend_from_slice(&pl.data);
                out.extend_from_slice(&pr.data);
            }
        }
        out
    }

    fn import_preconditioners(&mut self, layers: &[usize], data: &[f32]) -> usize {
        let mut off = 0;
        for &li in layers {
            let st = &mut self.layers[li];
            if let (Some(pl), Some(pr)) = (&mut st.pl, &mut st.pr) {
                pl.data.copy_from_slice(&data[off..off + pl.data.len()]);
                off += pl.data.len();
                pr.data.copy_from_slice(&data[off..off + pr.data.len()]);
                off += pr.data.len();
            }
        }
        off
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn ctx(lr: f32, wd: f32, upd: bool) -> StepCtx {
        StepCtx { lr, weight_decay: wd, update_precond: upd }
    }

    #[test]
    fn stats_accumulate_even_on_skip_steps() {
        let mut rng = Rng::new(0);
        let mut p = vec![Matrix::randn(6, 4, 1.0, &mut rng)];
        let g = vec![Matrix::randn(6, 4, 0.5, &mut rng)];
        let mut opt = Shampoo::new(&[(6, 4)], Hyper::default());
        let s0 = opt.layers[0].lstat.clone().unwrap();
        let pl0 = opt.layers[0].pl.clone().unwrap();
        opt.step(&mut p, &g, ctx(0.1, 0.0, false));
        assert!(opt.layers[0].lstat.as_ref().unwrap().max_abs_diff(&s0) > 0.0);
        assert_eq!(opt.layers[0].pl.as_ref().unwrap(), &pl0); // stale
        opt.step(&mut p, &g, ctx(0.1, 0.0, true));
        assert!(opt.layers[0].pl.as_ref().unwrap().max_abs_diff(&pl0) > 0.0);
    }

    #[test]
    fn refresh_accumulates_stats_on_skip_steps_too() {
        // the sharded path calls refresh_layers every step; Shampoo's
        // stat EMA must advance even when roots are not recomputed
        let mut rng = Rng::new(7);
        let g = vec![Matrix::randn(6, 4, 0.5, &mut rng)];
        let mut opt = Shampoo::new(&[(6, 4)], Hyper::default());
        let s0 = opt.layers[0].lstat.clone().unwrap();
        let pl0 = opt.layers[0].pl.clone().unwrap();
        opt.refresh_layers(&[0], &g, false);
        assert!(opt.layers[0].lstat.as_ref().unwrap().max_abs_diff(&s0) > 0.0);
        assert_eq!(opt.layers[0].pl.as_ref().unwrap(), &pl0);
    }

    #[test]
    fn newton_and_eigh_roots_agree_in_trajectory() {
        let mut rng = Rng::new(1);
        let shapes = [(8usize, 5usize)];
        let mut p_a = vec![Matrix::randn(8, 5, 1.0, &mut rng)];
        let mut p_b = p_a.clone();
        let mut newton = Shampoo::with_root(&shapes, Hyper::default(), RootMethod::Newton);
        let mut eigh = Shampoo::with_root(&shapes, Hyper::default(), RootMethod::Eigh);
        let mut r2 = Rng::new(2);
        for _ in 0..5 {
            let g = vec![Matrix::randn(8, 5, 0.3, &mut r2)];
            newton.step(&mut p_a, &g, ctx(0.05, 0.0, true));
            eigh.step(&mut p_b, &g, ctx(0.05, 0.0, true));
        }
        let rel = p_a[0].max_abs_diff(&p_b[0]) / p_a[0].max_abs();
        assert!(rel < 0.05, "newton vs eigh trajectories differ: {rel}");
    }

    #[test]
    fn first_step_magnitude_is_sgd_grafted() {
        let mut rng = Rng::new(3);
        let mut p = vec![Matrix::randn(8, 5, 1.0, &mut rng)];
        let p0 = p[0].clone();
        let g = vec![Matrix::randn(8, 5, 0.2, &mut rng)];
        let mut opt = Shampoo::new(&[(8, 5)], Hyper::default());
        opt.step(&mut p, &g, ctx(0.05, 0.0, true));
        let step_norm = p[0].sub(&p0).frobenius();
        let want = 0.05 * g[0].frobenius();
        assert!((step_norm - want).abs() / want < 1e-3);
    }

    #[test]
    fn memory_is_larger_than_jorge() {
        let shapes = [(16usize, 8usize), (8, 1)];
        let shampoo = Shampoo::new(&shapes, Hyper::default());
        let jorge = super::super::Jorge::new(&shapes, Hyper::default());
        assert!(shampoo.state_floats() > jorge.state_floats());
    }

    #[test]
    fn converges_on_quadratic() {
        let mut rng = Rng::new(5);
        let target = Matrix::randn(8, 6, 1.0, &mut rng);
        let mut p = vec![Matrix::zeros(8, 6)];
        let mut opt = Shampoo::new(&[(8, 6)], Hyper::default());
        for _ in 0..80 {
            let g = vec![p[0].sub(&target)];
            opt.step(&mut p, &g, ctx(0.1, 0.0, true));
        }
        let err = p[0].sub(&target).frobenius_sq();
        assert!(err < 0.05 * target.frobenius_sq());
    }
}
