//! Analytic A100 performance model (substrate).
//!
//! The paper's wall-clock numbers (Table 1, Table 4, Fig. 2-right) were
//! measured on A100 GPUs we do not have. This model projects the *op mix*
//! of each optimizer — known exactly from the layer inventories in
//! [`crate::models`] — onto A100 roofline parameters, reproducing the
//! relative ordering and approximate ratios of the paper's tables. The
//! benches print both our *measured* CPU numbers (shape evidence) and
//! these *projected* numbers (scale evidence), clearly labeled.
//!
//! Calibration anchors (public numbers):
//! * A100 TF32 tensor-core peak 156 TFLOP/s; large GEMMs reach ~50%.
//! * HBM2e bandwidth 1.55 TB/s (40 GB SXM).
//! * cuSOLVER `ssyevd` on n=1024 ≈ 20 ms (used by Shampoo-style roots);
//!   modeled as n^3 / 5e10 + 100 us launch overhead.
//! * Paper Table 1 fwd+bwd baselines: ResNet-50 bs64/GPU = 0.09 s/iter,
//!   DeepLabv3 bs16/GPU = 0.33 s/iter (SGD row — optimizer cost there is
//!   negligible, so these anchor the network compute).

use crate::collectives::CommCostModel;
use crate::models::NetworkInventory;
use crate::optim::memory::OptKind;

#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    /// sustained GEMM throughput (FLOP/s)
    pub gemm_flops: f64,
    /// HBM bandwidth (B/s)
    pub hbm_bw: f64,
    /// per-kernel launch overhead (s)
    pub launch: f64,
    /// syevd cost: n^3 / syevd_rate + syevd_overhead
    pub syevd_rate: f64,
    pub syevd_overhead: f64,
}

impl GpuModel {
    pub fn a100() -> Self {
        GpuModel {
            gemm_flops: 78e12, // 156 TF/s TF32 @ ~50% efficiency
            hbm_bw: 1.55e12,
            launch: 5e-6,
            syevd_rate: 5e10,
            syevd_overhead: 1e-4,
        }
    }

    /// GEMM time with a memory-bound floor (roofline).
    pub fn gemm_time(&self, m: usize, k: usize, n: usize) -> f64 {
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let bytes = 4.0 * (m * k + k * n + m * n) as f64;
        self.launch + (flops / self.gemm_flops).max(bytes / self.hbm_bw)
    }

    /// Elementwise pass over `n` floats (read+write).
    pub fn elementwise_time(&self, n: usize) -> f64 {
        self.launch + 8.0 * n as f64 / self.hbm_bw
    }

    /// Eigendecomposition (`syevd`) of an n x n matrix.
    pub fn syevd_time(&self, n: usize) -> f64 {
        self.syevd_overhead + (n as f64).powi(3) / self.syevd_rate
    }
}

/// Per-iteration optimizer cost for a network, amortising the
/// preconditioner refresh over `precond_every` steps.
pub fn optimizer_step_time(
    gpu: &GpuModel,
    net: &NetworkInventory,
    opt: OptKind,
    precond_every: usize,
    newton_iters: usize,
) -> f64 {
    let pcount = net.param_count();
    let every = precond_every.max(1) as f64;
    match opt {
        // SGD: one fused elementwise pass over params+momentum.
        OptKind::Sgd => gpu.elementwise_time(2 * pcount),
        // AdamW: two state tensors + params.
        OptKind::AdamW => gpu.elementwise_time(3 * pcount),
        OptKind::Jorge => {
            let mut t = gpu.elementwise_time(3 * pcount); // mom/gmom/params
            for l in &net.layers {
                if !l.preconditioned() {
                    continue;
                }
                let (m, n) = (l.m, l.n);
                // preconditioning every step: (LG)R
                t += gpu.gemm_time(m, m, n) + gpu.gemm_time(m, n, n);
                // update (amortised): grams + P2,P4,X,X2,PM per side + norm
                let upd_l = gpu.gemm_time(m, n, m) + 5.0 * gpu.gemm_time(m, m, m)
                    + gpu.elementwise_time(m * m);
                let upd_r = gpu.gemm_time(n, m, n) + 5.0 * gpu.gemm_time(n, n, n)
                    + gpu.elementwise_time(n * n);
                t += (upd_l + upd_r) / every;
            }
            t
        }
        OptKind::Shampoo => {
            let mut t = gpu.elementwise_time(3 * pcount);
            for l in &net.layers {
                if !l.preconditioned() {
                    continue;
                }
                let (m, n) = (l.m, l.n);
                // stats EMA every step: grams + axpy
                t += gpu.gemm_time(m, n, m)
                    + gpu.gemm_time(n, m, n)
                    + gpu.elementwise_time(m * m + n * n);
                // preconditioning every step
                t += gpu.gemm_time(m, m, n) + gpu.gemm_time(m, n, n);
                // roots (amortised): syevd per side — the paper's Shampoo
                // baseline computes eigendecompositions; a Newton variant
                // would be `newton_iters * 4 GEMMs` instead.
                let _ = newton_iters;
                t += (gpu.syevd_time(m) + gpu.syevd_time(n)) / every;
            }
            t
        }
    }
}

/// Full-iteration projection: network fwd/bwd anchor + optimizer +
/// gradient all-reduce across `gpus`.
#[derive(Clone, Copy, Debug)]
pub struct IterProjection {
    pub fwd_bwd_s: f64,
    pub optimizer_s: f64,
    pub comm_s: f64,
}

impl IterProjection {
    pub fn total(&self) -> f64 {
        self.fwd_bwd_s + self.optimizer_s + self.comm_s
    }
}

pub fn project_iteration(
    gpu: &GpuModel,
    comm: &CommCostModel,
    net: &NetworkInventory,
    opt: OptKind,
    precond_every: usize,
    fwd_bwd_anchor_s: f64,
    gpus: usize,
) -> IterProjection {
    let grad_bytes = 4 * net.param_count();
    IterProjection {
        fwd_bwd_s: fwd_bwd_anchor_s,
        optimizer_s: optimizer_step_time(gpu, net, opt, precond_every, 15),
        comm_s: comm.ring_all_reduce_time(grad_bytes, gpus),
    }
}

/// Distributed-Shampoo projection (Shi et al. 2023): preconditioner
/// computations sharded across `gpus`, roots all-gathered afterwards.
pub fn project_dist_shampoo_iteration(
    gpu: &GpuModel,
    comm: &CommCostModel,
    net: &NetworkInventory,
    precond_every: usize,
    fwd_bwd_anchor_s: f64,
    gpus: usize,
) -> IterProjection {
    let every = precond_every.max(1) as f64;
    let pcount = net.param_count();
    let mut opt_t = gpu.elementwise_time(3 * pcount);
    let mut root_t = 0.0;
    let mut root_bytes = 0usize;
    for l in &net.layers {
        if !l.preconditioned() {
            continue;
        }
        let (m, n) = (l.m, l.n);
        opt_t += gpu.gemm_time(m, n, m)
            + gpu.gemm_time(n, m, n)
            + gpu.elementwise_time(m * m + n * n);
        opt_t += gpu.gemm_time(m, m, n) + gpu.gemm_time(m, n, n);
        root_t += gpu.syevd_time(m) + gpu.syevd_time(n);
        root_bytes += 4 * (m * m + n * n);
    }
    // roots parallelise across gpus; results all-gathered
    opt_t += root_t / gpus as f64 / every;
    let comm_s = comm.ring_all_reduce_time(4 * pcount, gpus)
        + comm.all_gather_time(root_bytes, gpus) / every;
    IterProjection { fwd_bwd_s: fwd_bwd_anchor_s, optimizer_s: opt_t, comm_s }
}

/// Projection of the coordinator's own sharded scheme
/// (`shampoo_sharded` / `jorge_sharded`): preconditioner refresh work is
/// owner-computes across `gpus` (FLOP-balanced, so ~1/gpus each), the
/// refreshed preconditioners are all-gathered, and every worker runs the
/// preconditioning GEMMs + elementwise apply on its own replica.
/// `opt` must be a second-order kind.
pub fn project_sharded_iteration(
    gpu: &GpuModel,
    comm: &CommCostModel,
    net: &NetworkInventory,
    opt: OptKind,
    precond_every: usize,
    fwd_bwd_anchor_s: f64,
    gpus: usize,
) -> IterProjection {
    assert!(
        matches!(opt, OptKind::Shampoo | OptKind::Jorge),
        "sharded projection is for second-order optimizers, got {}",
        opt.name()
    );
    let every = precond_every.max(1) as f64;
    let shards = gpus.max(1) as f64;
    let pcount = net.param_count();
    let mut opt_t = gpu.elementwise_time(3 * pcount); // mom/gmom/params
    let mut refresh_t = 0.0; // owner-computes: divided by gpus
    let mut gather_bytes = 0usize;
    for l in &net.layers {
        if !l.preconditioned() {
            continue;
        }
        let (m, n) = (l.m, l.n);
        // preconditioning every step, on every replica: (LG)R
        opt_t += gpu.gemm_time(m, m, n) + gpu.gemm_time(m, n, n);
        match opt {
            OptKind::Shampoo => {
                // stats EMA runs on the owner every step
                refresh_t += (gpu.gemm_time(m, n, m)
                    + gpu.gemm_time(n, m, n)
                    + gpu.elementwise_time(m * m + n * n))
                    * every;
                refresh_t += gpu.syevd_time(m) + gpu.syevd_time(n);
            }
            OptKind::Jorge => {
                // grams + truncated-binomial update, only on update steps
                refresh_t += gpu.gemm_time(m, n, m) + 5.0 * gpu.gemm_time(m, m, m)
                    + gpu.elementwise_time(m * m);
                refresh_t += gpu.gemm_time(n, m, n) + 5.0 * gpu.gemm_time(n, n, n)
                    + gpu.elementwise_time(n * n);
            }
            _ => unreachable!(),
        }
        gather_bytes += 4 * (m * m + n * n);
    }
    opt_t += refresh_t / shards / every;
    let comm_s = comm.ring_all_reduce_time(4 * pcount, gpus)
        + comm.all_gather_time(gather_bytes, gpus) / every;
    IterProjection { fwd_bwd_s: fwd_bwd_anchor_s, optimizer_s: opt_t, comm_s }
}

/// Sharded projection with the deferred preconditioner exchange
/// (`--precond-overlap`): the all-gather of refreshed preconditioners
/// runs concurrently with the *next* step's forward+backward compute,
/// so an exchange step costs `max(all_gather_time, fwd_bwd)` instead of
/// their sum — amortised, only the gather's excess over the compute it
/// hides behind is charged to `comm_s`. Refresh FLOPs, the apply, and
/// the gradient ring all-reduce are unchanged (the reduce sits on the
/// critical path between backward and apply and cannot be hidden).
pub fn project_sharded_iteration_overlapped(
    gpu: &GpuModel,
    comm: &CommCostModel,
    net: &NetworkInventory,
    opt: OptKind,
    precond_every: usize,
    fwd_bwd_anchor_s: f64,
    gpus: usize,
) -> IterProjection {
    let sync =
        project_sharded_iteration(gpu, comm, net, opt, precond_every, fwd_bwd_anchor_s, gpus);
    let every = precond_every.max(1) as f64;
    let reduce_s = comm.ring_all_reduce_time(4 * net.param_count(), gpus);
    // per-exchange gather time (sync model amortises it by `every`)
    let gather_s = (sync.comm_s - reduce_s) * every;
    let hidden_excess = (gather_s - fwd_bwd_anchor_s).max(0.0);
    IterProjection {
        fwd_bwd_s: sync.fwd_bwd_s,
        optimizer_s: sync.optimizer_s,
        comm_s: reduce_s + hidden_excess / every,
    }
}

/// Modeled one-off cost of readmitting a dropped rank (elastic rejoin):
/// the leader tree-broadcasts the full training state — params plus the
/// optimizer's mirror state and preconditioners — to the restored
/// membership, exactly the bytes a checkpoint of the run would hold.
/// Charged to the step the rejoin lands on (the runtime mirrors this in
/// `FaultSession::resync_broadcast`); amortised over a long run it is
/// noise, but it bounds how often elasticity can be exercised before
/// resync traffic dominates the gradient all-reduce.
pub fn project_rejoin_resync(
    comm: &CommCostModel,
    net: &NetworkInventory,
    opt: OptKind,
    gpus: usize,
) -> f64 {
    let state_bytes = 4 * net.param_count() + crate::optim::memory::state_bytes(net, opt, true);
    comm.broadcast_time(state_bytes, gpus)
}

/// Overlapped variant of [`project_rejoin_resync`]: the state broadcast
/// runs concurrently with the rejoin step's forward+backward compute, so
/// the rejoin step costs `max(broadcast, fwd_bwd)` instead of
/// `fwd_bwd + broadcast`. Returns the modeled wall-clock of that step's
/// compute+resync portion (compare against `fwd_bwd_anchor_s +
/// project_rejoin_resync(..)` for the synchronous charge).
pub fn project_rejoin_resync_overlapped(
    comm: &CommCostModel,
    net: &NetworkInventory,
    opt: OptKind,
    gpus: usize,
    fwd_bwd_anchor_s: f64,
) -> f64 {
    project_rejoin_resync(comm, net, opt, gpus).max(fwd_bwd_anchor_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{deeplabv3_r50, resnet50};

    fn table1_setup() -> (GpuModel, CommCostModel) {
        (GpuModel::a100(), CommCostModel::nvlink_a100())
    }

    #[test]
    fn gemm_time_monotone_and_roofline() {
        let g = GpuModel::a100();
        assert!(g.gemm_time(1024, 1024, 1024) > g.gemm_time(256, 256, 256));
        // tiny GEMM is launch/memory bound, not 0
        assert!(g.gemm_time(8, 8, 8) >= g.launch);
    }

    #[test]
    fn syevd_anchor() {
        let g = GpuModel::a100();
        let t = g.syevd_time(1024);
        assert!((0.01..0.05).contains(&t), "syevd(1024) = {t}");
    }

    #[test]
    fn table1_resnet50_ordering_and_ratios() {
        // Paper Table 1 (bs 1024 / 16 GPUs): SGD 0.09, Jorge 0.09, Shampoo 0.12
        let (g, c) = table1_setup();
        let net = resnet50().blocked(1024);
        let sgd = project_iteration(&g, &c, &net, OptKind::Sgd, 50, 0.085, 16).total();
        let jorge = project_iteration(&g, &c, &net, OptKind::Jorge, 50, 0.085, 16).total();
        let shampoo = project_iteration(&g, &c, &net, OptKind::Shampoo, 50, 0.085, 16).total();
        assert!(jorge < shampoo, "jorge {jorge} !< shampoo {shampoo}");
        // Jorge within ~10% of SGD
        assert!(jorge / sgd < 1.12, "jorge/sgd = {}", jorge / sgd);
        // Shampoo 15-60% slower than SGD (paper: 33%)
        let ratio = shampoo / sgd;
        assert!((1.1..1.8).contains(&ratio), "shampoo/sgd = {ratio}");
    }

    #[test]
    fn table1_deeplab_ordering() {
        // Paper: SGD 0.33, Jorge 0.37, Shampoo 0.47 (bs 64 / 4 GPUs, every 50)
        let (g, c) = table1_setup();
        let net = deeplabv3_r50().blocked(1024);
        let sgd = project_iteration(&g, &c, &net, OptKind::Sgd, 50, 0.32, 4).total();
        let jorge = project_iteration(&g, &c, &net, OptKind::Jorge, 50, 0.32, 4).total();
        let shampoo = project_iteration(&g, &c, &net, OptKind::Shampoo, 50, 0.32, 4).total();
        assert!(sgd < jorge && jorge < shampoo);
        assert!(jorge / sgd < 1.25, "jorge/sgd = {}", jorge / sgd);
        assert!(shampoo / sgd > 1.15, "shampoo/sgd = {}", shampoo / sgd);
    }

    #[test]
    fn dist_shampoo_beats_serial_shampoo_but_not_jorge_by_much() {
        // Fig. 2-right structure: serial shampoo slowest per iter; dist
        // shampoo close to jorge; jorge still <= dist shampoo.
        let (g, c) = table1_setup();
        let net = resnet50().blocked(1024);
        let serial = project_iteration(&g, &c, &net, OptKind::Shampoo, 50, 0.085, 16).total();
        let dist = project_dist_shampoo_iteration(&g, &c, &net, 50, 0.085, 16).total();
        let jorge = project_iteration(&g, &c, &net, OptKind::Jorge, 50, 0.085, 16).total();
        assert!(dist < serial);
        assert!(jorge <= dist * 1.02, "jorge {jorge} vs dist {dist}");
    }

    #[test]
    fn sharded_shampoo_faster_than_serial_but_pays_gather_traffic() {
        let (g, c) = table1_setup();
        let net = resnet50().blocked(1024);
        let serial = project_iteration(&g, &c, &net, OptKind::Shampoo, 50, 0.085, 16);
        let sharded = project_sharded_iteration(&g, &c, &net, OptKind::Shampoo, 50, 0.085, 16);
        assert!(sharded.total() < serial.total(), "{} !< {}", sharded.total(), serial.total());
        // the all-gather of refreshed roots is charged on top of the
        // gradient all-reduce
        assert!(sharded.comm_s > serial.comm_s, "{} !> {}", sharded.comm_s, serial.comm_s);
    }

    #[test]
    fn sharded_jorge_cuts_refresh_cost_and_pays_gather_traffic() {
        let (g, c) = table1_setup();
        let net = resnet50().blocked(1024);
        let serial = project_iteration(&g, &c, &net, OptKind::Jorge, 50, 0.085, 16);
        let sharded = project_sharded_iteration(&g, &c, &net, OptKind::Jorge, 50, 0.085, 16);
        assert!(sharded.optimizer_s < serial.optimizer_s);
        assert!(sharded.comm_s > serial.comm_s);
    }

    #[test]
    fn overlapped_exchange_charges_max_not_sum() {
        let (g, c) = table1_setup();
        let net = resnet50().blocked(1024);
        for opt in [OptKind::Jorge, OptKind::Shampoo] {
            let sync = project_sharded_iteration(&g, &c, &net, opt, 50, 0.085, 16);
            let ovl = project_sharded_iteration_overlapped(&g, &c, &net, opt, 50, 0.085, 16);
            // compute terms untouched; only the gather charge shrinks
            assert_eq!(ovl.fwd_bwd_s, sync.fwd_bwd_s);
            assert_eq!(ovl.optimizer_s, sync.optimizer_s);
            assert!(ovl.comm_s <= sync.comm_s, "{} !<= {}", ovl.comm_s, sync.comm_s);
            assert!(ovl.total() <= sync.total());
            // the gradient all-reduce stays on the critical path
            let reduce = c.ring_all_reduce_time(4 * net.param_count(), 16);
            assert!(ovl.comm_s >= reduce);
            // the paper-scale gather hides entirely behind an 85 ms
            // fwd/bwd window, so overlapped comm == bare all-reduce
            assert!((ovl.comm_s - reduce).abs() < 1e-12, "{} vs {reduce}", ovl.comm_s);
        }
    }

    #[test]
    fn overlapped_exchange_still_pays_gather_excess_when_compute_is_tiny() {
        let (g, c) = table1_setup();
        let net = resnet50().blocked(1024);
        // a (hypothetical) 1 us fwd/bwd window hides almost nothing:
        // the overlapped charge degrades toward the synchronous sum
        let sync = project_sharded_iteration(&g, &c, &net, OptKind::Shampoo, 50, 1e-6, 16);
        let ovl =
            project_sharded_iteration_overlapped(&g, &c, &net, OptKind::Shampoo, 50, 1e-6, 16);
        let reduce = c.ring_all_reduce_time(4 * net.param_count(), 16);
        assert!(ovl.comm_s > reduce, "gather excess must surface: {}", ovl.comm_s);
        assert!(ovl.comm_s <= sync.comm_s);
        // max(comm, compute) identity on the exchange step: sum minus
        // overlapped == hidden portion <= fwd_bwd / every
        let hidden = sync.comm_s - ovl.comm_s;
        assert!(hidden <= 1e-6 / 50.0 + 1e-15, "hidden {hidden}");
    }

    #[test]
    fn overlapped_rejoin_resync_is_max_of_broadcast_and_compute() {
        let (_, c) = table1_setup();
        let net = resnet50().blocked(1024);
        let sync = project_rejoin_resync(&c, &net, OptKind::Jorge, 16);
        let ovl = project_rejoin_resync_overlapped(&c, &net, OptKind::Jorge, 16, 0.085);
        assert_eq!(ovl, sync.max(0.085));
        assert!(ovl <= 0.085 + sync, "{ovl} !<= fwd_bwd + {sync}");
        // a long compute window hides the whole broadcast
        assert_eq!(project_rejoin_resync_overlapped(&c, &net, OptKind::Jorge, 16, 10.0), 10.0);
    }

    #[test]
    #[should_panic(expected = "second-order")]
    fn sharded_projection_rejects_first_order() {
        let (g, c) = table1_setup();
        let net = resnet50().blocked(1024);
        project_sharded_iteration(&g, &c, &net, OptKind::Sgd, 50, 0.085, 16);
    }

    #[test]
    fn rejoin_resync_cost_is_positive_and_tracks_state_size() {
        let (_, c) = table1_setup();
        let net = resnet50().blocked(1024);
        let jorge = project_rejoin_resync(&c, &net, OptKind::Jorge, 16);
        assert!(jorge > 0.0);
        // a bigger world pays more tree hops for the same bytes
        assert!(project_rejoin_resync(&c, &net, OptKind::Jorge, 32) > jorge);
        // Shampoo carries stat EMAs on top of the preconditioners, so
        // its resync blob is at least as heavy as Jorge's
        assert!(project_rejoin_resync(&c, &net, OptKind::Shampoo, 16) >= jorge);
        // one resync should stay well under a full second on NVLink
        assert!(jorge < 1.0, "resync {jorge}s");
    }

    #[test]
    fn frequent_updates_hurt_shampoo_more_than_jorge() {
        let (g, c) = table1_setup();
        let net = resnet50().blocked(1024);
        let j1 = project_iteration(&g, &c, &net, OptKind::Jorge, 1, 0.085, 16).total();
        let s1 = project_iteration(&g, &c, &net, OptKind::Shampoo, 1, 0.085, 16).total();
        let j50 = project_iteration(&g, &c, &net, OptKind::Jorge, 50, 0.085, 16).total();
        let s50 = project_iteration(&g, &c, &net, OptKind::Shampoo, 50, 0.085, 16).total();
        assert!((s1 - s50) > (j1 - j50));
    }
}
