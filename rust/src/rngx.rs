//! Deterministic pseudo-random numbers (substrate).
//!
//! The offline build has no `rand` crate, so we carry a small, fast,
//! reproducible generator: SplitMix64 for seeding + xoshiro256** for the
//! stream. Everything downstream (synthetic datasets, parameter init,
//! property tests) is keyed off explicit seeds so runs are replayable.

/// SplitMix64 — used to expand a single u64 seed into generator state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Deterministic, seedable, fast; passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (e.g. per worker / per layer).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(mean, std) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            buckets[(u * 10.0) as usize] += 1;
        }
        for b in buckets {
            assert!((8_500..11_500).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for i in p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
