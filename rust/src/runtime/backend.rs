//! Pluggable execution backends.
//!
//! The coordinator is generic over *how* a step executes: `ExecBackend`
//! hands out `ExecStep`s by artifact name (the `train_*` / `grad_*` /
//! `apply_*` / `eval_*` naming scheme of `aot.py`), and an `ExecStep`
//! maps host tensors to host tensors. Two implementations exist:
//!
//! * [`crate::runtime::NativeBackend`] — pure Rust, always available:
//!   native model forward/backward (`nn`) + native optimizer mirrors
//!   (`optim`), no artifacts or system libraries required.
//! * [`crate::runtime::Engine`] — PJRT execution of the AOT-lowered HLO
//!   artifacts, behind the off-by-default `pjrt` cargo feature.

use super::manifest::{ArtifactSpec, Manifest};
use super::values::HostTensor;
use anyhow::Result;
use std::sync::Arc;

/// One executable step: a fixed I/O signature plus a run function.
pub trait ExecStep: Send + Sync {
    /// The manifest spec describing inputs/outputs of this step.
    fn spec(&self) -> &ArtifactSpec;

    /// Execute with host tensors; returns one host tensor per output.
    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;
}

/// A provider of executable steps plus the manifest describing them.
pub trait ExecBackend: Send + Sync {
    /// Human-readable platform tag ("native", "cpu", ...).
    fn platform(&self) -> String;

    /// The manifest: artifact I/O specs, model metadata, hyperparameters.
    fn manifest(&self) -> &Manifest;

    /// Resolve an artifact name to an executable step (cached).
    fn load(&self, name: &str) -> Result<Arc<dyn ExecStep>>;
}

/// The valid `backend_for` choices (also what `TrainConfig` validates).
pub const BACKEND_CHOICES: &[&str] = &["auto", "native", "pjrt"];

/// Build a backend by name: `"native"`, `"pjrt"`, or `"auto"`.
///
/// `auto` prefers PJRT when the crate is built with the `pjrt` feature
/// *and* `artifacts_dir` holds a manifest, and falls back to the native
/// backend otherwise — so a clean checkout trains out of the box.
pub fn backend_for(artifacts_dir: &str, choice: &str) -> Result<Arc<dyn ExecBackend>> {
    match choice {
        "native" => Ok(Arc::new(super::native::NativeBackend::new())),
        "pjrt" => pjrt_backend(artifacts_dir),
        "auto" => {
            if cfg!(feature = "pjrt")
                && std::path::Path::new(artifacts_dir).join("manifest.json").exists()
            {
                return pjrt_backend(artifacts_dir);
            }
            Ok(Arc::new(super::native::NativeBackend::new()))
        }
        other => {
            Err(anyhow::anyhow!("unknown backend {other:?} (choose {BACKEND_CHOICES:?})"))
        }
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(artifacts_dir: &str) -> Result<Arc<dyn ExecBackend>> {
    Ok(Arc::new(super::engine::Engine::new(artifacts_dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_artifacts_dir: &str) -> Result<Arc<dyn ExecBackend>> {
    Err(anyhow::anyhow!(
        "backend \"pjrt\" requires building with `--features pjrt` (and the xla crate; see README)"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_and_auto_resolve() {
        let b = backend_for("/nonexistent", "native").unwrap();
        assert_eq!(b.platform(), "native");
        // without artifacts, auto falls back to native
        let b = backend_for("/nonexistent", "auto").unwrap();
        assert_eq!(b.platform(), "native");
    }

    #[test]
    fn unknown_choice_is_error() {
        assert!(backend_for("artifacts", "tpu").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_without_feature_is_explained() {
        let err = backend_for("artifacts", "pjrt").unwrap_err().to_string();
        assert!(err.contains("--features pjrt"), "{err}");
    }
}
