//! PJRT execution engine: load HLO-text artifacts, compile once, execute
//! many times. Adapted from /opt/xla-example/load_hlo — HLO *text* is the
//! interchange format (see aot.py).

use super::backend::{ExecBackend, ExecStep};
use super::manifest::{ArtifactSpec, Manifest};
use super::values::HostTensor;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// A compiled artifact with its manifest spec.
pub struct CompiledStep {
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
}

impl CompiledStep {
    /// Execute with host tensors; returns one host tensor per output.
    ///
    /// The executables are lowered with `return_tuple=True`, so PJRT
    /// hands back a single tuple buffer which we decompose host-side.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape() != spec.shape.as_slice() {
                return Err(anyhow!(
                    "{}: input {} shape {:?} != spec {:?}",
                    self.spec.name,
                    spec.name,
                    t.shape(),
                    spec.shape
                ));
            }
        }
        let literals: Vec<Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            return Err(anyhow!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            ));
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }
}

/// PJRT client + compiled-executable cache.
pub struct Engine {
    pub manifest: Manifest,
    client: PjRtClient,
    cache: Mutex<BTreeMap<String, std::sync::Arc<CompiledStep>>>,
}

// PJRT CPU client and executables are internally synchronized.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
unsafe impl Send for CompiledStep {}
unsafe impl Sync for CompiledStep {}

impl Engine {
    pub fn new(artifacts_dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
        let client = PjRtClient::cpu()?;
        Ok(Engine { manifest, client, cache: Mutex::new(BTreeMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<CompiledStep>> {
        if let Some(hit) = self.cache.lock().unwrap().get(name) {
            return Ok(hit.clone());
        }
        let spec = self.manifest.artifact(name).map_err(|e| anyhow!(e))?.clone();
        let path = self.manifest.artifact_path(&spec);
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let step = std::sync::Arc::new(CompiledStep { spec, exe });
        self.cache.lock().unwrap().insert(name.to_string(), step.clone());
        Ok(step)
    }
}

impl ExecStep for CompiledStep {
    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        CompiledStep::run(self, inputs)
    }
}

impl ExecBackend for Engine {
    fn platform(&self) -> String {
        Engine::platform(self)
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, name: &str) -> Result<std::sync::Arc<dyn ExecStep>> {
        let step = Engine::load(self, name)?;
        Ok(step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn artifacts_dir() -> String {
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
    }

    fn engine() -> Option<Engine> {
        if !std::path::Path::new(&artifacts_dir()).join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Engine::new(&artifacts_dir()).unwrap())
    }

    #[test]
    fn kernel_matmul_artifact_matches_native_gemm() {
        let Some(eng) = engine() else { return };
        let step = eng.load("kernel_matmul").unwrap();
        let mut rng = Rng::new(0);
        let a = crate::tensor::Matrix::randn(48, 32, 1.0, &mut rng);
        let b = crate::tensor::Matrix::randn(32, 56, 1.0, &mut rng);
        let out = step
            .run(&[
                HostTensor::from_f32(vec![48, 32], a.data.clone()),
                HostTensor::from_f32(vec![32, 56], b.data.clone()),
            ])
            .unwrap();
        let want = crate::tensor::matmul(&a, &b);
        let got = out[0].as_f32().unwrap();
        let max_err = got
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "pallas-artifact vs native gemm: {max_err}");
    }

    #[test]
    fn kernel_jorge_update_artifact_matches_native_mirror() {
        let Some(eng) = engine() else { return };
        let step = eng.load("kernel_jorge_update").unwrap();
        let mut rng = Rng::new(1);
        let g = crate::tensor::Matrix::randn(64, 40, 0.3, &mut rng);
        let s = crate::tensor::gram_left(&g);
        let p = crate::tensor::Matrix::eye(64, (1e-6f32).powf(-0.25));
        let out = step
            .run(&[
                HostTensor::from_f32(vec![64, 64], p.data.clone()),
                HostTensor::from_f32(vec![64, 64], s.data.clone()),
            ])
            .unwrap();
        let want = crate::tensor::jorge_update(&p, &s);
        let got = out[0].as_f32().unwrap();
        let scale = want.max_abs();
        let max_err = got
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_err / scale < 1e-3,
            "HLO jorge_update vs rust mirror: rel {}",
            max_err / scale
        );
    }

    #[test]
    fn kernel_newton_root_artifact_matches_native() {
        let Some(eng) = engine() else { return };
        let step = eng.load("kernel_newton_root").unwrap();
        let mut rng = Rng::new(2);
        let g = crate::tensor::Matrix::randn(32, 32, 0.5, &mut rng);
        let mut a = crate::tensor::gram_left(&g);
        a.scale_inplace(1.0 / 32.0);
        for i in 0..32 {
            a.data[i * 32 + i] += 0.1;
        }
        let out = step
            .run(&[HostTensor::from_f32(vec![32, 32], a.data.clone())])
            .unwrap();
        let want = crate::tensor::inv_fourth_root_newton(&a, 15, 1e-6);
        let got = out[0].as_f32().unwrap();
        let max_err = got
            .iter()
            .zip(&want.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err / want.max_abs() < 5e-3, "rel {}", max_err / want.max_abs());
    }

    #[test]
    fn run_rejects_wrong_arity_and_shape() {
        let Some(eng) = engine() else { return };
        let step = eng.load("kernel_matmul").unwrap();
        assert!(step.run(&[]).is_err());
        let bad = vec![
            HostTensor::from_f32(vec![4, 4], vec![0.0; 16]),
            HostTensor::from_f32(vec![32, 56], vec![0.0; 32 * 56]),
        ];
        assert!(step.run(&bad).is_err());
    }

    #[test]
    fn executable_cache_reuses() {
        let Some(eng) = engine() else { return };
        let a = eng.load("kernel_matmul").unwrap();
        let b = eng.load("kernel_matmul").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }
}
