//! Typed view of `artifacts/manifest.json` (written by `aot.py`).

use crate::jsonio::Json;
use crate::optim::OptimizerKind;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self, String> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(format!("unknown dtype {other:?}")),
        }
    }
}

/// Initialisation rule for a param/state tensor.
#[derive(Clone, Debug, PartialEq)]
pub enum Init {
    Zeros,
    Ones,
    Eye { scale: f32 },
    He { fan_in: usize, scale: f32 },
    Normal { std: f32 },
}

impl Init {
    fn parse(j: &Json) -> Result<Init, String> {
        let kind = j.get("kind").and_then(|k| k.as_str()).ok_or("init: no kind")?;
        match kind {
            "zeros" => Ok(Init::Zeros),
            "ones" => Ok(Init::Ones),
            "eye" => Ok(Init::Eye {
                scale: j.get("scale").and_then(|v| v.as_f64()).ok_or("eye: no scale")? as f32,
            }),
            "he" => Ok(Init::He {
                fan_in: j.get("fan_in").and_then(|v| v.as_usize()).ok_or("he: no fan_in")?,
                scale: j.get("scale").and_then(|v| v.as_f64()).unwrap_or(1.0) as f32,
            }),
            "normal" => Ok(Init::Normal {
                std: j.get("std").and_then(|v| v.as_f64()).ok_or("normal: no std")? as f32,
            }),
            other => Err(format!("unknown init kind {other:?}")),
        }
    }
}

/// The role an input/output plays in the step signature.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Param,
    State,
    Grad,
    X,
    Y,
    Lr,
    Wd,
    Loss,
    Metric,
    In,
    Out,
}

impl Role {
    fn parse(s: &str) -> Result<Self, String> {
        Ok(match s {
            "param" => Role::Param,
            "state" => Role::State,
            "grad" => Role::Grad,
            "x" => Role::X,
            "y" => Role::Y,
            "lr" => Role::Lr,
            "wd" => Role::Wd,
            "loss" => Role::Loss,
            "metric" => Role::Metric,
            "in" => Role::In,
            "out" => Role::Out,
            other => return Err(format!("unknown role {other:?}")),
        })
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub role: Role,
    pub init: Option<Init>,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub model: Option<String>,
    pub optimizer: Option<String>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    pub fn input_index(&self, role: Role) -> Option<usize> {
        self.inputs.iter().position(|i| i.role == role)
    }
    pub fn count_inputs(&self, role: Role) -> usize {
        self.inputs.iter().filter(|i| i.role == role).count()
    }
    pub fn count_outputs(&self, role: Role) -> usize {
        self.outputs.iter().filter(|o| o.role == role).count()
    }
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub metric: String,
    pub batch: usize,
    pub eval_batch: usize,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    pub param_count: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelMeta>,
    pub hyper: BTreeMap<String, f64>,
}

fn parse_io(j: &Json) -> Result<IoSpec, String> {
    let name = j.get("name").and_then(|v| v.as_str()).ok_or("io: no name")?.to_string();
    let shape = j
        .get("shape")
        .and_then(|v| v.as_arr())
        .ok_or("io: no shape")?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| "io: bad dim".to_string()))
        .collect::<Result<Vec<_>, _>>()?;
    let dtype = Dtype::parse(j.get("dtype").and_then(|v| v.as_str()).unwrap_or("f32"))?;
    let role = Role::parse(j.get("role").and_then(|v| v.as_str()).ok_or("io: no role")?)?;
    let init = match j.get("init") {
        Some(i) => Some(Init::parse(i)?),
        None => None,
    };
    Ok(IoSpec { name, shape, dtype, role, init })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {path:?}: {e}. Run `make artifacts` first."))?;
        let j = Json::parse(&text)?;

        let mut artifacts = BTreeMap::new();
        for (name, art) in j.get("artifacts").and_then(|a| a.as_obj()).ok_or("no artifacts")? {
            let inputs = art
                .get("inputs")
                .and_then(|v| v.as_arr())
                .ok_or("artifact: no inputs")?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>, _>>()
                .map_err(|e| format!("{name}: {e}"))?;
            let outputs = art
                .get("outputs")
                .and_then(|v| v.as_arr())
                .ok_or("artifact: no outputs")?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>, _>>()
                .map_err(|e| format!("{name}: {e}"))?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: art.get("file").and_then(|v| v.as_str()).ok_or("no file")?.to_string(),
                    kind: art.get("kind").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
                    model: art.get("model").and_then(|v| v.as_str()).map(String::from),
                    optimizer: art.get("optimizer").and_then(|v| v.as_str()).map(String::from),
                    inputs,
                    outputs,
                },
            );
        }

        let mut models = BTreeMap::new();
        if let Some(ms) = j.get("models").and_then(|m| m.as_obj()) {
            for (name, m) in ms {
                models.insert(
                    name.clone(),
                    ModelMeta {
                        name: name.clone(),
                        metric: m.get("metric").and_then(|v| v.as_str()).unwrap_or("?").into(),
                        batch: m.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
                        eval_batch: m.get("eval_batch").and_then(|v| v.as_usize()).unwrap_or(0),
                        x_shape: m
                            .get("x_shape")
                            .and_then(|v| v.as_arr())
                            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                            .unwrap_or_default(),
                        y_shape: m
                            .get("y_shape")
                            .and_then(|v| v.as_arr())
                            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                            .unwrap_or_default(),
                        param_count: m.get("param_count").and_then(|v| v.as_usize()).unwrap_or(0),
                    },
                );
            }
        }

        let mut hyper = BTreeMap::new();
        if let Some(h) = j.get("hyper").and_then(|h| h.as_obj()) {
            for (k, v) in h {
                if let Some(f) = v.as_f64() {
                    hyper.insert(k.clone(), f);
                }
            }
        }

        Ok(Manifest { dir, artifacts, models, hyper })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec, String> {
        self.artifacts
            .get(name)
            .ok_or_else(|| format!("artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().take(8).collect::<Vec<_>>()))
    }

    pub fn artifact_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Train artifact name for (model, optimizer, update_precond).
    /// Sharded variants reuse the serial artifacts — sharding changes who
    /// refreshes, not what the kernel computes.
    pub fn train_name(model: &str, opt: OptimizerKind, update_precond: bool) -> String {
        let base = opt.base_name();
        if update_precond || !opt.has_skip() {
            format!("train_{model}_{base}")
        } else {
            format!("train_{model}_{base}_skip")
        }
    }

    pub fn apply_name(model: &str, opt: OptimizerKind, update_precond: bool) -> String {
        let base = opt.base_name();
        if update_precond || !opt.has_skip() {
            format!("apply_{model}_{base}")
        } else {
            format!("apply_{model}_{base}_skip")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert!(m.artifacts.contains_key("train_mlp_jorge"));
        assert!(m.models.contains_key("mlp"));
        let art = m.artifact("train_mlp_jorge").unwrap();
        // trailing inputs are x, y, lr, wd
        let roles: Vec<Role> = art.inputs.iter().map(|i| i.role).collect();
        assert_eq!(&roles[roles.len() - 4..], &[Role::X, Role::Y, Role::Lr, Role::Wd]);
        assert_eq!(art.count_outputs(Role::Loss), 1);
        // every param/state input has an init rule
        for i in &art.inputs {
            if matches!(i.role, Role::Param | Role::State) {
                assert!(i.init.is_some(), "{}", i.name);
            }
        }
        assert!(m.artifact_path(art).exists());
    }

    #[test]
    fn train_and_apply_names() {
        assert_eq!(Manifest::train_name("mlp", OptimizerKind::SGD, false), "train_mlp_sgd");
        assert_eq!(Manifest::train_name("mlp", OptimizerKind::JORGE, true), "train_mlp_jorge");
        assert_eq!(Manifest::train_name("mlp", OptimizerKind::JORGE, false), "train_mlp_jorge_skip");
        assert_eq!(
            Manifest::apply_name("cnn", OptimizerKind::SHAMPOO, false),
            "apply_cnn_shampoo_skip"
        );
        // Sharded kinds map onto the serial artifact set.
        assert_eq!(
            Manifest::train_name("mlp", OptimizerKind::JORGE_SHARDED, false),
            "train_mlp_jorge_skip"
        );
        assert_eq!(
            Manifest::apply_name("mlp", OptimizerKind::SHAMPOO_SHARDED, true),
            "apply_mlp_shampoo"
        );
    }

    #[test]
    fn missing_dir_is_error_with_hint() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn hyper_values_present() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert_eq!(m.hyper.get("beta1").copied(), Some(0.9));
        assert!(m.hyper.contains_key("precond_eps"));
    }
}
