//! PJRT runtime: manifest-driven artifact loading and execution.
//!
//! `Engine` wraps the `xla` crate's PJRT CPU client; `CompiledStep` pairs
//! a compiled executable with its manifest I/O spec so the coordinator is
//! generic over models and optimizers. Host tensors (`HostTensor`) carry
//! dtype-tagged data between the coordinator and the device.

pub mod engine;
pub mod manifest;
pub mod values;

pub use engine::{CompiledStep, Engine};
pub use manifest::{ArtifactSpec, Dtype, Init, IoSpec, Manifest, Role};
pub use values::HostTensor;
