//! Execution runtime: manifest-driven step loading and execution.
//!
//! [`ExecBackend`]/[`ExecStep`] abstract over how a training step runs so
//! the coordinator is generic over models, optimizers *and* execution
//! substrates. [`NativeBackend`] drives the pure-Rust model and optimizer
//! mirrors and is always available; [`Engine`] (behind the `pjrt` cargo
//! feature) compiles and executes the AOT-lowered HLO-text artifacts
//! through the `xla` crate's PJRT CPU client. Host tensors
//! ([`HostTensor`]) carry dtype-tagged data between the coordinator and
//! whichever backend is active.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod native;
pub mod values;

pub use backend::{backend_for, ExecBackend, ExecStep, BACKEND_CHOICES};
#[cfg(feature = "pjrt")]
pub use engine::{CompiledStep, Engine};
pub use manifest::{ArtifactSpec, Dtype, Init, IoSpec, Manifest, Role};
pub use native::NativeBackend;
pub use values::HostTensor;
