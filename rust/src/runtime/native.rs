//! Pure-Rust execution backend — no artifacts, no system libraries.
//!
//! `NativeBackend` synthesises the same manifest surface `aot.py` writes
//! (`train_*` / `grad_*` / `apply_*` / `eval_*` artifacts with typed I/O
//! specs and init rules) and executes each step natively: model
//! forward/backward from [`crate::nn`], optimizer updates from the
//! mirrors in [`crate::optim`]. Steps are stateless — optimizer state is
//! round-tripped through the step's State tensors exactly like the HLO
//! artifacts do it, so fused-vs-split execution and checkpointing behave
//! identically across backends.

use super::backend::{ExecBackend, ExecStep};
use super::manifest::{ArtifactSpec, Dtype, Init, IoSpec, Manifest, ModelMeta, Role};
use super::values::HostTensor;
use crate::nn::{self, BatchRef, NativeModel};
use crate::optim::{self, Hyper, StepCtx};
use crate::tensor::Matrix;
use crate::trace::{self, Phase};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

const OPTS: &[&str] = &["sgd", "adamw", "shampoo", "jorge"];

/// What a native step does when run.
enum Kind {
    Train { opt: String, update_precond: bool },
    Grad,
    Apply { opt: String, update_precond: bool },
    Eval,
}

/// One stateless native step (see module docs).
pub struct NativeStep {
    spec: ArtifactSpec,
    model: Arc<dyn NativeModel>,
    kind: Kind,
    hyper: Hyper,
}

/// The always-available pure-Rust backend.
pub struct NativeBackend {
    manifest: Manifest,
    hyper: Hyper,
    models: BTreeMap<String, Arc<dyn NativeModel>>,
    cache: Mutex<BTreeMap<String, Arc<dyn ExecStep>>>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        // Spawn the GEMM worker pool now so the first train step doesn't
        // pay thread-creation latency inside a timed iteration.
        crate::tensor::warm_pool();
        let hyper = Hyper::default();
        let mut models: BTreeMap<String, Arc<dyn NativeModel>> = BTreeMap::new();
        for name in nn::MODEL_NAMES {
            let model = nn::for_model(name).expect("builtin model");
            models.insert(name.to_string(), Arc::from(model));
        }
        let manifest = build_manifest(&models, &hyper);
        NativeBackend { manifest, hyper, models, cache: Mutex::new(BTreeMap::new()) }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl ExecBackend for NativeBackend {
    fn platform(&self) -> String {
        "native".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, name: &str) -> Result<Arc<dyn ExecStep>> {
        if let Some(hit) = self.cache.lock().unwrap().get(name) {
            return Ok(hit.clone());
        }
        let spec = self.manifest.artifact(name).map_err(|e| anyhow!(e))?.clone();
        let model_name =
            spec.model.clone().ok_or_else(|| anyhow!("{name}: artifact has no model"))?;
        let model = self
            .models
            .get(&model_name)
            .ok_or_else(|| anyhow!("{name}: unknown model {model_name}"))?
            .clone();
        let update_precond = !name.ends_with("_skip");
        let kind = match spec.kind.as_str() {
            "train" => Kind::Train {
                opt: spec.optimizer.clone().unwrap_or_default(),
                update_precond,
            },
            "grad" => Kind::Grad,
            "apply" => Kind::Apply {
                opt: spec.optimizer.clone().unwrap_or_default(),
                update_precond,
            },
            "eval" => Kind::Eval,
            other => return Err(anyhow!("{name}: unknown artifact kind {other:?}")),
        };
        let step: Arc<dyn ExecStep> =
            Arc::new(NativeStep { spec, model, kind, hyper: self.hyper });
        self.cache.lock().unwrap().insert(name.to_string(), step.clone());
        Ok(step)
    }
}

impl ExecStep for NativeStep {
    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape() != spec.shape.as_slice() {
                return Err(anyhow!(
                    "{}: input {} shape {:?} != spec {:?}",
                    self.spec.name,
                    spec.name,
                    t.shape(),
                    spec.shape
                ));
            }
        }

        // partition inputs by role, preserving order. Attribute the input
        // unpacking to the Data phase only for the kinds the trainer does
        // not already wrap in a scope of its own (Apply is wrapped by
        // `apply_reduced`, Eval by `evaluate`).
        let data_scope = match self.kind {
            Kind::Train { .. } | Kind::Grad => Some(trace::scope(Phase::Data)),
            Kind::Apply { .. } | Kind::Eval => None,
        };
        let mut params_in: Vec<&HostTensor> = Vec::new();
        let mut grads_in: Vec<&HostTensor> = Vec::new();
        let mut state_in: Vec<&HostTensor> = Vec::new();
        let (mut x, mut y, mut lr, mut wd) = (None, None, None, None);
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            match spec.role {
                Role::Param => params_in.push(t),
                Role::Grad => grads_in.push(t),
                Role::State => state_in.push(t),
                Role::X => x = Some(t),
                Role::Y => y = Some(t),
                Role::Lr => lr = Some(t),
                Role::Wd => wd = Some(t),
                _ => {}
            }
        }
        let mut mats = to_matrices(&params_in)?;
        let lr = lr.map(|t| t.scalar() as f32).unwrap_or(0.0);
        let wd = wd.map(|t| t.scalar() as f32).unwrap_or(0.0);
        drop(data_scope);

        match &self.kind {
            Kind::Train { opt, update_precond } => {
                let batch = batch_ref(need(x, "x")?, need(y, "y")?)?;
                let (grads, loss, metric) = self.model.loss_grad(&mats, &batch);
                // The fused opt.step() runs refresh + apply back to back
                // (it does not route through the scoped trait halves), so
                // the whole optimizer cost lands in Apply here.
                let _apply_scope = trace::scope(Phase::Apply);
                let state_out = apply_optimizer(
                    opt,
                    self.hyper,
                    &mut mats,
                    &grads,
                    &state_in,
                    lr,
                    wd,
                    *update_precond,
                )?;
                let mut out = tensors_from(&mats, &params_in);
                out.extend(state_out);
                out.push(HostTensor::scalar_f32(loss as f32));
                out.push(HostTensor::scalar_f32(metric as f32));
                Ok(out)
            }
            Kind::Grad => {
                let batch = batch_ref(need(x, "x")?, need(y, "y")?)?;
                let (grads, loss, metric) = self.model.loss_grad(&mats, &batch);
                let mut out: Vec<HostTensor> = grads
                    .iter()
                    .zip(&params_in)
                    .map(|(g, p)| HostTensor::from_f32(p.shape().to_vec(), g.data.clone()))
                    .collect();
                out.push(HostTensor::scalar_f32(loss as f32));
                out.push(HostTensor::scalar_f32(metric as f32));
                Ok(out)
            }
            Kind::Apply { opt, update_precond } => {
                let gmats = to_matrices(&grads_in)?;
                let state_out = apply_optimizer(
                    opt,
                    self.hyper,
                    &mut mats,
                    &gmats,
                    &state_in,
                    lr,
                    wd,
                    *update_precond,
                )?;
                let mut out = tensors_from(&mats, &params_in);
                out.extend(state_out);
                Ok(out)
            }
            Kind::Eval => {
                let batch = batch_ref(need(x, "x")?, need(y, "y")?)?;
                let (loss, metric) = self.model.loss_metric(&mats, &batch);
                Ok(vec![
                    HostTensor::scalar_f32(loss as f32),
                    HostTensor::scalar_f32(metric as f32),
                ])
            }
        }
    }
}

fn need<'a>(t: Option<&'a HostTensor>, what: &str) -> Result<&'a HostTensor> {
    t.ok_or_else(|| anyhow!("missing {what} input"))
}

fn to_matrix(t: &HostTensor) -> Result<Matrix> {
    let d = t.as_f32().ok_or_else(|| anyhow!("expected f32 tensor"))?;
    let sh = t.shape();
    let rows = sh.first().copied().unwrap_or(1);
    let cols = sh.get(1).copied().unwrap_or(1);
    Ok(Matrix::from_vec(rows, cols, d.to_vec()))
}

fn to_matrices(ts: &[&HostTensor]) -> Result<Vec<Matrix>> {
    ts.iter().map(|t| to_matrix(t)).collect()
}

fn tensors_from(mats: &[Matrix], like: &[&HostTensor]) -> Vec<HostTensor> {
    mats.iter()
        .zip(like)
        .map(|(m, t)| HostTensor::from_f32(t.shape().to_vec(), m.data.clone()))
        .collect()
}

fn batch_ref<'a>(x: &'a HostTensor, y: &'a HostTensor) -> Result<BatchRef<'a>> {
    let batch = x.shape().first().copied().unwrap_or(1);
    let (x_f32, x_i32): (&[f32], &[i32]) = match x {
        HostTensor::F32 { data, .. } => (data.as_slice(), &[]),
        HostTensor::I32 { data, .. } => (&[], data.as_slice()),
    };
    let y = y.as_i32().ok_or_else(|| anyhow!("labels must be i32"))?;
    Ok(BatchRef { batch, x_f32, x_i32, y })
}

/// Build the optimizer, import state, step, export state.
fn apply_optimizer(
    opt_name: &str,
    hyper: Hyper,
    params: &mut [Matrix],
    grads: &[Matrix],
    state_in: &[&HostTensor],
    lr: f32,
    wd: f32,
    update_precond: bool,
) -> Result<Vec<HostTensor>> {
    let shapes: Vec<(usize, usize)> = params.iter().map(|p| (p.rows, p.cols)).collect();
    let kind: optim::OptimizerKind = opt_name.parse().map_err(|e: String| anyhow!(e))?;
    let mut opt = optim::build(kind, &shapes, hyper);
    let has_counter = opt_name == "adamw";
    let nslots = state_in.len() - usize::from(has_counter);
    {
        let mut slots = opt.state_mut();
        if slots.len() != nslots {
            return Err(anyhow!(
                "{opt_name}: state arity mismatch ({} tensors vs {} slots)",
                nslots,
                slots.len()
            ));
        }
        for (slot, t) in slots.iter_mut().zip(&state_in[..nslots]) {
            let d = t.as_f32().ok_or_else(|| anyhow!("state must be f32"))?;
            if d.len() != slot.data.len() {
                return Err(anyhow!("{opt_name}: state tensor length mismatch"));
            }
            slot.data.copy_from_slice(d);
        }
    }
    if has_counter {
        let t = state_in[nslots].as_f32().ok_or_else(|| anyhow!("counter must be f32"))?;
        opt.set_step_count(t[0] as u64);
    }
    opt.step(params, grads, StepCtx { lr, weight_decay: wd, update_precond });
    let mut out = Vec::with_capacity(state_in.len());
    {
        let mut slots = opt.state_mut();
        for (slot, t) in slots.iter_mut().zip(&state_in[..nslots]) {
            out.push(HostTensor::from_f32(t.shape().to_vec(), slot.data.clone()));
        }
    }
    if has_counter {
        out.push(HostTensor::from_f32(vec![1], vec![opt.step_count() as f32]));
    }
    Ok(out)
}

// -- manifest synthesis ------------------------------------------------------

fn fspec(name: String, shape: Vec<usize>, role: Role, init: Option<Init>) -> IoSpec {
    IoSpec { name, shape, dtype: Dtype::F32, role, init }
}

fn param_iospecs(model: &dyn NativeModel, role: Role, with_init: bool) -> Vec<IoSpec> {
    model
        .spec()
        .params
        .iter()
        .map(|p| {
            fspec(
                p.name.clone(),
                vec![p.rows, p.cols],
                role,
                if with_init { Some(p.init.clone()) } else { None },
            )
        })
        .collect()
}

/// State tensor specs in exactly the order `Optimizer::state_mut` exposes
/// them (plus AdamW's trailing step counter).
fn state_iospecs(opt: &str, shapes: &[(usize, usize)], hyper: &Hyper, role: Role) -> Vec<IoSpec> {
    let eps = hyper.precond_eps;
    let pscale = eps.powf(-0.25);
    let mut out = Vec::new();
    match opt {
        "sgd" => {
            for (i, &(m, n)) in shapes.iter().enumerate() {
                out.push(fspec(format!("mom_{i}"), vec![m, n], role, Some(Init::Zeros)));
            }
        }
        "adamw" => {
            for (i, &(m, n)) in shapes.iter().enumerate() {
                out.push(fspec(format!("exp_avg_{i}"), vec![m, n], role, Some(Init::Zeros)));
            }
            for (i, &(m, n)) in shapes.iter().enumerate() {
                out.push(fspec(format!("exp_avg_sq_{i}"), vec![m, n], role, Some(Init::Zeros)));
            }
            out.push(fspec("t".to_string(), vec![1], role, Some(Init::Zeros)));
        }
        "shampoo" => {
            for (i, &(m, n)) in shapes.iter().enumerate() {
                if m > 1 && n > 1 {
                    out.push(fspec(
                        format!("lstat_{i}"),
                        vec![m, m],
                        role,
                        Some(Init::Eye { scale: eps }),
                    ));
                    out.push(fspec(
                        format!("rstat_{i}"),
                        vec![n, n],
                        role,
                        Some(Init::Eye { scale: eps }),
                    ));
                    out.push(fspec(
                        format!("pl_{i}"),
                        vec![m, m],
                        role,
                        Some(Init::Eye { scale: pscale }),
                    ));
                    out.push(fspec(
                        format!("pr_{i}"),
                        vec![n, n],
                        role,
                        Some(Init::Eye { scale: pscale }),
                    ));
                }
                out.push(fspec(format!("mom_{i}"), vec![m, n], role, Some(Init::Zeros)));
                out.push(fspec(format!("gmom_{i}"), vec![m, n], role, Some(Init::Zeros)));
            }
        }
        "jorge" => {
            for (i, &(m, n)) in shapes.iter().enumerate() {
                if m > 1 && n > 1 {
                    out.push(fspec(
                        format!("l_hat_{i}"),
                        vec![m, m],
                        role,
                        Some(Init::Eye { scale: pscale }),
                    ));
                    out.push(fspec(
                        format!("r_hat_{i}"),
                        vec![n, n],
                        role,
                        Some(Init::Eye { scale: pscale }),
                    ));
                }
                out.push(fspec(format!("mom_{i}"), vec![m, n], role, Some(Init::Zeros)));
                out.push(fspec(format!("gmom_{i}"), vec![m, n], role, Some(Init::Zeros)));
            }
        }
        _ => {}
    }
    out
}

fn batch_io(model: &dyn NativeModel, batch: usize) -> (IoSpec, IoSpec) {
    let spec = model.spec();
    let mut x_shape = vec![batch];
    x_shape.extend(&spec.x_sample);
    let mut y_shape = vec![batch];
    y_shape.extend(&spec.y_sample);
    let x = IoSpec {
        name: "x".to_string(),
        shape: x_shape,
        dtype: spec.x_dtype,
        role: Role::X,
        init: None,
    };
    let y = IoSpec {
        name: "y".to_string(),
        shape: y_shape,
        dtype: Dtype::I32,
        role: Role::Y,
        init: None,
    };
    (x, y)
}

fn scalar_out(name: &str, role: Role) -> IoSpec {
    fspec(name.to_string(), vec![], role, None)
}

fn build_manifest(models: &BTreeMap<String, Arc<dyn NativeModel>>, hyper: &Hyper) -> Manifest {
    let mut artifacts = BTreeMap::new();
    let mut metas = BTreeMap::new();

    for (mname, model) in models {
        let spec = model.spec();
        let shapes = spec.shapes();
        metas.insert(
            mname.clone(),
            ModelMeta {
                name: mname.clone(),
                metric: spec.metric.to_string(),
                batch: spec.batch,
                eval_batch: spec.eval_batch,
                x_shape: {
                    let mut s = vec![spec.batch];
                    s.extend(&spec.x_sample);
                    s
                },
                y_shape: {
                    let mut s = vec![spec.batch];
                    s.extend(&spec.y_sample);
                    s
                },
                param_count: spec.param_count(),
            },
        );

        let (x, y) = batch_io(model.as_ref(), spec.batch);
        let (ex, ey) = batch_io(model.as_ref(), spec.eval_batch);
        let lr = fspec("lr".to_string(), vec![], Role::Lr, None);
        let wd = fspec("wd".to_string(), vec![], Role::Wd, None);
        let params_in = param_iospecs(model.as_ref(), Role::Param, true);
        let params_out = param_iospecs(model.as_ref(), Role::Param, false);
        let grads_io = param_iospecs(model.as_ref(), Role::Grad, false);

        // grad_{model}: params, x, y -> grads, loss, metric
        let mut inputs = params_out.clone();
        inputs.push(x.clone());
        inputs.push(y.clone());
        let mut outputs = grads_io.clone();
        outputs.push(scalar_out("loss", Role::Loss));
        outputs.push(scalar_out("metric", Role::Metric));
        let name = format!("grad_{mname}");
        artifacts.insert(
            name.clone(),
            ArtifactSpec {
                name,
                file: String::new(),
                kind: "grad".to_string(),
                model: Some(mname.clone()),
                optimizer: None,
                inputs,
                outputs,
            },
        );

        // eval_{model}: params, x, y -> loss, metric (held-out batch size)
        let mut inputs = params_out.clone();
        inputs.push(ex);
        inputs.push(ey);
        let outputs =
            vec![scalar_out("loss", Role::Loss), scalar_out("metric", Role::Metric)];
        let name = format!("eval_{mname}");
        artifacts.insert(
            name.clone(),
            ArtifactSpec {
                name,
                file: String::new(),
                kind: "eval".to_string(),
                model: Some(mname.clone()),
                optimizer: None,
                inputs,
                outputs,
            },
        );

        for opt in OPTS {
            let state_in = state_iospecs(opt, &shapes, hyper, Role::State);
            let state_out = state_iospecs(opt, &shapes, hyper, Role::State)
                .into_iter()
                .map(|mut s| {
                    s.init = None;
                    s
                })
                .collect::<Vec<_>>();
            let has_skip = matches!(*opt, "shampoo" | "jorge");
            let variants: &[&str] = if has_skip { &["", "_skip"] } else { &[""] };
            for suffix in variants {
                // train_{model}_{opt}[_skip]:
                //   params, state, x, y, lr, wd -> params, state, loss, metric
                let mut inputs = params_in.clone();
                inputs.extend(state_in.clone());
                inputs.push(x.clone());
                inputs.push(y.clone());
                inputs.push(lr.clone());
                inputs.push(wd.clone());
                let mut outputs = params_out.clone();
                outputs.extend(state_out.clone());
                outputs.push(scalar_out("loss", Role::Loss));
                outputs.push(scalar_out("metric", Role::Metric));
                let name = format!("train_{mname}_{opt}{suffix}");
                artifacts.insert(
                    name.clone(),
                    ArtifactSpec {
                        name,
                        file: String::new(),
                        kind: "train".to_string(),
                        model: Some(mname.clone()),
                        optimizer: Some(opt.to_string()),
                        inputs,
                        outputs,
                    },
                );

                // apply_{model}_{opt}[_skip]:
                //   params, grads, state, lr, wd -> params, state
                let mut inputs = params_in.clone();
                inputs.extend(grads_io.clone());
                inputs.extend(state_in.clone());
                inputs.push(lr.clone());
                inputs.push(wd.clone());
                let mut outputs = params_out.clone();
                outputs.extend(state_out.clone());
                let name = format!("apply_{mname}_{opt}{suffix}");
                artifacts.insert(
                    name.clone(),
                    ArtifactSpec {
                        name,
                        file: String::new(),
                        kind: "apply".to_string(),
                        model: Some(mname.clone()),
                        optimizer: Some(opt.to_string()),
                        inputs,
                        outputs,
                    },
                );
            }
        }
    }

    let mut hyper_map = BTreeMap::new();
    hyper_map.insert("beta1".to_string(), hyper.beta1 as f64);
    hyper_map.insert("sgd_momentum".to_string(), hyper.sgd_momentum as f64);
    hyper_map.insert("shampoo_beta2".to_string(), hyper.shampoo_beta2 as f64);
    hyper_map.insert("precond_eps".to_string(), hyper.precond_eps as f64);
    hyper_map.insert("newton_iters".to_string(), hyper.newton_iters as f64);
    hyper_map.insert("adam_beta1".to_string(), hyper.adam_beta1 as f64);
    hyper_map.insert("adam_beta2".to_string(), hyper.adam_beta2 as f64);
    hyper_map.insert("adam_eps".to_string(), hyper.adam_eps as f64);

    Manifest { dir: PathBuf::new(), artifacts, models: metas, hyper: hyper_map }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn backend() -> NativeBackend {
        NativeBackend::new()
    }

    #[test]
    fn manifest_covers_all_models_and_optimizers() {
        let b = backend();
        let m = b.manifest();
        for model in nn::MODEL_NAMES {
            assert!(m.models.contains_key(*model), "{model} meta missing");
            assert!(m.artifacts.contains_key(&format!("grad_{model}")));
            assert!(m.artifacts.contains_key(&format!("eval_{model}")));
            for opt in OPTS {
                assert!(m.artifacts.contains_key(&format!("train_{model}_{opt}")));
                assert!(m.artifacts.contains_key(&format!("apply_{model}_{opt}")));
            }
            assert!(m.artifacts.contains_key(&format!("train_{model}_jorge_skip")));
        }
        // trailing inputs of a train artifact are x, y, lr, wd
        let art = m.artifact("train_mlp_jorge").unwrap();
        let roles: Vec<Role> = art.inputs.iter().map(|i| i.role).collect();
        assert_eq!(&roles[roles.len() - 4..], &[Role::X, Role::Y, Role::Lr, Role::Wd]);
        // every param/state input carries an init rule
        for i in &art.inputs {
            if matches!(i.role, Role::Param | Role::State) {
                assert!(i.init.is_some(), "{} lacks init", i.name);
            }
        }
    }

    #[test]
    fn hyper_values_present() {
        let b = backend();
        assert_eq!(b.manifest().hyper.get("beta1").copied(), Some(0.9));
        assert!(b.manifest().hyper.contains_key("precond_eps"));
    }

    #[test]
    fn unknown_artifact_is_error() {
        let b = backend();
        assert!(b.load("train_mlp_nonexistent").is_err());
        assert!(b.load("train_resnet_sgd").is_err());
    }

    #[test]
    fn load_caches_steps() {
        let b = backend();
        let s1 = b.load("train_mlp_sgd").unwrap();
        let s2 = b.load("train_mlp_sgd").unwrap();
        assert!(Arc::ptr_eq(&s1, &s2));
    }

    fn init_inputs(step: &dyn ExecStep, seed: u64) -> Vec<HostTensor> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for spec in &step.spec().inputs {
            match spec.role {
                Role::Param | Role::State => {
                    out.push(HostTensor::from_init(spec, &mut rng).unwrap())
                }
                Role::Grad => {
                    out.push(HostTensor::from_f32(spec.shape.clone(), vec![0.0; spec.elements()]))
                }
                Role::X => match spec.dtype {
                    Dtype::F32 => {
                        let mut d = vec![0.0f32; spec.elements()];
                        rng.fill_normal(&mut d, 0.0, 1.0);
                        out.push(HostTensor::from_f32(spec.shape.clone(), d));
                    }
                    Dtype::I32 => {
                        let d: Vec<i32> =
                            (0..spec.elements()).map(|_| rng.below(10) as i32).collect();
                        out.push(HostTensor::from_i32(spec.shape.clone(), d));
                    }
                },
                Role::Y => {
                    let d: Vec<i32> =
                        (0..spec.elements()).map(|_| rng.below(8) as i32).collect();
                    out.push(HostTensor::from_i32(spec.shape.clone(), d));
                }
                Role::Lr => out.push(HostTensor::scalar_f32(0.05)),
                Role::Wd => out.push(HostTensor::scalar_f32(1e-4)),
                _ => unreachable!(),
            }
        }
        out
    }

    #[test]
    fn train_step_runs_and_is_deterministic() {
        let b = backend();
        let step = b.load("train_mlp_sgd").unwrap();
        let inputs = init_inputs(step.as_ref(), 42);
        let out1 = step.run(&inputs).unwrap();
        let out2 = step.run(&inputs).unwrap();
        assert_eq!(out1.len(), step.spec().outputs.len());
        assert_eq!(out1, out2);
        // loss output is finite and positive (cross-entropy)
        let loss = out1[out1.len() - 2].scalar();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    }

    #[test]
    fn run_rejects_wrong_arity_and_shape() {
        let b = backend();
        let step = b.load("eval_mlp").unwrap();
        assert!(step.run(&[]).is_err());
        let mut inputs = init_inputs(step.as_ref(), 1);
        inputs[0] = HostTensor::from_f32(vec![2, 2], vec![0.0; 4]);
        assert!(step.run(&inputs).is_err());
    }

    #[test]
    fn adamw_counter_round_trips() {
        // two apply steps through the stateless interface must equal two
        // steps of a live AdamW mirror (bias correction depends on t).
        use crate::optim::{build, Optimizer, OptimizerKind};
        let b = backend();
        let step = b.load("apply_mlp_adamw").unwrap();
        let spec = step.spec().clone();

        let mut rng = Rng::new(9);
        let mut inputs = init_inputs(step.as_ref(), 9);
        // randomise grads (init_inputs has no Grad arm: fill by role here)
        for (t, s) in inputs.iter_mut().zip(&spec.inputs) {
            if s.role == Role::Grad {
                let mut d = vec![0.0f32; s.elements()];
                rng.fill_normal(&mut d, 0.0, 0.1);
                *t = HostTensor::from_f32(s.shape.clone(), d);
            }
        }

        // live mirror
        let shapes: Vec<(usize, usize)> = spec
            .inputs
            .iter()
            .filter(|s| s.role == Role::Param)
            .map(|s| (s.shape[0], s.shape.get(1).copied().unwrap_or(1)))
            .collect();
        let mut mirror = build(OptimizerKind::ADAMW, &shapes, Hyper::default());
        let mut mirror_params: Vec<Matrix> = inputs
            .iter()
            .zip(&spec.inputs)
            .filter(|(_, s)| s.role == Role::Param)
            .map(|(t, _)| to_matrix(t).unwrap())
            .collect();
        let gmats: Vec<Matrix> = inputs
            .iter()
            .zip(&spec.inputs)
            .filter(|(_, s)| s.role == Role::Grad)
            .map(|(t, _)| to_matrix(t).unwrap())
            .collect();

        for _ in 0..2 {
            let out = step.run(&inputs).unwrap();
            mirror.step(
                &mut mirror_params,
                &gmats,
                StepCtx { lr: 0.05, weight_decay: 1e-4, update_precond: true },
            );
            // write updated params + state back into the inputs
            let mut oi = 0usize;
            for (t, s) in inputs.iter_mut().zip(&spec.inputs) {
                if matches!(s.role, Role::Param | Role::State) {
                    *t = out[oi].clone();
                    oi += 1;
                }
            }
        }
        for (pi, mp) in mirror_params.iter().enumerate() {
            let t = inputs
                .iter()
                .zip(&spec.inputs)
                .filter(|(_, s)| s.role == Role::Param)
                .nth(pi)
                .unwrap()
                .0;
            let got = t.as_f32().unwrap();
            let max_err = got
                .iter()
                .zip(&mp.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_err < 1e-6, "param {pi}: {max_err}");
        }
    }
}
