//! Host-side tensors (and, with the `pjrt` feature, conversion to/from
//! XLA literals).

use super::manifest::{Dtype, Init, IoSpec};
use crate::rngx::Rng;
#[cfg(feature = "pjrt")]
use xla::Literal;

/// A dtype-tagged host tensor matching one artifact input/output slot.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn zeros(spec: &IoSpec) -> HostTensor {
        let n = spec.elements();
        match spec.dtype {
            Dtype::F32 => HostTensor::F32 { shape: spec.shape.clone(), data: vec![0.0; n] },
            Dtype::I32 => HostTensor::I32 { shape: spec.shape.clone(), data: vec![0; n] },
        }
    }

    /// Build the initial value of a param/state tensor from its manifest
    /// init rule (mirrors `aot.param_init_meta`/`state_init_meta`).
    pub fn from_init(spec: &IoSpec, rng: &mut Rng) -> Result<HostTensor, String> {
        let init = spec
            .init
            .as_ref()
            .ok_or_else(|| format!("{}: no init rule", spec.name))?;
        let n = spec.elements();
        let data = match init {
            Init::Zeros => vec![0.0f32; n],
            Init::Ones => vec![1.0f32; n],
            Init::Eye { scale } => {
                if spec.shape.len() != 2 || spec.shape[0] != spec.shape[1] {
                    return Err(format!("{}: eye needs square shape", spec.name));
                }
                let dim = spec.shape[0];
                let mut d = vec![0.0f32; n];
                for i in 0..dim {
                    d[i * dim + i] = *scale;
                }
                d
            }
            Init::He { fan_in, scale } => {
                let std = (2.0 / *fan_in as f32).sqrt() * scale;
                let mut d = vec![0.0f32; n];
                rng.fill_normal(&mut d, 0.0, std);
                d
            }
            Init::Normal { std } => {
                let mut d = vec![0.0f32; n];
                rng.fill_normal(&mut d, 0.0, *std);
                d
            }
        };
        Ok(HostTensor::F32 { shape: spec.shape.clone(), data })
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn from_f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn from_i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } => shape,
            HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_f32_mut(&mut self) -> Option<&mut Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Some(data),
            _ => None,
        }
    }

    /// First element as f64 (for scalar loss/metric outputs).
    pub fn scalar(&self) -> f64 {
        match self {
            HostTensor::F32 { data, .. } => data[0] as f64,
            HostTensor::I32 { data, .. } => data[0] as f64,
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> anyhow::Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        match self {
            HostTensor::F32 { data, .. } => {
                if dims.is_empty() {
                    Ok(Literal::scalar(data[0]))
                } else {
                    Ok(Literal::vec1(data).reshape(&dims)?)
                }
            }
            HostTensor::I32 { data, .. } => {
                if dims.is_empty() {
                    Ok(Literal::scalar(data[0]))
                } else {
                    Ok(Literal::vec1(data).reshape(&dims)?)
                }
            }
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &Literal, spec: &IoSpec) -> anyhow::Result<HostTensor> {
        Ok(match spec.dtype {
            Dtype::F32 => HostTensor::F32 { shape: spec.shape.clone(), data: lit.to_vec::<f32>()? },
            Dtype::I32 => HostTensor::I32 { shape: spec.shape.clone(), data: lit.to_vec::<i32>()? },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Role;

    fn spec(name: &str, shape: Vec<usize>, dtype: Dtype, init: Option<Init>) -> IoSpec {
        IoSpec { name: name.into(), shape, dtype, role: Role::Param, init }
    }

    #[test]
    fn init_zeros_ones_eye() {
        let mut rng = Rng::new(0);
        let z = HostTensor::from_init(&spec("z", vec![2, 3], Dtype::F32, Some(Init::Zeros)), &mut rng).unwrap();
        assert_eq!(z.as_f32().unwrap(), &[0.0; 6]);
        let o = HostTensor::from_init(&spec("o", vec![4, 1], Dtype::F32, Some(Init::Ones)), &mut rng).unwrap();
        assert_eq!(o.as_f32().unwrap(), &[1.0; 4]);
        let e = HostTensor::from_init(
            &spec("e", vec![3, 3], Dtype::F32, Some(Init::Eye { scale: 2.5 })),
            &mut rng,
        )
        .unwrap();
        assert_eq!(e.as_f32().unwrap(), &[2.5, 0., 0., 0., 2.5, 0., 0., 0., 2.5]);
    }

    #[test]
    fn init_he_statistics() {
        let mut rng = Rng::new(1);
        let h = HostTensor::from_init(
            &spec("h", vec![100, 200], Dtype::F32, Some(Init::He { fan_in: 100, scale: 1.0 })),
            &mut rng,
        )
        .unwrap();
        let d = h.as_f32().unwrap();
        let mean: f32 = d.iter().sum::<f32>() / d.len() as f32;
        let var: f32 = d.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d.len() as f32;
        assert!(mean.abs() < 0.01);
        assert!((var - 0.02).abs() < 0.005, "var {var}"); // 2/fan_in = 0.02
    }

    #[test]
    fn eye_requires_square() {
        let mut rng = Rng::new(2);
        assert!(HostTensor::from_init(
            &spec("e", vec![2, 3], Dtype::F32, Some(Init::Eye { scale: 1.0 })),
            &mut rng
        )
        .is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::from_f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(
            &lit,
            &spec("t", vec![2, 2], Dtype::F32, None),
        )
        .unwrap();
        assert_eq!(t, back);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_i32_and_scalar() {
        let t = HostTensor::from_i32(vec![3], vec![7, 8, 9]);
        let lit = t.to_literal().unwrap();
        let back =
            HostTensor::from_literal(&lit, &spec("t", vec![3], Dtype::I32, None)).unwrap();
        assert_eq!(t, back);

        let s = HostTensor::scalar_f32(0.25);
        let lit = s.to_literal().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![0.25]);
    }
}
