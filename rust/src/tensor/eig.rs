//! Cyclic Jacobi eigensolver for symmetric matrices (substrate).
//!
//! This is the "expensive, iterative, irregular" computation the paper's
//! whole point is to avoid on accelerators: we need it (a) as the exact
//! oracle for inverse-root validation, and (b) to *cost* the
//! eigendecomposition path in the Table-1 microbenches, where it plays the
//! role of cuSOLVER `syevd` in the paper's Shampoo baseline.

use super::matrix::Matrix;

/// Eigendecomposition A = V diag(w) V^T for symmetric A.
/// Returns (eigenvalues ascending, V with eigenvectors in columns).
pub fn eigh(a: &Matrix, max_sweeps: usize, tol: f64) -> (Vec<f32>, Matrix) {
    assert!(a.is_square(), "eigh needs a square matrix");
    let n = a.rows;
    let mut m: Vec<f64> = a.data.iter().map(|&v| v as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    for _sweep in 0..max_sweeps {
        // off-diagonal magnitude
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    // extract + sort ascending
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[i * n + i], i)).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let w: Vec<f32> = pairs.iter().map(|&(val, _)| val as f32).collect();
    let mut vec_sorted = Matrix::zeros(n, n);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for r in 0..n {
            vec_sorted.data[r * n + new_col] = v[r * n + old_col] as f32;
        }
    }
    (w, vec_sorted)
}

/// Convenience with defaults good to ~1e-6 for n <= 1024.
pub fn eigh_default(a: &Matrix) -> (Vec<f32>, Matrix) {
    eigh(a, 30, 1e-10 * (a.rows as f64))
}

/// Apply `f` to the spectrum: V diag(f(w)) V^T.
pub fn spectral_map(a: &Matrix, f: impl Fn(f32) -> f32) -> Matrix {
    let (w, v) = eigh_default(a);
    let n = a.rows;
    // V * diag(f(w))
    let mut vf = v.clone();
    for c in 0..n {
        let s = f(w[c]);
        for r in 0..n {
            vf.data[r * n + c] *= s;
        }
    }
    // V diag(f(w)) @ V^T via the transpose-free NT kernel
    super::gemm::matmul_nt(&vf, &v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;
    use crate::tensor::gemm::{gram_left, matmul};

    fn random_spd(n: usize, seed: u64, lo: f32, hi: f32) -> Matrix {
        let mut rng = Rng::new(seed);
        let g = Matrix::randn(n, n, 1.0, &mut rng);
        let mut s = gram_left(&g);
        // shift spectrum into [lo, hi]-ish
        let sc = (hi - lo) / (4.0 * n as f32);
        s.scale_inplace(sc);
        for i in 0..n {
            s.data[i * n + i] += lo;
        }
        s
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let mut a = Matrix::zeros(3, 3);
        a.data[0] = 3.0;
        a.data[4] = 1.0;
        a.data[8] = 2.0;
        let (w, _) = eigh_default(&a);
        assert!((w[0] - 1.0).abs() < 1e-6);
        assert!((w[1] - 2.0).abs() < 1e-6);
        assert!((w[2] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn reconstruction() {
        let a = random_spd(16, 0, 0.1, 5.0);
        let rec = spectral_map(&a, |x| x);
        assert!(
            rec.max_abs_diff(&a) < 1e-3,
            "reconstruction err {}",
            rec.max_abs_diff(&a)
        );
    }

    #[test]
    fn eigenvectors_are_orthonormal() {
        let a = random_spd(12, 1, 0.5, 3.0);
        let (_, v) = eigh_default(&a);
        let vtv = matmul(&v.t(), &v);
        assert!(vtv.max_abs_diff(&Matrix::eye(12, 1.0)) < 1e-4);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1, 3
        let a = Matrix::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let (w, _) = eigh_default(&a);
        assert!((w[0] - 1.0).abs() < 1e-6);
        assert!((w[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn spectral_map_inverse() {
        let a = random_spd(10, 2, 1.0, 4.0);
        let inv = spectral_map(&a, |x| 1.0 / x);
        let prod = matmul(&a, &inv);
        assert!(prod.max_abs_diff(&Matrix::eye(10, 1.0)) < 1e-3);
    }

    #[test]
    fn trace_preserved() {
        let a = random_spd(14, 3, 0.1, 2.0);
        let (w, _) = eigh_default(&a);
        let tr: f64 = w.iter().map(|&x| x as f64).sum();
        assert!((tr - a.trace()).abs() < 1e-3 * a.trace().abs().max(1.0));
    }
}
