//! Packed, tile-parallel GEMM — the Rust mirror of the Pallas kernel.
//!
//! The optimizer mirrors in `optim/`, the native models in `nn/` and the
//! Table-1 micro-benchmarks all run on this. Layout mirrors the L1
//! kernel: tile the output, pack panels of A and B through cache (the
//! CPU analogue of HBM->VMEM staging), and accumulate in f32 registers.
//!
//! Compared to the first-cut GEMM this module adds:
//! * a persistent worker pool ([`super::pool`]) instead of a
//!   `std::thread::scope` spawn per call;
//! * parallelism over **2-D output tiles**, so tall-skinny and wide
//!   shapes thread too (the old row-band split left any `m < 128`
//!   single-threaded no matter how many flops were on the table);
//! * transpose-free variants [`matmul_nt`] / [`matmul_tn`] so backprop
//!   never materialises `.t()` copies;
//! * fused epilogues ([`matmul_bias`], [`matmul_bias_relu`]) that fold
//!   the bias broadcast and ReLU into the tile while it is cache-hot;
//! * threaded symmetric rank-k grams ([`gram_left`], [`gram_right`]) on
//!   Jorge's and Shampoo's every-precond-update path.

use super::matrix::Matrix;
use super::pool;
use std::cell::RefCell;

/// Tile edges. 64x64 output tiles with a 64-deep k panel keep the working
/// set (3 * 64*64*4 B = 48 KiB) inside L1/L2 — measured best on this host
/// (see EXPERIMENTS.md §Perf).
const MC: usize = 64;
const NC: usize = 64;
const KC: usize = 64;

/// Threading pays once a GEMM crosses ~1 MFLOP: pool dispatch is a
/// couple of condvar wakes (microseconds), so the old 4-MFLOP /
/// `m >= 2 * MC` cliff is gone — the gate is flops and tile count only.
const PAR_MIN_FLOPS: f64 = 1.0e6;

#[derive(Clone, Copy)]
enum Layout {
    /// `A @ B`
    Nn,
    /// `A @ B^T`, `B` stored row-major `(n, k)`
    Nt,
    /// `A^T @ B`, `A` stored row-major `(k, m)`
    Tn,
}

/// Fused epilogue applied to each output tile while it is cache-hot.
#[derive(Clone, Copy)]
enum Ep<'a> {
    None,
    Bias(&'a [f32]),
    BiasRelu(&'a [f32]),
}

thread_local! {
    /// Per-thread packing scratch: the transposed A block (TN) and the
    /// contiguous B panel. Reused across tiles — no per-call allocation.
    static PACK_A: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    static PACK_B: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Shared output buffer; every task writes disjoint row segments only.
#[derive(Clone, Copy)]
struct CPtr(*mut f32);

unsafe impl Send for CPtr {}
unsafe impl Sync for CPtr {}

impl CPtr {
    /// The `[j0, j0 + nj)` segment of row `i` — exclusive to one task.
    #[allow(clippy::mut_from_ref)]
    unsafe fn row(&self, i: usize, j0: usize, nj: usize, ldc: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.0.add(i * ldc + j0), nj)
    }
}

/// Four-lane unrolled dot product — the NT micro-kernel; auto-vectorises.
#[inline]
fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len(), "dot operand length mismatch");
    let n = x.len().min(y.len());
    let n4 = n - n % 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut i = 0;
    while i < n4 {
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
        i += 4;
    }
    for j in n4..n {
        s0 += x[j] * y[j];
    }
    (s0 + s2) + (s1 + s3)
}

/// Copy the `(kk, nj)` panel of `b` at `(k0, j0)` into contiguous
/// scratch so the inner kernel streams it with unit stride.
#[inline]
fn pack_panel(
    b: &[f32],
    ldb: usize,
    k0: usize,
    j0: usize,
    kk: usize,
    nj: usize,
    out: &mut Vec<f32>,
) {
    out.clear();
    for k in 0..kk {
        out.extend_from_slice(&b[(k0 + k) * ldb + j0..(k0 + k) * ldb + j0 + nj]);
    }
}

/// `C[tile] += A_rows @ B_panel`: `ap` holds length-`kk` rows at stride
/// `lda` from `a_off`; `bp` is the packed `(kk, nj)` panel.
#[inline]
fn kernel_axpy(
    ap: &[f32],
    lda: usize,
    a_off: usize,
    bp: &[f32],
    c: CPtr,
    ldc: usize,
    i0: usize,
    j0: usize,
    mi: usize,
    nj: usize,
    kk: usize,
) {
    for i in 0..mi {
        let arow = &ap[a_off + i * lda..a_off + i * lda + kk];
        let crow = unsafe { c.row(i0 + i, j0, nj, ldc) };
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &bp[k * nj..(k + 1) * nj];
            // inner loop: c[i, j0..] += aik * b[k, j0..]; auto-vectorises
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// Compute one `(mi, nj)` output tile across all of k, then apply the
/// fused epilogue while the tile is still cache-hot.
fn run_tile(
    layout: Layout,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: CPtr,
    ldc: usize,
    kdim: usize,
    i0: usize,
    j0: usize,
    mi: usize,
    nj: usize,
    ep: Ep<'_>,
) {
    match layout {
        Layout::Nn => PACK_B.with(|bp| {
            let bp = &mut *bp.borrow_mut();
            for k0 in (0..kdim).step_by(KC) {
                let kk = KC.min(kdim - k0);
                pack_panel(b, ldb, k0, j0, kk, nj, bp);
                kernel_axpy(a, lda, i0 * lda + k0, bp, c, ldc, i0, j0, mi, nj, kk);
            }
        }),
        Layout::Tn => PACK_A.with(|ap| {
            PACK_B.with(|bp| {
                let ap = &mut *ap.borrow_mut();
                let bp = &mut *bp.borrow_mut();
                for k0 in (0..kdim).step_by(KC) {
                    let kk = KC.min(kdim - k0);
                    pack_panel(b, ldb, k0, j0, kk, nj, bp);
                    // pack the (kk, mi) block of A at (k0, i0), transposed,
                    // so the kernel reads contiguous length-kk rows
                    ap.resize(mi * kk, 0.0);
                    for k in 0..kk {
                        let src = &a[(k0 + k) * lda + i0..(k0 + k) * lda + i0 + mi];
                        for (i, &v) in src.iter().enumerate() {
                            ap[i * kk + k] = v;
                        }
                    }
                    kernel_axpy(ap, kk, 0, bp, c, ldc, i0, j0, mi, nj, kk);
                }
            })
        }),
        Layout::Nt => {
            // both operands are row-contiguous over k: pure dot products
            for i in 0..mi {
                let arow = &a[(i0 + i) * lda..(i0 + i) * lda + kdim];
                let crow = unsafe { c.row(i0 + i, j0, nj, ldc) };
                for (j, cv) in crow.iter_mut().enumerate() {
                    let brow = &b[(j0 + j) * ldb..(j0 + j) * ldb + kdim];
                    *cv = dot(arow, brow);
                }
            }
        }
    }
    match ep {
        Ep::None => {}
        Ep::Bias(bias) => {
            for i in 0..mi {
                let crow = unsafe { c.row(i0 + i, j0, nj, ldc) };
                for (cv, &bv) in crow.iter_mut().zip(&bias[j0..j0 + nj]) {
                    *cv += bv;
                }
            }
        }
        Ep::BiasRelu(bias) => {
            for i in 0..mi {
                let crow = unsafe { c.row(i0 + i, j0, nj, ldc) };
                for (cv, &bv) in crow.iter_mut().zip(&bias[j0..j0 + nj]) {
                    *cv = (*cv + bv).max(0.0);
                }
            }
        }
    }
}

/// Shared driver: shape-check, tile the output 2-D, run tiles on the
/// pool when the flop count justifies it. Per-element accumulation
/// order is identical threaded and not, so results are bitwise equal.
fn gemm(layout: Layout, a: &Matrix, b: &Matrix, ep: Ep<'_>, allow_threads: bool) -> Matrix {
    let (m, kdim, n) = match layout {
        Layout::Nn => {
            assert_eq!(a.cols, b.rows, "gemm: {:?} @ {:?}", a.shape(), b.shape());
            (a.rows, a.cols, b.cols)
        }
        Layout::Nt => {
            assert_eq!(a.cols, b.cols, "gemm_nt: {:?} @ {:?}^T", a.shape(), b.shape());
            (a.rows, a.cols, b.rows)
        }
        Layout::Tn => {
            assert_eq!(a.rows, b.rows, "gemm_tn: {:?}^T @ {:?}", a.shape(), b.shape());
            (a.cols, a.rows, b.cols)
        }
    };
    if let Ep::Bias(bias) | Ep::BiasRelu(bias) = ep {
        assert_eq!(bias.len(), n, "epilogue bias length");
    }
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    let tiles_n = n.div_ceil(NC);
    let n_tiles = m.div_ceil(MC) * tiles_n;
    let cp = CPtr(c.data.as_mut_ptr());
    let (lda, ldb, ldc) = (a.cols, b.cols, n);
    let tile = |t: usize| {
        let i0 = (t / tiles_n) * MC;
        let j0 = (t % tiles_n) * NC;
        let (mi, nj) = (MC.min(m - i0), NC.min(n - j0));
        run_tile(layout, &a.data, lda, &b.data, ldb, cp, ldc, kdim, i0, j0, mi, nj, ep);
    };
    let flops = 2.0 * m as f64 * n as f64 * kdim as f64;
    let threaded = allow_threads && flops >= PAR_MIN_FLOPS && pool::pool_size() > 1;
    if threaded && n_tiles > 1 {
        pool::parallel_for(n_tiles, tile);
    } else if threaded && kdim >= 2 * KC {
        // single output tile but a flop count worth threading: the 2-D
        // tile fan-out has nothing to split, so split k instead
        ksplit_single_tile(layout, &a.data, lda, &b.data, ldb, &mut c, kdim, ep);
    } else {
        for t in 0..n_tiles {
            tile(t);
        }
    }
    c
}

/// Single-tile k-split for k-heavy shapes (`m, n <= 64`, large k): the
/// whole `(m, n)` output is one tile, so the 2-D tile fan-out cannot
/// parallelise. Instead k is partitioned into KC-aligned ranges, each
/// task accumulates a private `(m, n)` partial, and the partials are
/// reduced serially in fixed index order — deterministic for a given
/// pool width regardless of thread timing, but the f32 k-sum is
/// reassociated relative to the serial loop, so this path is covered by
/// a tolerance property test (`ksplit_*` below) rather than a bitwise
/// one. The epilogue runs once, after the reduction.
#[allow(clippy::too_many_arguments)]
fn ksplit_single_tile(
    layout: Layout,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut Matrix,
    kdim: usize,
    ep: Ep<'_>,
) {
    let (m, n) = (c.rows, c.cols);
    let kblocks = kdim.div_ceil(KC);
    let parts = pool::pool_size().min(kblocks);
    let per_part = kblocks.div_ceil(parts);
    let mut partials = vec![0.0f32; parts * m * n];
    let pp = CPtr(partials.as_mut_ptr());
    pool::parallel_for(parts, |p| {
        let k_lo = (p * per_part * KC).min(kdim);
        let k_hi = ((p + 1) * per_part * KC).min(kdim);
        if k_lo >= k_hi {
            return;
        }
        // this task's private (m, n) accumulator inside `partials`
        let cpart = CPtr(unsafe { pp.0.add(p * m * n) });
        match layout {
            Layout::Nn => PACK_B.with(|bp| {
                let bp = &mut *bp.borrow_mut();
                for k0 in (k_lo..k_hi).step_by(KC) {
                    let kk = KC.min(k_hi - k0);
                    pack_panel(b, ldb, k0, 0, kk, n, bp);
                    kernel_axpy(a, lda, k0, bp, cpart, n, 0, 0, m, n, kk);
                }
            }),
            Layout::Tn => PACK_A.with(|ap| {
                PACK_B.with(|bp| {
                    let ap = &mut *ap.borrow_mut();
                    let bp = &mut *bp.borrow_mut();
                    for k0 in (k_lo..k_hi).step_by(KC) {
                        let kk = KC.min(k_hi - k0);
                        pack_panel(b, ldb, k0, 0, kk, n, bp);
                        ap.resize(m * kk, 0.0);
                        for k in 0..kk {
                            let src = &a[(k0 + k) * lda..(k0 + k) * lda + m];
                            for (i, &v) in src.iter().enumerate() {
                                ap[i * kk + k] = v;
                            }
                        }
                        kernel_axpy(ap, kk, 0, bp, cpart, n, 0, 0, m, n, kk);
                    }
                })
            }),
            Layout::Nt => {
                for i in 0..m {
                    let arow = &a[i * lda + k_lo..i * lda + k_hi];
                    let crow = unsafe { cpart.row(i, 0, n, n) };
                    for (j, cv) in crow.iter_mut().enumerate() {
                        let brow = &b[j * ldb + k_lo..j * ldb + k_hi];
                        *cv = dot(arow, brow);
                    }
                }
            }
        }
    });
    // fixed-order reduction: partial p always folds in before p + 1, no
    // matter which worker produced it
    for p in 0..parts {
        let part = &partials[p * m * n..(p + 1) * m * n];
        for (cv, &pv) in c.data.iter_mut().zip(part) {
            *cv += pv;
        }
    }
    match ep {
        Ep::None => {}
        Ep::Bias(bias) => {
            for i in 0..m {
                for (cv, &bv) in c.data[i * n..(i + 1) * n].iter_mut().zip(bias) {
                    *cv += bv;
                }
            }
        }
        Ep::BiasRelu(bias) => {
            for i in 0..m {
                for (cv, &bv) in c.data[i * n..(i + 1) * n].iter_mut().zip(bias) {
                    *cv = (*cv + bv).max(0.0);
                }
            }
        }
    }
}

/// `A @ B`, threaded over 2-D output tiles when the flop count pays.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    gemm(Layout::Nn, a, b, Ep::None, true)
}

/// `A @ B` pinned to the calling thread (reference / microbench baseline).
pub fn matmul_st(a: &Matrix, b: &Matrix) -> Matrix {
    gemm(Layout::Nn, a, b, Ep::None, false)
}

/// `A @ B^T` without materialising the transpose (`B` is `(n, k)`).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    gemm(Layout::Nt, a, b, Ep::None, true)
}

/// `A^T @ B` without materialising the transpose (`A` is `(k, m)`).
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    gemm(Layout::Tn, a, b, Ep::None, true)
}

/// `A @ B + bias`, the bias broadcast over rows and fused into the tile
/// epilogue (`bias` is the usual `(cols, 1)` parameter matrix).
pub fn matmul_bias(a: &Matrix, b: &Matrix, bias: &Matrix) -> Matrix {
    gemm(Layout::Nn, a, b, Ep::Bias(&bias.data), true)
}

/// `relu(A @ B + bias)` fused into the tile epilogue.
pub fn matmul_bias_relu(a: &Matrix, b: &Matrix, bias: &Matrix) -> Matrix {
    gemm(Layout::Nn, a, b, Ep::BiasRelu(&bias.data), true)
}

/// `A @ A` convenience.
pub fn square(a: &Matrix) -> Matrix {
    matmul(a, a)
}

/// Upper-triangle tile origins for an `n x n` symmetric output.
fn sym_blocks(n: usize) -> Vec<(usize, usize)> {
    let tiles = n.div_ceil(NC);
    let mut blocks = Vec::with_capacity(tiles * (tiles + 1) / 2);
    for ti in 0..tiles {
        for tj in ti..tiles {
            blocks.push((ti * NC, tj * NC));
        }
    }
    blocks
}

fn run_sym(blocks: &[(usize, usize)], flops: f64, f: impl Fn(usize) + Sync) {
    if blocks.len() > 1 && flops >= PAR_MIN_FLOPS && pool::pool_size() > 1 {
        pool::parallel_for(blocks.len(), f);
    } else {
        for i in 0..blocks.len() {
            f(i);
        }
    }
}

/// Mirror the computed upper part of block `(i0, j0)` into `(j0, i0)`.
/// The transposed region belongs to the same task, so writes stay
/// disjoint across the pool.
fn mirror_block(cp: CPtr, n: usize, i0: usize, j0: usize, mi: usize, nj: usize) {
    for i in 0..mi {
        let jlo = if i0 == j0 { i + 1 } else { 0 };
        for j in jlo..nj {
            unsafe { *cp.0.add((j0 + j) * n + i0 + i) = *cp.0.add((i0 + i) * n + j0 + j) };
        }
    }
}

/// `G @ G^T` (left gram) — threaded symmetric rank-k, no transpose
/// copy: upper-triangle tiles of row-dot-products, mirrored by the
/// owning task. Sits on every Jorge/Shampoo preconditioner update.
pub fn gram_left(g: &Matrix) -> Matrix {
    let (m, kdim) = g.shape();
    let mut c = Matrix::zeros(m, m);
    let blocks = sym_blocks(m);
    let cp = CPtr(c.data.as_mut_ptr());
    let task = |bi: usize| {
        let (i0, j0) = blocks[bi];
        let (mi, nj) = (NC.min(m - i0), NC.min(m - j0));
        for i in 0..mi {
            let gi = &g.data[(i0 + i) * kdim..(i0 + i + 1) * kdim];
            let jlo = if i0 == j0 { i } else { 0 };
            let crow = unsafe { cp.row(i0 + i, j0, nj, m) };
            for j in jlo..nj {
                let gj = &g.data[(j0 + j) * kdim..(j0 + j + 1) * kdim];
                crow[j] = dot(gi, gj);
            }
        }
        mirror_block(cp, m, i0, j0, mi, nj);
    };
    run_sym(&blocks, m as f64 * m as f64 * kdim as f64, task);
    c
}

/// `G^T @ G` (right gram) computed directly from `G`'s row-major layout
/// — rank-1 row accumulation over upper-triangle tiles; no `g.t()`
/// materialisation, threaded like [`gram_left`].
pub fn gram_right(g: &Matrix) -> Matrix {
    let (kdim, n) = g.shape();
    let mut c = Matrix::zeros(n, n);
    let blocks = sym_blocks(n);
    let cp = CPtr(c.data.as_mut_ptr());
    let task = |bi: usize| {
        let (i0, j0) = blocks[bi];
        let (mi, nj) = (NC.min(n - i0), NC.min(n - j0));
        for r in 0..kdim {
            let grow = &g.data[r * n..(r + 1) * n];
            for i in 0..mi {
                let gi = grow[i0 + i];
                if gi == 0.0 {
                    continue;
                }
                let jlo = if i0 == j0 { i } else { 0 };
                let crow = unsafe { cp.row(i0 + i, j0 + jlo, nj - jlo, n) };
                for (cv, &bv) in crow.iter_mut().zip(&grow[j0 + jlo..j0 + nj]) {
                    *cv += gi * bv;
                }
            }
        }
        mirror_block(cp, n, i0, j0, mi, nj);
    };
    run_sym(&blocks, n as f64 * n as f64 * kdim as f64, task);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for kk in 0..k {
                    acc += a.at(i, kk) as f64 * b.at(kk, j) as f64;
                }
                c.data[i * n + j] = acc as f32;
            }
        }
        c
    }

    const ODD_SHAPES: &[(usize, usize, usize)] =
        &[(1, 1, 1), (3, 5, 2), (17, 9, 23), (64, 64, 64), (65, 63, 67), (5, 130, 3)];

    #[test]
    fn matches_naive_small() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in ODD_SHAPES {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let got = matmul_st(&a, &b);
            let want = naive(&a, &b);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "({m},{k},{n}): {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn nt_matches_explicit_transpose() {
        let mut rng = Rng::new(10);
        for &(m, k, n) in ODD_SHAPES {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let bt = Matrix::randn(n, k, 1.0, &mut rng);
            let got = matmul_nt(&a, &bt);
            let want = naive(&a, &bt.t());
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "nt ({m},{k},{n}): {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn tn_matches_explicit_transpose() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in ODD_SHAPES {
            let at = Matrix::randn(k, m, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let got = matmul_tn(&at, &b);
            let want = naive(&at.t(), &b);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "tn ({m},{k},{n}): {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn fused_epilogues_match_unfused() {
        let mut rng = Rng::new(12);
        for &(m, k, n) in ODD_SHAPES {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let bias = Matrix::randn(n, 1, 1.0, &mut rng);
            let plain = matmul_st(&a, &b);
            let mut want_bias = plain.clone();
            for i in 0..m {
                for j in 0..n {
                    want_bias.data[i * n + j] += bias.data[j];
                }
            }
            let got_bias = matmul_bias(&a, &b, &bias);
            assert!(got_bias.max_abs_diff(&want_bias) < 1e-5, "bias ({m},{k},{n})");
            let mut want_relu = want_bias.clone();
            for v in want_relu.data.iter_mut() {
                *v = v.max(0.0);
            }
            let got_relu = matmul_bias_relu(&a, &b, &bias);
            assert!(got_relu.max_abs_diff(&want_relu) < 1e-5, "relu ({m},{k},{n})");
        }
    }

    #[test]
    fn threaded_matches_single_threaded() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(300, 200, 1.0, &mut rng);
        let b = Matrix::randn(200, 250, 1.0, &mut rng);
        let st = matmul_st(&a, &b);
        let mt = matmul(&a, &b);
        assert!(st.max_abs_diff(&mt) < 1e-4);
    }

    #[test]
    fn tall_skinny_threads_and_matches() {
        // m = 8 < MC: the old row-band split ran this single-threaded;
        // the 2-D tile grid plus flop gate threads it over N.
        let mut rng = Rng::new(13);
        let a = Matrix::randn(8, 300, 1.0, &mut rng);
        let b = Matrix::randn(300, 600, 1.0, &mut rng);
        let st = matmul_st(&a, &b);
        let mt = matmul(&a, &b);
        assert_eq!(st.max_abs_diff(&mt), 0.0, "tile order must be thread-invariant");
    }

    #[test]
    fn ksplit_single_tile_matches_naive() {
        // m, n <= 64 with heavy k: one output tile, threaded via the
        // k-split path (per-thread partials + fixed-order reduction).
        // The k-sum is reassociated, so compare against the f64 naive
        // reference with a sqrt(k)-scaled tolerance, not bitwise.
        let mut rng = Rng::new(21);
        for &(m, k, n) in &[(48, 4096, 48), (64, 2000, 64), (1, 8192, 64), (33, 4097, 17)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            let tol = 1e-3 * (k as f32).sqrt();
            assert!(got.max_abs_diff(&want) < tol, "({m},{k},{n}): {}", got.max_abs_diff(&want));
        }
    }

    #[test]
    fn ksplit_variants_and_epilogue_match_naive() {
        let mut rng = Rng::new(22);
        let (m, k, n) = (32, 3000, 40);
        let tol = 1e-3 * (k as f32).sqrt();
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let want = naive(&a, &b);
        assert!(matmul_nt(&a, &b.t()).max_abs_diff(&want) < tol, "nt k-split");
        assert!(matmul_tn(&a.t(), &b).max_abs_diff(&want) < tol, "tn k-split");
        // the fused epilogue must run once, after the partial reduction
        let bias = Matrix::randn(n, 1, 1.0, &mut rng);
        let mut want_relu = want.clone();
        for i in 0..m {
            for j in 0..n {
                want_relu.data[i * n + j] = (want_relu.data[i * n + j] + bias.data[j]).max(0.0);
            }
        }
        assert!(matmul_bias_relu(&a, &b, &bias).max_abs_diff(&want_relu) < tol, "relu k-split");
    }

    #[test]
    fn ksplit_is_run_to_run_deterministic() {
        // partition and reduction order are fixed by pool width, not by
        // thread timing: repeated calls are bitwise identical
        let mut rng = Rng::new(23);
        let a = Matrix::randn(48, 4096, 1.0, &mut rng);
        let b = Matrix::randn(4096, 48, 1.0, &mut rng);
        let first = matmul(&a, &b);
        for _ in 0..3 {
            assert_eq!(first.max_abs_diff(&matmul(&a, &b)), 0.0);
        }
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(40, 40, 1.0, &mut rng);
        let eye = Matrix::eye(40, 1.0);
        assert!(matmul(&a, &eye).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&eye, &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Rng::new(3);
        let g = Matrix::randn(31, 17, 1.0, &mut rng);
        let want_l = matmul_st(&g, &g.t());
        let want_r = matmul_st(&g.t(), &g);
        assert!(gram_left(&g).max_abs_diff(&want_l) < 1e-4);
        assert!(gram_right(&g).max_abs_diff(&want_r) < 1e-4);
    }

    #[test]
    fn threaded_gram_matches_and_is_symmetric() {
        // big enough to cross the parallel gate (m^2 k > 1e6, > 1 block)
        let mut rng = Rng::new(14);
        let g = Matrix::randn(150, 80, 1.0, &mut rng);
        let l = gram_left(&g);
        let r = gram_right(&g.t());
        // G @ G^T computed two ways (dot-tiles vs rank-1 accumulation)
        assert!(l.max_abs_diff(&matmul_st(&g, &g.t())) < 1e-3);
        assert!(r.max_abs_diff(&l) < 1e-3);
        for i in 0..l.rows {
            assert!(l.at(i, i) >= 0.0, "diag {i}");
            for j in 0..l.cols {
                assert_eq!(l.at(i, j), l.at(j, i), "asym at ({i},{j})");
            }
        }
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Rng::new(4);
        let g = Matrix::randn(20, 9, 1.0, &mut rng);
        let s = gram_left(&g);
        for i in 0..20 {
            assert!(s.at(i, i) >= 0.0);
            for j in 0..20 {
                assert_eq!(s.at(i, j), s.at(j, i));
            }
        }
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        matmul_st(&a, &b);
    }

    #[test]
    #[should_panic]
    fn nt_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        matmul_nt(&a, &b);
    }
}
