//! Blocked, multi-threaded GEMM — the Rust mirror of the Pallas kernel.
//!
//! The optimizer mirrors in `optim/` and the Table-1 micro-benchmarks run
//! on this. Layout mirrors the L1 kernel: tile the output, stream panels
//! of A and B through cache (the CPU analogue of HBM->VMEM staging), and
//! accumulate in f32 registers. Threading splits the output row-blocks
//! across a scoped thread pool.

use super::matrix::Matrix;

/// Tile edges. 64x64 output tiles with a 64-deep k panel keep the working
/// set (3 * 64*64*4 B = 48 KiB) inside L1/L2 — measured best on this host
/// (see EXPERIMENTS.md §Perf).
const MC: usize = 64;
const NC: usize = 64;
const KC: usize = 64;

/// Single-threaded blocked kernel: `c[i0.., j0..] += a_panel @ b_panel`.
#[inline]
fn gemm_block(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    i0: usize,
    j0: usize,
    k0: usize,
    mi: usize,
    nj: usize,
    kk: usize,
) {
    for i in i0..i0 + mi {
        let arow = &a[i * lda + k0..i * lda + k0 + kk];
        for k in 0..kk {
            let aik = arow[k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[(k0 + k) * ldb + j0..(k0 + k) * ldb + j0 + nj];
            let crow = &mut c[i * ldc + j0..i * ldc + j0 + nj];
            // inner loop: c[i, j0..] += aik * b[k, j0..]; auto-vectorises
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += aik * bv;
            }
        }
    }
}

/// `A @ B` single-threaded.
pub fn matmul_st(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "gemm: {:?} @ {:?}", a.shape(), b.shape());
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for i0 in (0..m).step_by(MC) {
        let mi = MC.min(m - i0);
        for k0 in (0..k).step_by(KC) {
            let kk = KC.min(k - k0);
            for j0 in (0..n).step_by(NC) {
                let nj = NC.min(n - j0);
                gemm_block(&a.data, k, &b.data, n, &mut c.data, n, i0, j0, k0, mi, nj, kk);
            }
        }
    }
    c
}

/// `A @ B`, multi-threaded over output row blocks when the problem is big
/// enough to amortise thread spawn (std::thread::scope — no pool dep).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let threads = available_threads();
    if threads <= 1 || flops < 4e6 || m < 2 * MC {
        return matmul_st(a, b);
    }
    assert_eq!(a.cols, b.rows, "gemm: {:?} @ {:?}", a.shape(), b.shape());
    let mut c = Matrix::zeros(m, n);
    let row_blocks: Vec<usize> = (0..m).step_by(MC).collect();
    let nchunks = threads.min(row_blocks.len());
    let chunk = row_blocks.len().div_ceil(nchunks);

    // Split C into disjoint row bands, one per worker.
    let band_rows = chunk * MC;
    let bands: Vec<&mut [f32]> = c.data.chunks_mut(band_rows * n).collect();
    std::thread::scope(|s| {
        for (bi, band) in bands.into_iter().enumerate() {
            let a = &a.data;
            let b = &b.data;
            s.spawn(move || {
                let i_start = bi * band_rows;
                let mi_total = band.len() / n;
                for i0 in (0..mi_total).step_by(MC) {
                    let mi = MC.min(mi_total - i0);
                    for k0 in (0..k).step_by(KC) {
                        let kk = KC.min(k - k0);
                        for j0 in (0..n).step_by(NC) {
                            let nj = NC.min(n - j0);
                            // band is row-shifted view of C
                            gemm_block(
                                &a[(i_start) * k..],
                                k,
                                b,
                                n,
                                band,
                                n,
                                i0,
                                j0,
                                k0,
                                mi,
                                nj,
                                kk,
                            );
                        }
                    }
                }
            });
        }
    });
    c
}

pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// `A @ A` convenience.
pub fn square(a: &Matrix) -> Matrix {
    matmul(a, a)
}

/// `G @ G^T` (left gram) without materialising the transpose.
pub fn gram_left(g: &Matrix) -> Matrix {
    let (m, k) = g.shape();
    let mut c = Matrix::zeros(m, m);
    for i in 0..m {
        let gi = &g.data[i * k..(i + 1) * k];
        for j in i..m {
            let gj = &g.data[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (x, y) in gi.iter().zip(gj.iter()) {
                acc += x * y;
            }
            c.data[i * m + j] = acc;
            c.data[j * m + i] = acc;
        }
    }
    c
}

/// `G^T @ G` (right gram).
pub fn gram_right(g: &Matrix) -> Matrix {
    gram_left(&g.t())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for kk in 0..k {
                    acc += a.at(i, kk) as f64 * b.at(kk, j) as f64;
                }
                c.data[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 9, 23), (64, 64, 64), (65, 63, 67)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let got = matmul_st(&a, &b);
            let want = naive(&a, &b);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "({m},{k},{n}): {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn threaded_matches_single_threaded() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(300, 200, 1.0, &mut rng);
        let b = Matrix::randn(200, 250, 1.0, &mut rng);
        let st = matmul_st(&a, &b);
        let mt = matmul(&a, &b);
        assert!(st.max_abs_diff(&mt) < 1e-4);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(40, 40, 1.0, &mut rng);
        let eye = Matrix::eye(40, 1.0);
        assert!(matmul(&a, &eye).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&eye, &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Rng::new(3);
        let g = Matrix::randn(31, 17, 1.0, &mut rng);
        let want_l = matmul_st(&g, &g.t());
        let want_r = matmul_st(&g.t(), &g);
        assert!(gram_left(&g).max_abs_diff(&want_l) < 1e-4);
        assert!(gram_right(&g).max_abs_diff(&want_r) < 1e-4);
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Rng::new(4);
        let g = Matrix::randn(20, 9, 1.0, &mut rng);
        let s = gram_left(&g);
        for i in 0..20 {
            assert!(s.at(i, i) >= 0.0);
            for j in 0..20 {
                assert_eq!(s.at(i, j), s.at(j, i));
            }
        }
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        matmul_st(&a, &b);
    }
}
