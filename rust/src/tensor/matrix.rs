//! Dense row-major f32 matrix — the linear-algebra substrate.
//!
//! Small by design: exactly the operations the optimizer mirrors and the
//! benchmark harness need (GEMM in `gemm.rs`, eigensolver in `eig.rs`,
//! inverse roots in `roots.rs`).

use crate::rngx::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length != rows*cols");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    /// `scale * I`.
    pub fn eye(n: usize, scale: f32) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = scale;
        }
        m
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, 0.0, std);
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    pub fn t(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    pub fn scale(&self, s: f32) -> Matrix {
        let mut out = self.clone();
        for v in out.data.iter_mut() {
            *v *= s;
        }
        out
    }

    pub fn scale_inplace(&mut self, s: f32) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        out
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
        out
    }

    /// `self += s * other` (axpy).
    pub fn add_scaled_inplace(&mut self, s: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Hadamard product.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a *= b;
        }
        out
    }

    pub fn frobenius_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    pub fn frobenius(&self) -> f64 {
        self.frobenius_sq().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// max |self - other|
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }

    /// Symmetrise: (A + A^T)/2 — used to clean up drift in preconditioners.
    pub fn symmetrize(&self) -> Matrix {
        assert!(self.is_square());
        let n = self.rows;
        let mut out = self.clone();
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.5 * (self.at(i, j) + self.at(j, i));
                out.data[i * n + j] = v;
                out.data[j * n + i] = v;
            }
        }
        out
    }

    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self.at(i, i) as f64).sum()
    }

    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_and_at() {
        let m = Matrix::eye(3, 2.0);
        assert_eq!(m.at(0, 0), 2.0);
        assert_eq!(m.at(0, 1), 0.0);
        assert_eq!(m.trace(), 6.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(0);
        let m = Matrix::randn(37, 23, 1.0, &mut rng);
        let tt = m.t().t();
        assert_eq!(m, tt);
    }

    #[test]
    fn transpose_values() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.t();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.at(2, 1), 6.0);
        assert_eq!(t.at(0, 1), 4.0);
    }

    #[test]
    fn add_sub_scale() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(2, 2, vec![4., 3., 2., 1.]);
        assert_eq!(a.add(&b).data, vec![5., 5., 5., 5.]);
        assert_eq!(a.sub(&b).data, vec![-3., -1., 1., 3.]);
        assert_eq!(a.scale(2.0).data, vec![2., 4., 6., 8.]);
    }

    #[test]
    fn axpy() {
        let mut a = Matrix::from_vec(1, 3, vec![1., 1., 1.]);
        let b = Matrix::from_vec(1, 3, vec![1., 2., 3.]);
        a.add_scaled_inplace(0.5, &b);
        assert_eq!(a.data, vec![1.5, 2.0, 2.5]);
    }

    #[test]
    fn frobenius() {
        let a = Matrix::from_vec(1, 2, vec![3., 4.]);
        assert!((a.frobenius() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn symmetrize_is_symmetric() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(8, 8, 1.0, &mut rng);
        let s = m.symmetrize();
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(s.at(i, j), s.at(j, i));
            }
        }
    }

    #[test]
    #[should_panic]
    fn from_vec_length_checked() {
        Matrix::from_vec(2, 2, vec![1.0; 3]);
    }
}
