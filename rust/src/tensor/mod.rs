//! Dense linear algebra substrate: matrices, GEMM, eigensolver, inverse
//! roots. This is the Rust mirror of the Pallas L1 kernels, used by the
//! native optimizer mirrors, the property tests and the Table-1
//! microbenchmarks.

pub mod eig;
pub mod gemm;
pub mod matrix;
pub mod pool;
pub mod roots;

pub use eig::{eigh, eigh_default, spectral_map};
pub use gemm::{gram_left, gram_right, matmul, matmul_bias, matmul_bias_relu, matmul_nt};
pub use gemm::{matmul_st, matmul_tn};
pub use matrix::Matrix;
pub use pool::{
    dispatch_counters, parallel_chunks, parallel_for, parallel_zip_mut, pool_size, warm_pool,
    PoolCounters,
};
pub use roots::{
    dynamic_beta2, inv_fourth_root_eigh, inv_fourth_root_newton, inv_pth_root_eigh,
    jorge_update,
};
