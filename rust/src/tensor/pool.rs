//! Persistent scoped worker pool — the threading substrate for the
//! native compute path.
//!
//! The old GEMM spawned a fresh `std::thread::scope` per call, which put
//! a thread-creation storm on every hot loop (one spawn per band per
//! matmul per layer per step). This pool spawns `available_parallelism()
//! - 1` workers once, parks them on a condvar, and hands out tasks by
//! index: [`parallel_for`] publishes a job, the calling thread
//! participates in draining it, and workers go back to sleep when the
//! task counter runs dry. Dispatch cost is a couple of condvar wakes
//! (microseconds) instead of thread spawns (hundreds of microseconds),
//! which is what makes threading pay off for the paper-scale (≤ 1024)
//! matrices this crate runs.
//!
//! Nesting: a task that itself calls [`parallel_for`] (e.g. a per-layer
//! optimizer update whose GEMMs are threaded) runs the nested loop
//! inline on its own thread — no deadlock, no oversubscription. A job
//! submitted while another user thread's job is in flight runs inline
//! rather than queueing behind it.
//!
//! `JORGE_THREADS=n` caps the pool (1 disables threading entirely).

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock, PoisonError, TryLockError};

/// Lifetime-erased handle to the closure of the job in flight. Only
/// dereferenced between job publication and completion, during which
/// [`parallel_for`] keeps the closure alive on its stack.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
}

unsafe impl Send for Job {}

unsafe fn call_closure<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    (*(data as *const F))(i)
}

struct State {
    job: Option<Job>,
    n_tasks: usize,
    /// Next unclaimed task index of the current job.
    next: usize,
    /// Tasks currently executing (claimed but not finished).
    running: usize,
    /// Bumped per job so sleeping workers can tell old jobs from new.
    epoch: u64,
}

struct Shared {
    state: Mutex<State>,
    /// Signalled when a new job is published.
    work: Condvar,
    /// Signalled when the last running task of a job finishes.
    done: Condvar,
    /// First panic payload from a task of the current job; re-thrown by
    /// the submitter so assert messages survive the pool boundary.
    panic_payload: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Shared {
    fn lock(&self) -> MutexGuard<'_, State> {
        // A panicking task poisons the mutex; the state itself stays
        // consistent (bookkeeping runs in `TaskGuard::drop`), so keep going.
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

pub struct Pool {
    shared: &'static Shared,
    /// Number of background workers (threads beyond the caller).
    workers: usize,
    /// Serialises jobs from different user threads.
    submit: Mutex<()>,
}

static POOL: OnceLock<Pool> = OnceLock::new();

thread_local! {
    /// True while this thread is executing a pool task; nested
    /// `parallel_for` calls then run inline.
    static IN_TASK: Cell<bool> = const { Cell::new(false) };
}

/// Parse a `JORGE_THREADS`-style override. A value of 0 would size a
/// pool that can never run a task, so anything parsing below 1 clamps
/// to 1 (single-threaded); non-numeric values fall through to the
/// hardware default.
fn parse_threads(v: &str) -> Option<usize> {
    v.trim().parse::<usize>().ok().map(|n| n.max(1))
}

fn env_threads() -> Option<usize> {
    std::env::var("JORGE_THREADS").ok().and_then(|v| parse_threads(&v))
}

fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Pool {
    fn new() -> Pool {
        let threads = env_threads().unwrap_or_else(hardware_threads).max(1);
        let workers = threads - 1;
        let shared: &'static Shared = Box::leak(Box::new(Shared {
            state: Mutex::new(State { job: None, n_tasks: 0, next: 0, running: 0, epoch: 0 }),
            work: Condvar::new(),
            done: Condvar::new(),
            panic_payload: Mutex::new(None),
        }));
        for wi in 0..workers {
            std::thread::Builder::new()
                .name(format!("jorge-pool-{wi}"))
                .spawn(move || worker_loop(shared))
                .expect("spawn pool worker");
        }
        Pool { shared, workers, submit: Mutex::new(()) }
    }
}

fn pool() -> &'static Pool {
    POOL.get_or_init(Pool::new)
}

// Dispatch telemetry: always-on relaxed atomics (sub-nanosecond per
// job), read by the trainer's metrics layer as before/after deltas.
static POOL_JOBS: AtomicU64 = AtomicU64::new(0);
static INLINE_JOBS: AtomicU64 = AtomicU64::new(0);
static POOL_TASKS: AtomicU64 = AtomicU64::new(0);

/// Cumulative dispatch counts since process start. `pool_jobs` fanned
/// out across workers; `inline_jobs` ran on the calling thread (no
/// workers, trivial task count, nested call, or pool busy).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolCounters {
    pub pool_jobs: u64,
    pub inline_jobs: u64,
    pub tasks: u64,
}

impl PoolCounters {
    /// Counter growth between `earlier` and `self`.
    pub fn since(&self, earlier: &PoolCounters) -> PoolCounters {
        PoolCounters {
            pool_jobs: self.pool_jobs - earlier.pool_jobs,
            inline_jobs: self.inline_jobs - earlier.inline_jobs,
            tasks: self.tasks - earlier.tasks,
        }
    }

    /// Fraction of jobs that actually fanned out across the pool.
    pub fn fanout_ratio(&self) -> f64 {
        let total = self.pool_jobs + self.inline_jobs;
        if total == 0 {
            0.0
        } else {
            self.pool_jobs as f64 / total as f64
        }
    }
}

/// Snapshot the dispatch counters.
pub fn dispatch_counters() -> PoolCounters {
    PoolCounters {
        pool_jobs: POOL_JOBS.load(Ordering::Relaxed),
        inline_jobs: INLINE_JOBS.load(Ordering::Relaxed),
        tasks: POOL_TASKS.load(Ordering::Relaxed),
    }
}

/// Total threads the pool can bring to bear (workers + the caller).
pub fn pool_size() -> usize {
    pool().workers + 1
}

/// Force pool construction up front so the first hot-path call doesn't
/// pay thread-spawn latency.
pub fn warm_pool() {
    let _ = pool();
}

/// Drain tasks of the current job until none are left to claim.
/// Returns with the state lock released.
fn drain(shared: &Shared, my_epoch: u64) {
    loop {
        let mut st = shared.lock();
        if st.epoch != my_epoch || st.next >= st.n_tasks {
            return;
        }
        let i = st.next;
        st.next += 1;
        st.running += 1;
        let job = st.job.expect("claimed task without a job");
        drop(st);

        let guard = TaskGuard { shared };
        IN_TASK.with(|f| f.set(true));
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (job.call)(job.data, i) }));
        IN_TASK.with(|f| f.set(false));
        if let Err(payload) = result {
            let mut slot = shared.panic_payload.lock().unwrap_or_else(PoisonError::into_inner);
            slot.get_or_insert(payload);
        }
        drop(guard);
    }
}

/// Decrements `running` (and wakes the submitter when the job drains)
/// even if the task body panics.
struct TaskGuard<'a> {
    shared: &'a Shared,
}

impl Drop for TaskGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        st.running -= 1;
        if st.running == 0 && st.next >= st.n_tasks {
            self.shared.done.notify_all();
        }
    }
}

fn worker_loop(shared: &'static Shared) {
    let mut seen_epoch = 0u64;
    loop {
        let my_epoch;
        {
            let mut st = shared.lock();
            while st.job.is_none() || st.epoch == seen_epoch {
                st = shared.work.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            my_epoch = st.epoch;
        }
        seen_epoch = my_epoch;
        drain(shared, my_epoch);
    }
}

/// Run `f(0), f(1), …, f(n_tasks - 1)` across the pool, returning when
/// all calls have finished. The calling thread participates. Tasks must
/// only touch disjoint data (the usual output-tile contract).
///
/// Runs inline when the pool has no workers, the task count is trivial,
/// the caller is itself a pool task (nested parallelism), or another
/// thread's job currently occupies the pool.
pub fn parallel_for<F: Fn(usize) + Sync>(n_tasks: usize, f: F) {
    if n_tasks == 0 {
        return;
    }
    let pool = pool();
    if pool.workers == 0 || n_tasks == 1 || IN_TASK.with(|c| c.get()) {
        INLINE_JOBS.fetch_add(1, Ordering::Relaxed);
        POOL_TASKS.fetch_add(n_tasks as u64, Ordering::Relaxed);
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }

    // Another thread already has a job in flight: running this one
    // inline beats queueing behind the full drain of theirs. A poisoned
    // lock (a prior job panicked mid-flight) is safe to reclaim — job
    // state is reset below.
    let _submit = match pool.submit.try_lock() {
        Ok(guard) => guard,
        Err(TryLockError::Poisoned(poisoned)) => poisoned.into_inner(),
        Err(TryLockError::WouldBlock) => {
            INLINE_JOBS.fetch_add(1, Ordering::Relaxed);
            POOL_TASKS.fetch_add(n_tasks as u64, Ordering::Relaxed);
            for i in 0..n_tasks {
                f(i);
            }
            return;
        }
    };
    POOL_JOBS.fetch_add(1, Ordering::Relaxed);
    POOL_TASKS.fetch_add(n_tasks as u64, Ordering::Relaxed);
    let shared = pool.shared;
    *shared.panic_payload.lock().unwrap_or_else(PoisonError::into_inner) = None;
    let my_epoch;
    {
        let mut st = shared.lock();
        st.job = Some(Job { data: &f as *const F as *const (), call: call_closure::<F> });
        st.n_tasks = n_tasks;
        st.next = 0;
        st.running = 0;
        st.epoch += 1;
        my_epoch = st.epoch;
        shared.work.notify_all();
    }

    drain(shared, my_epoch);

    let mut st = shared.lock();
    while st.running > 0 {
        st = shared.done.wait(st).unwrap_or_else(PoisonError::into_inner);
    }
    st.job = None;
    drop(st);
    let payload = shared.panic_payload.lock().unwrap_or_else(PoisonError::into_inner).take();
    if let Some(payload) = payload {
        resume_unwind(payload);
    }
}

/// Split `data` into `chunk_len`-sized pieces and run `f(i, chunk_i)`
/// over them in parallel. `data.len()` must be a multiple of
/// `chunk_len`. The safe face of the disjoint-write contract for
/// batch-split kernels (im2col / col2im).
pub fn parallel_chunks<F: Fn(usize, &mut [f32]) + Sync>(
    data: &mut [f32],
    chunk_len: usize,
    f: F,
) {
    assert!(chunk_len > 0 && data.len() % chunk_len == 0, "parallel_chunks: uneven split");
    let n = data.len() / chunk_len;
    let base = SendPtr(data.as_mut_ptr());
    parallel_for(n, |i| {
        let chunk =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(i * chunk_len), chunk_len) };
        f(i, chunk);
    });
}

/// Zip two equal-length mutable slices and run `f(i, &mut a[i], &mut
/// b[i])` in parallel — the shape of an independent per-layer optimizer
/// step (params + state).
pub fn parallel_zip_mut<A: Send, B: Send, F: Fn(usize, &mut A, &mut B) + Sync>(
    xs: &mut [A],
    ys: &mut [B],
    f: F,
) {
    assert_eq!(xs.len(), ys.len(), "parallel_zip_mut: length mismatch");
    let xp = SendPtr(xs.as_mut_ptr());
    let yp = SendPtr(ys.as_mut_ptr());
    parallel_for(xs.len(), |i| {
        let x = unsafe { &mut *xp.0.add(i) };
        let y = unsafe { &mut *yp.0.add(i) };
        f(i, x, y);
    });
}

/// Raw pointer that may cross threads; every user hands out disjoint
/// regions per task index.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_every_index_exactly_once() {
        let n = 257;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn nested_calls_run_inline_without_deadlock() {
        let total = AtomicUsize::new(0);
        parallel_for(8, |_| {
            parallel_for(8, |_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn sequential_jobs_reuse_the_pool() {
        for round in 0..50 {
            let sum = AtomicUsize::new(0);
            parallel_for(round + 2, |i| {
                sum.fetch_add(i + 1, Ordering::SeqCst);
            });
            let n = round + 2;
            assert_eq!(sum.load(Ordering::SeqCst), n * (n + 1) / 2);
        }
    }

    #[test]
    fn chunks_are_disjoint_and_complete() {
        let mut data = vec![0.0f32; 12 * 5];
        parallel_chunks(&mut data, 5, |i, chunk| {
            for v in chunk.iter_mut() {
                *v += (i + 1) as f32;
            }
        });
        for (pos, v) in data.iter().enumerate() {
            assert_eq!(*v, (pos / 5 + 1) as f32, "pos {pos}");
        }
    }

    #[test]
    fn zip_mut_updates_both_sides() {
        let mut a = vec![0u64; 33];
        let mut b = vec![0u64; 33];
        parallel_zip_mut(&mut a, &mut b, |i, x, y| {
            *x = i as u64;
            *y = 2 * i as u64;
        });
        for i in 0..33 {
            assert_eq!(a[i], i as u64);
            assert_eq!(b[i], 2 * i as u64);
        }
    }

    #[test]
    fn pool_size_is_positive() {
        assert!(pool_size() >= 1);
        warm_pool();
    }

    #[test]
    fn thread_override_clamps_zero_to_one() {
        // JORGE_THREADS=0 must never size a zero-worker pool
        assert_eq!(parse_threads("0"), Some(1));
        assert_eq!(parse_threads("00"), Some(1));
        assert_eq!(parse_threads("1"), Some(1));
        assert_eq!(parse_threads(" 4 "), Some(4));
        // non-numeric garbage falls back to the hardware default
        assert_eq!(parse_threads("zero"), None);
        assert_eq!(parse_threads(""), None);
        assert_eq!(parse_threads("-2"), None);
    }

    #[test]
    fn dispatch_counters_track_jobs_and_tasks() {
        let before = dispatch_counters();
        parallel_for(1, |_| {}); // single task: always inline
        parallel_for(16, |_| {});
        let d = dispatch_counters().since(&before);
        assert!(d.pool_jobs + d.inline_jobs >= 2);
        assert!(d.tasks >= 17);
        assert!((0.0..=1.0).contains(&d.fanout_ratio()));
    }

    #[test]
    #[should_panic(expected = "task 3 boom")]
    fn task_panics_propagate_with_payload() {
        parallel_for(8, |i| {
            if i == 3 {
                panic!("task {i} boom");
            }
        });
    }
}
