//! Matrix inverse p-th roots: the operation Jorge exists to avoid.
//!
//! Three implementations, mirroring the comparison the paper runs:
//! * `inv_fourth_root_eigh`   — exact, via the Jacobi eigensolver
//!   (plays the role of cuSOLVER `syevd` in the Shampoo baseline);
//! * `inv_fourth_root_newton` — coupled Newton iteration (all GEMMs, the
//!   root used inside our Shampoo artifacts; Anil et al. 2021);
//! * `jorge_update`           — the paper's inverse-*free* single-step
//!   approximation (Eq. 11), also exposed from `optim::jorge`.

use super::eig::spectral_map;
use super::gemm::matmul;
use super::matrix::Matrix;

/// Exact `(A)^{-1/p}` via eigendecomposition, clipping eigenvalues at eps.
pub fn inv_pth_root_eigh(a: &Matrix, p: f32, eps: f32) -> Matrix {
    spectral_map(a, |w| w.max(eps).powf(-1.0 / p))
}

pub fn inv_fourth_root_eigh(a: &Matrix, eps: f32) -> Matrix {
    inv_pth_root_eigh(a, 4.0, eps)
}

/// Coupled Newton iteration for `(A + ridge I)^{-1/4}` — GEMMs only.
///
/// ```text
/// z  = (1+p) / (2 ||A||_F),   M0 = z A,   H0 = z^{1/p} I
/// Mi = (1-alpha) I + alpha M_k         (alpha = -1/p)
/// M' = Mi^p M_k,   H' = H_k Mi
/// ```
pub fn inv_fourth_root_newton(a: &Matrix, iters: usize, ridge: f32) -> Matrix {
    assert!(a.is_square());
    let n = a.rows;
    let p = 4.0f32;
    let alpha = -1.0 / p;

    let mut a_r = a.clone();
    for i in 0..n {
        a_r.data[i * n + i] += ridge;
    }
    let fnorm = a_r.frobenius().max(1e-30) as f32;
    let z = (1.0 + p) / (2.0 * fnorm);

    let mut m = a_r.scale(z);
    let mut h = Matrix::eye(n, z.powf(1.0 / p));
    let one_minus_alpha = 1.0 - alpha;

    for _ in 0..iters {
        // mi = (1-alpha) I + alpha m
        let mut mi = m.scale(alpha);
        for i in 0..n {
            mi.data[i * n + i] += one_minus_alpha;
        }
        let mi2 = matmul(&mi, &mi);
        let mi4 = matmul(&mi2, &mi2);
        m = matmul(&mi4, &m);
        h = matmul(&h, &mi);
    }
    h
}

/// The Jorge preconditioner update (Eq. 11): given the previous
/// inverse-fourth-root estimate `p_hat` and a gram statistic `s`,
/// produce the new estimate without any inverse:
///
/// ```text
/// X     = P^4 S,  nx = ||X||_F
/// P_new = ((nx+1)/nx)^{1/4} P (I - X/(4 nx) + 5 X^2/(32 nx^2))
/// ```
///
/// Must match `python/compile/kernels/jorge_update.py` bit-for-bit in
/// structure (validated against the HLO artifact in runtime tests).
pub fn jorge_update(p_hat: &Matrix, s: &Matrix) -> Matrix {
    assert!(p_hat.is_square() && p_hat.shape() == s.shape());
    let n = p_hat.rows;
    let p2 = matmul(p_hat, p_hat);
    let p4 = matmul(&p2, &p2);
    let x = matmul(&p4, s);

    let nx = x.frobenius() as f32;
    // Guardrail: a non-finite statistic (NaN/Inf gradient upstream) fails
    // the `<= 1e-30` check and would otherwise poison P forever; keep the
    // stale estimate instead (stale preconditioners are a sound
    // degradation mode — Anil et al. 2021).
    if !nx.is_finite() || nx <= 1e-30 {
        return p_hat.clone();
    }
    let a = 1.0 / (4.0 * nx);
    let b = 5.0 / (32.0 * nx * nx);
    let scale = ((nx + 1.0) / nx).powf(0.25);

    let x2 = matmul(&x, &x);
    // M = I - a X + b X^2
    let mut m = x.scale(-a);
    m.add_scaled_inplace(b, &x2);
    for i in 0..n {
        m.data[i * n + i] += 1.0;
    }
    let mut out = matmul(p_hat, &m);
    out.scale_inplace(scale);
    out
}

/// Dynamic beta2 rule of App. A.1: `beta2 = ||X|| / (||X|| + 1)`.
pub fn dynamic_beta2(nx: f64) -> f64 {
    nx / (nx + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Rng;
    use crate::tensor::gemm::gram_left;

    fn random_spd(n: usize, seed: u64, shift: f32) -> Matrix {
        let mut rng = Rng::new(seed);
        let g = Matrix::randn(n, n, 1.0, &mut rng);
        let mut s = gram_left(&g);
        s.scale_inplace(1.0 / n as f32);
        for i in 0..n {
            s.data[i * n + i] += shift;
        }
        s
    }

    fn fourth_power(h: &Matrix) -> Matrix {
        let h2 = matmul(h, h);
        matmul(&h2, &h2)
    }

    #[test]
    fn eigh_root_inverts_fourth_power() {
        let a = random_spd(12, 0, 0.5);
        let h = inv_fourth_root_eigh(&a, 1e-9);
        // h^4 @ a = I
        let prod = matmul(&fourth_power(&h), &a);
        assert!(
            prod.max_abs_diff(&Matrix::eye(12, 1.0)) < 5e-3,
            "err {}",
            prod.max_abs_diff(&Matrix::eye(12, 1.0))
        );
    }

    #[test]
    fn newton_matches_eigh() {
        for seed in 0..3 {
            let a = random_spd(16, seed, 0.3);
            let newton = inv_fourth_root_newton(&a, 30, 0.0);
            let exact = inv_fourth_root_eigh(&a, 1e-9);
            let rel = newton.max_abs_diff(&exact) / exact.max_abs();
            assert!(rel < 5e-2, "seed {seed}: rel err {rel}");
        }
    }

    #[test]
    fn newton_identity() {
        let eye = Matrix::eye(8, 1.0);
        let h = inv_fourth_root_newton(&eye, 25, 0.0);
        assert!(h.max_abs_diff(&Matrix::eye(8, 1.0)) < 1e-3);
    }

    #[test]
    fn newton_scales_correctly() {
        // (c I)^{-1/4} = c^{-1/4} I
        let a = Matrix::eye(6, 16.0);
        let h = inv_fourth_root_newton(&a, 25, 0.0);
        assert!(h.max_abs_diff(&Matrix::eye(6, 0.5)) < 1e-3);
    }

    #[test]
    fn jorge_update_zero_statistic_is_identity_op() {
        let p = Matrix::eye(10, 5.0);
        let s = Matrix::zeros(10, 10);
        assert_eq!(jorge_update(&p, &s), p);
    }

    #[test]
    fn jorge_update_nonfinite_statistic_keeps_stale_estimate() {
        let p = Matrix::eye(6, 2.0);
        let mut s = Matrix::zeros(6, 6);
        s.data[3] = f32::NAN;
        assert_eq!(jorge_update(&p, &s), p);
        let mut s_inf = Matrix::zeros(6, 6);
        s_inf.data[0] = f32::INFINITY;
        assert_eq!(jorge_update(&p, &s_inf), p);
        // a non-finite *estimate* stays non-finite (the optimizer layer
        // detects this and self-heals by resetting to the eps-identity)
        let mut p_bad = Matrix::eye(6, 1.0);
        p_bad.data[1] = f32::NAN;
        let s_ok = random_spd(6, 3, 0.5);
        assert!(!jorge_update(&p_bad, &s_ok).all_finite());
    }

    #[test]
    fn jorge_update_tracks_exact_root_on_fixed_statistic() {
        // Repeated updates on a constant statistic should drive P towards
        // the inverse fourth root of the EMA fixed point; check that
        // ||P^4 S - I-ish|| shrinks dramatically relative to the start.
        let s = random_spd(10, 7, 0.2);
        let mut p = Matrix::eye(10, (1e-2f32).powf(-0.25));
        let exact = inv_fourth_root_eigh(&s, 1e-9);
        let err0 = p.max_abs_diff(&exact);
        for _ in 0..40 {
            p = jorge_update(&p, &s);
            assert!(p.all_finite());
        }
        let err1 = p.max_abs_diff(&exact);
        assert!(
            err1 < 0.15 * err0,
            "no convergence towards exact root: {err0} -> {err1}"
        );
    }

    #[test]
    fn jorge_update_preserves_symmetry_approximately() {
        let s = random_spd(12, 9, 0.1);
        let mut p = Matrix::eye(12, (1e-3f32).powf(-0.25));
        for _ in 0..10 {
            p = jorge_update(&p, &s);
        }
        let asym = p.sub(&p.t()).max_abs() / p.max_abs();
        assert!(asym < 1e-2, "asymmetry {asym}");
    }

    #[test]
    fn dynamic_beta2_bound() {
        // beta2 must exceed ||X||/(||X||+1) - here equality; the series
        // argument then has norm exactly 1 (validity boundary).
        for &nx in &[1e-6, 1.0, 1e6] {
            let b2 = dynamic_beta2(nx);
            assert!(b2 > 0.0 && b2 < 1.0);
            let arg_norm = (1.0 - b2) / b2 * nx;
            assert!((arg_norm - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn newton_handles_ill_conditioned() {
        // condition number ~1e4
        let mut a = random_spd(12, 11, 1e-4);
        a.data[0] += 10.0;
        let h = inv_fourth_root_newton(&a, 40, 1e-6);
        assert!(h.all_finite());
        let prod = matmul(&fourth_power(&h), &a);
        // looser: ill-conditioned f32
        let err = prod.max_abs_diff(&Matrix::eye(12, 1.0));
        assert!(err < 0.5, "err {err}");
    }
}
