//! Step-phase tracing and the unified metrics registry.
//!
//! A run-wide singleton that attributes wall-clock to the phases of a
//! training step (data / forward / backward / grad all-reduce /
//! preconditioner refresh / preconditioner all-gather / apply /
//! checkpoint / resync / eval) and folds every subsystem's counters — guardrails,
//! faults, sharding, worker-pool dispatch — into one place. The trainer
//! drains it into a [`MetricsReport`] at the end of a run (`--metrics-out`)
//! and streams per-step phase rows as JSONL (`--trace`).
//!
//! Cost discipline: when tracing is disabled (the default) every entry
//! point is a single relaxed atomic load and nothing else — no clock
//! reads, no locks, no allocation — so instrumented code paths stay
//! bitwise identical to uninstrumented ones. Enabling tracing only adds
//! `Instant` reads and registry bookkeeping; it never touches RNG state
//! or float math, so traced trajectories are bitwise identical too.
//!
//! Phase scopes may fire from worker threads (the data-parallel gradient
//! fan-out runs `loss_grad` per simulated rank). Those samples add
//! *per-device* time, so with `--workers N` the forward/backward totals
//! sum across ranks and can exceed wall-clock — the same convention GPU
//! profilers use for per-device streams. Single-worker runs are strictly
//! sequential and their phase totals sum to the step wall-clock (pinned
//! within 5% by `tests/trace_layer.rs`).

use crate::jsonio::Json;
use crate::metricsio::Summary;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// The phases of one training step, in pipeline order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Batch assembly: dataset slicing + host tensor packing.
    Data,
    /// Model forward pass (per simulated rank under data parallelism).
    Forward,
    /// Model backward pass (per simulated rank under data parallelism).
    Backward,
    /// Ring/tree all-reduce of the gradient buckets, incl. fault retries.
    GradReduce,
    /// Owner-computes preconditioner refresh (gram + root / Jorge update).
    PrecondRefresh,
    /// Ring all-gather of refreshed preconditioners.
    PrecondGather,
    /// Parameter update (grafted step, weight decay, state writeback).
    Apply,
    /// Cadenced checkpoint save.
    Checkpoint,
    /// Rejoin readmission: leader state broadcast + owner re-assignment.
    Resync,
    /// Validation pass + eval-result broadcast.
    Eval,
}

/// Every phase, in the order reports and JSONL rows list them.
pub const PHASES: [Phase; 10] = [
    Phase::Data,
    Phase::Forward,
    Phase::Backward,
    Phase::GradReduce,
    Phase::PrecondRefresh,
    Phase::PrecondGather,
    Phase::Apply,
    Phase::Checkpoint,
    Phase::Resync,
    Phase::Eval,
];

impl Phase {
    /// Stable snake_case name — the JSONL/metrics key for this phase.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Data => "data",
            Phase::Forward => "forward",
            Phase::Backward => "backward",
            Phase::GradReduce => "grad_all_reduce",
            Phase::PrecondRefresh => "precond_refresh",
            Phase::PrecondGather => "precond_all_gather",
            Phase::Apply => "apply",
            Phase::Checkpoint => "checkpoint",
            Phase::Resync => "resync",
            Phase::Eval => "eval",
        }
    }

    fn index(self) -> usize {
        match self {
            Phase::Data => 0,
            Phase::Forward => 1,
            Phase::Backward => 2,
            Phase::GradReduce => 3,
            Phase::PrecondRefresh => 4,
            Phase::PrecondGather => 5,
            Phase::Apply => 6,
            Phase::Checkpoint => 7,
            Phase::Resync => 8,
            Phase::Eval => 9,
        }
    }
}

const N_PHASES: usize = PHASES.len();

/// Registry state behind the mutex. `scratch` accumulates the current
/// step; `flush_step` rolls it into the per-step distributions.
struct Inner {
    scratch: [f64; N_PHASES],
    per_step: Vec<Summary>,
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
}

impl Inner {
    fn new() -> Inner {
        Inner {
            scratch: [0.0; N_PHASES],
            per_step: (0..N_PHASES).map(|_| Summary::new()).collect(),
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static REGISTRY: Mutex<Option<Inner>> = Mutex::new(None);

/// Whether tracing is live. One relaxed load — the entire disabled-path
/// cost of every instrumentation point.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the registry on (resetting any prior state) or off. The trainer
/// flips this only for runs that asked for `--trace`/`--metrics-out`;
/// everything else never touches it.
pub fn set_enabled(on: bool) {
    if on {
        let mut guard = lock();
        *guard = Some(Inner::new());
    }
    ENABLED.store(on, Ordering::Relaxed);
}

fn lock() -> std::sync::MutexGuard<'static, Option<Inner>> {
    // A poisoned registry only ever holds timing telemetry; recover it
    // rather than cascading a panic out of an instrumentation point.
    REGISTRY.lock().unwrap_or_else(|e| e.into_inner())
}

fn with_inner(f: impl FnOnce(&mut Inner)) {
    let mut guard = lock();
    f(guard.get_or_insert_with(Inner::new));
}

/// RAII phase timer: accumulates elapsed seconds into the registry on
/// drop. Inert (no clock read) when tracing is disabled.
pub struct PhaseGuard {
    phase: Phase,
    start: Option<Instant>,
}

impl Drop for PhaseGuard {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            add_phase_s(self.phase, t0.elapsed().as_secs_f64());
        }
    }
}

/// Open a scoped timer for `phase`; time accrues until the guard drops.
#[inline]
pub fn scope(phase: Phase) -> PhaseGuard {
    let start = if enabled() { Some(Instant::now()) } else { None };
    PhaseGuard { phase, start }
}

/// Credit `s` seconds to `phase` in the current step directly (for
/// intervals measured by the caller rather than a scope).
pub fn add_phase_s(phase: Phase, s: f64) {
    if !enabled() {
        return;
    }
    with_inner(|inner| inner.scratch[phase.index()] += s);
}

/// Bump a named counter. Counter names are free-form dotted paths
/// (`guard.stale_preconds`, `fault.retries`, `pool.jobs`).
pub fn incr(name: &str, n: u64) {
    if !enabled() || n == 0 {
        return;
    }
    with_inner(|inner| *inner.counters.entry(name.to_string()).or_insert(0) += n);
}

/// Set a named gauge (last-write-wins scalar, e.g. modeled comm time).
pub fn set_gauge(name: &str, v: f64) {
    if !enabled() {
        return;
    }
    with_inner(|inner| {
        inner.gauges.insert(name.to_string(), v);
    });
}

/// Accumulate into a named gauge (running-sum scalar). Used for
/// per-layer attribution (`trace.layer.<i>.refresh_s` / `.apply_s`),
/// where many small samples from possibly-concurrent layer fan-outs
/// fold into one total per run.
pub fn add_gauge(name: &str, v: f64) {
    if !enabled() {
        return;
    }
    with_inner(|inner| {
        *inner.gauges.entry(name.to_string()).or_insert(0.0) += v;
    });
}

/// Close out the current step: roll the scratch phase times into the
/// per-step distributions and return this step's `(phase, seconds)` rows
/// (phases that did not run are omitted). `None` when tracing is off.
pub fn flush_step() -> Option<Vec<(&'static str, f64)>> {
    if !enabled() {
        return None;
    }
    let mut out = Vec::new();
    with_inner(|inner| {
        for ph in PHASES {
            let s = inner.scratch[ph.index()];
            if s > 0.0 {
                inner.per_step[ph.index()].add(s);
                out.push((ph.name(), s));
            }
        }
        inner.scratch = [0.0; N_PHASES];
    });
    Some(out)
}

/// Drain the registry into a [`MetricsReport`] (leaving it reset but
/// still enabled). Un-flushed scratch from a partial step is folded in
/// as one final sample first.
pub fn take_report() -> MetricsReport {
    let _ = flush_step();
    let mut report = MetricsReport::default();
    with_inner(|inner| {
        for ph in PHASES {
            let s = &inner.per_step[ph.index()];
            if s.count() == 0 {
                continue;
            }
            report.phases.push(PhaseStat {
                name: ph.name(),
                count: s.count() as u64,
                total_s: s.total(),
                p50_s: s.percentile(50.0),
                p95_s: s.percentile(95.0),
            });
        }
        report.counters = std::mem::take(&mut inner.counters);
        report.gauges = std::mem::take(&mut inner.gauges);
        *inner = Inner::new();
    });
    report
}

/// Per-phase timing distribution over the steps of one run.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseStat {
    pub name: &'static str,
    /// Steps in which the phase ran.
    pub count: u64,
    pub total_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

/// The unified per-run metrics: phase timings plus every subsystem's
/// counters and gauges under one roof. Serialises through the
/// `benchrun` JSON-row convention (`"name"`-keyed rows) so
/// `jorge bench-diff` can diff two runs' metrics files in CI.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsReport {
    pub phases: Vec<PhaseStat>,
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
}

impl MetricsReport {
    /// Sum of all phase totals.
    pub fn total_phase_s(&self) -> f64 {
        self.phases.iter().map(|p| p.total_s).sum()
    }

    /// Total seconds attributed to `phase`, 0 if it never ran.
    pub fn phase_total_s(&self, phase: Phase) -> f64 {
        self.phases
            .iter()
            .find(|p| p.name == phase.name())
            .map_or(0.0, |p| p.total_s)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// `{"phases": [{"name", "count", "total_s", "p50_s", "p95_s"}, ...],
    ///   "counters": {...}, "gauges": {...}}`
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .phases
            .iter()
            .map(|p| {
                let mut row = BTreeMap::new();
                row.insert("name".to_string(), Json::Str(p.name.to_string()));
                row.insert("count".to_string(), Json::Num(p.count as f64));
                row.insert("total_s".to_string(), Json::Num(p.total_s));
                row.insert("p50_s".to_string(), Json::Num(p.p50_s));
                row.insert("p95_s".to_string(), Json::Num(p.p95_s));
                Json::Obj(row)
            })
            .collect();
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> =
            self.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect();
        let mut obj = BTreeMap::new();
        obj.insert("phases".to_string(), Json::Arr(rows));
        obj.insert("counters".to_string(), Json::Obj(counters));
        obj.insert("gauges".to_string(), Json::Obj(gauges));
        Json::Obj(obj)
    }
}

impl fmt::Display for MetricsReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let total = self.total_phase_s().max(1e-12);
        write!(f, "phases:")?;
        for p in &self.phases {
            write!(
                f,
                " {}={:.4}s({:.0}%)",
                p.name,
                p.total_s,
                100.0 * p.total_s / total
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    // The registry is process-global; serialise the tests that flip it.
    static TEST_LOCK: StdMutex<()> = StdMutex::new(());

    #[test]
    fn disabled_tracing_records_nothing() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        {
            let _s = scope(Phase::Forward);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        incr("x", 3);
        assert!(flush_step().is_none());
        set_enabled(true);
        let report = take_report();
        assert!(report.phases.is_empty());
        assert_eq!(report.counter("x"), 0);
        set_enabled(false);
    }

    #[test]
    fn scopes_accumulate_and_flush_per_step() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        for _ in 0..3 {
            add_phase_s(Phase::Data, 0.25);
            add_phase_s(Phase::Apply, 0.5);
            add_phase_s(Phase::Apply, 0.25);
            let rows = flush_step().unwrap();
            assert_eq!(rows, vec![("data", 0.25), ("apply", 0.75)]);
        }
        incr("guard.stale_preconds", 2);
        incr("guard.stale_preconds", 1);
        set_gauge("modeled_comm_s", 0.125);
        let report = take_report();
        assert_eq!(report.phases.len(), 2);
        assert_eq!(report.phase_total_s(Phase::Data), 0.75);
        assert_eq!(report.phase_total_s(Phase::Apply), 2.25);
        assert_eq!(report.phases[1].count, 3);
        assert_eq!(report.phases[1].p50_s, 0.75);
        assert_eq!(report.counter("guard.stale_preconds"), 3);
        assert_eq!(report.gauge("modeled_comm_s"), Some(0.125));
        // drained: a second take is empty
        assert!(take_report().phases.is_empty());
        set_enabled(false);
    }

    #[test]
    fn add_gauge_accumulates_and_respects_enable() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        add_gauge("trace.layer.0.refresh_s", 1.0);
        set_enabled(true);
        add_gauge("trace.layer.0.refresh_s", 0.25);
        add_gauge("trace.layer.0.refresh_s", 0.5);
        add_gauge("trace.layer.1.apply_s", 0.125);
        let report = take_report();
        assert_eq!(report.gauge("trace.layer.0.refresh_s"), Some(0.75));
        assert_eq!(report.gauge("trace.layer.1.apply_s"), Some(0.125));
        set_enabled(false);
    }

    #[test]
    fn scope_guard_measures_wall_time() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        {
            let _s = scope(Phase::Backward);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let report = take_report();
        assert!(report.phase_total_s(Phase::Backward) >= 0.002);
        set_enabled(false);
    }

    #[test]
    fn report_json_uses_name_keyed_rows() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        add_phase_s(Phase::GradReduce, 0.5);
        incr("fault.retries", 4);
        let j = take_report().to_json();
        set_enabled(false);
        let rows = j.get("phases").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("grad_all_reduce"));
        assert_eq!(rows[0].get("total_s").unwrap().as_f64(), Some(0.5));
        assert_eq!(rows[0].get("p95_s").unwrap().as_f64(), Some(0.5));
        let c = j.get("counters").unwrap();
        assert_eq!(c.get("fault.retries").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn phase_names_are_unique_and_ordered() {
        let names: Vec<&str> = PHASES.iter().map(|p| p.name()).collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names, dedup);
        for (i, ph) in PHASES.iter().enumerate() {
            assert_eq!(ph.index(), i);
        }
    }
}
