//! Cross-backend checkpoint compatibility.
//!
//! The checkpoint format's cross-backend contract is the naming scheme:
//! `param/{name}` / `state/{name}` keyed by the *manifest* input specs
//! (identical for every backend, since all backends load the same
//! manifest), plus `meta/global_step` and — native-mirror runs only —
//! `native/{i:04}` / `native/step_count`. These tests pin that contract
//! from the native side; the PJRT half runs when the `pjrt` feature and
//! compiled artifacts are present.

use jorge::config::{ScheduleKind, TrainConfig};
use jorge::coordinator::{checkpoint, Trainer};
use jorge::runtime::{ExecBackend, Manifest, NativeBackend, Role};
use std::sync::Arc;

fn backend() -> Arc<dyn ExecBackend> {
    Arc::new(NativeBackend::new())
}

fn cfg(opt: &str, workers: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp".into(),
        optimizer: opt.parse().unwrap(),
        epochs: 1,
        steps_per_epoch: 4,
        lr: 0.01,
        weight_decay: 1e-4,
        schedule: ScheduleKind::Constant,
        precond_every: 2,
        seed: 55,
        workers,
        dataset_size: 64 * 4 * workers.max(1) * 2,
        eval_every_epochs: 1000,
        backend: "native".into(),
        ..Default::default()
    }
}

fn tmp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("jorge_compat_{tag}_{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

#[test]
fn checkpoint_names_follow_manifest_spec_order() {
    let eng = backend();
    let c = cfg("jorge", 1);
    let step_name = Manifest::train_name(&c.model, c.optimizer, true);
    let spec = eng.load(&step_name).unwrap();
    let mut expected: Vec<String> = Vec::new();
    for input in &spec.spec().inputs {
        match input.role {
            Role::Param => expected.push(format!("param/{}", input.name)),
            Role::State => expected.push(format!("state/{}", input.name)),
            _ => {}
        }
    }
    expected.push("meta/global_step".into());

    let path = tmp("names");
    let mut trainer = Trainer::new(c, eng).unwrap();
    trainer.run().unwrap();
    trainer.save_checkpoint(&path).unwrap();
    let tensors = checkpoint::load(&path).unwrap();
    let names: Vec<String> = tensors.iter().map(|(n, _)| n.clone()).collect();
    assert_eq!(names, expected, "checkpoint naming drifted from the manifest contract");

    // shapes must match the manifest specs, so any backend can validate
    for (name, t) in &tensors {
        if let Some(io) = spec
            .spec()
            .inputs
            .iter()
            .find(|i| name.strip_prefix("param/") == Some(i.name.as_str())
                || name.strip_prefix("state/") == Some(i.name.as_str()))
        {
            assert_eq!(t.shape(), &io.shape[..], "{name}");
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn checkpoint_loads_into_a_fresh_backend_instance() {
    // two independently-constructed backends must agree on the format
    let path = tmp("roundtrip");
    let mut a = Trainer::new(cfg("jorge", 1), backend()).unwrap();
    a.run().unwrap();
    let (loss_a, metric_a) = a.evaluate().unwrap();
    a.save_checkpoint(&path).unwrap();

    let mut b = Trainer::new(cfg("jorge", 1), backend()).unwrap();
    b.load_checkpoint(&path).unwrap();
    let (loss_b, metric_b) = b.evaluate().unwrap();
    assert_eq!(loss_a.to_bits(), loss_b.to_bits());
    assert_eq!(metric_a.to_bits(), metric_b.to_bits());
    std::fs::remove_file(&path).ok();
}

#[test]
fn native_mirror_state_rides_along_and_restores() {
    // sharded runs carry the mirror's preconditioners + step counter
    let path = tmp("native_state");
    let mut a = Trainer::new(cfg("jorge_sharded", 2), backend()).unwrap();
    a.run().unwrap();
    a.save_checkpoint(&path).unwrap();

    let tensors = checkpoint::load(&path).unwrap();
    assert!(
        tensors.iter().any(|(n, _)| n.starts_with("native/") && n != "native/step_count"),
        "sharded checkpoint must carry native mirror state"
    );
    assert!(tensors.iter().any(|(n, _)| n == "native/step_count"));

    let mut b = Trainer::new(cfg("jorge_sharded", 2), backend()).unwrap();
    b.load_checkpoint(&path).unwrap();
    let (la, ma) = a.evaluate().unwrap();
    let (lb, mb) = b.evaluate().unwrap();
    assert_eq!(la.to_bits(), lb.to_bits());
    assert_eq!(ma.to_bits(), mb.to_bits());
    std::fs::remove_file(&path).ok();
}

#[test]
fn serial_checkpoint_has_no_native_state() {
    // the artifact-only path must not grow hidden state the PJRT side
    // would not know how to produce
    let path = tmp("no_native");
    let mut a = Trainer::new(cfg("jorge", 1), backend()).unwrap();
    a.save_checkpoint(&path).unwrap();
    let tensors = checkpoint::load(&path).unwrap();
    assert!(tensors.iter().all(|(n, _)| !n.starts_with("native/")));
    std::fs::remove_file(&path).ok();
}

#[cfg(feature = "pjrt")]
mod pjrt_side {
    use super::*;
    use jorge::runtime::backend_for;

    /// Native-saved checkpoints load into a PJRT-backed trainer (and
    /// vice versa) because both sides key tensors off the same manifest.
    /// Skips silently when no compiled artifacts are present.
    #[test]
    fn native_checkpoint_loads_under_pjrt() {
        let Ok(pjrt) = backend_for("artifacts", "pjrt") else {
            eprintln!("skipping: no compiled artifacts for the pjrt backend");
            return;
        };
        let path = tmp("pjrt");
        let mut c = cfg("jorge", 1);
        let mut a = Trainer::new(c.clone(), backend()).unwrap();
        a.run().unwrap();
        a.save_checkpoint(&path).unwrap();

        c.backend = "pjrt".into();
        let mut b = Trainer::new(c, pjrt).unwrap();
        b.load_checkpoint(&path).unwrap();
        let (loss, metric) = b.evaluate().unwrap();
        assert!(loss.is_finite() && metric.is_finite());
        std::fs::remove_file(&path).ok();
    }
}
