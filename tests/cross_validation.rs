//! Cross-validation: every execution path must implement the SAME
//! optimizer semantics.
//!
//! Always-on (native backend): the stateless `apply_*` / `train_*` steps
//! must reproduce the live optimizer mirrors exactly — this pins the
//! state round-trip through the manifest I/O convention (including
//! AdamW's bias-correction counter and the jorge/shampoo `_skip`
//! variants). With `--features pjrt` and artifacts present, the same
//! harness additionally pins the HLO-artifact math to the mirrors.

use jorge::optim::{build, Hyper, StepCtx};
use jorge::rngx::Rng;
use jorge::runtime::{ExecBackend, HostTensor, NativeBackend, Role};
use jorge::tensor::Matrix;
use std::sync::Arc;

fn native() -> Arc<dyn ExecBackend> {
    Arc::new(NativeBackend::new())
}

/// Drive the backend's `apply_mlp_*` step and the live mirror with
/// identical params/grads for `steps` steps; assert the trajectories
/// agree to `tol`.
fn check_apply_matches_mirror(eng: &dyn ExecBackend, opt_name: &str, steps: usize, tol: f32) {
    let full = eng.load(&format!("apply_mlp_{opt_name}")).unwrap();
    let has_skip = matches!(opt_name, "jorge" | "shampoo");
    let skip = if has_skip {
        Some(eng.load(&format!("apply_mlp_{opt_name}_skip")).unwrap())
    } else {
        None
    };

    // shapes from the artifact spec
    let param_specs: Vec<_> = full
        .spec()
        .inputs
        .iter()
        .filter(|i| i.role == Role::Param)
        .cloned()
        .collect();
    let shapes: Vec<(usize, usize)> = param_specs
        .iter()
        .map(|s| (s.shape[0], s.shape.get(1).copied().unwrap_or(1)))
        .collect();

    let mut rng = Rng::new(42);
    let params0: Vec<Matrix> =
        shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.3, &mut rng)).collect();

    // backend-side state from manifest init rules
    let mut init_rng = Rng::new(7);
    let mut art_state: Vec<HostTensor> = full
        .spec()
        .inputs
        .iter()
        .filter(|i| i.role == Role::State)
        .map(|s| HostTensor::from_init(s, &mut init_rng).unwrap())
        .collect();
    let mut art_params: Vec<HostTensor> = params0
        .iter()
        .zip(&param_specs)
        .map(|(m, s)| HostTensor::from_f32(s.shape.clone(), m.data.clone()))
        .collect();

    let mut mirror = build(opt_name.parse().unwrap(), &shapes, Hyper::default());
    let mut mirror_params = params0.clone();

    let mut grad_rng = Rng::new(99);
    for step in 0..steps {
        let update = step % 2 == 0; // exercise full and skip variants
        let grads: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.05, &mut grad_rng)).collect();

        // backend step
        let exe = if update || skip.is_none() { &full } else { skip.as_ref().unwrap() };
        let mut inputs: Vec<HostTensor> = art_params.clone();
        for (g, s) in grads.iter().zip(&param_specs) {
            inputs.push(HostTensor::from_f32(s.shape.clone(), g.data.clone()));
        }
        inputs.extend(art_state.iter().cloned());
        inputs.push(HostTensor::scalar_f32(0.05));
        inputs.push(HostTensor::scalar_f32(1e-3));
        let mut out = exe.run(&inputs).unwrap();
        let st = out.split_off(art_params.len());
        art_params = out;
        art_state = st;

        // mirror step
        mirror.step(
            &mut mirror_params,
            &grads,
            StepCtx { lr: 0.05, weight_decay: 1e-3, update_precond: update },
        );

        for (i, (a, n)) in art_params.iter().zip(&mirror_params).enumerate() {
            let a = a.as_f32().unwrap();
            let scale = n.max_abs().max(1e-6);
            let max_err =
                a.iter().zip(&n.data).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            assert!(
                max_err / scale < tol,
                "{opt_name} step {step} param {i}: rel err {} (tol {tol})",
                max_err / scale
            );
        }
    }
}

#[test]
fn sgd_apply_matches_mirror() {
    check_apply_matches_mirror(native().as_ref(), "sgd", 4, 1e-6);
}

#[test]
fn adamw_apply_matches_mirror() {
    check_apply_matches_mirror(native().as_ref(), "adamw", 4, 1e-6);
}

#[test]
fn jorge_apply_matches_mirror() {
    check_apply_matches_mirror(native().as_ref(), "jorge", 4, 1e-6);
}

#[test]
fn shampoo_apply_matches_mirror() {
    check_apply_matches_mirror(native().as_ref(), "shampoo", 4, 1e-6);
}

/// `train_mlp_sgd(params, state, batch)` must equal
/// `apply_mlp_sgd(params, grad_mlp(params, batch), state)`.
fn check_fused_equals_grad_plus_apply(eng: &dyn ExecBackend) {
    let fused = eng.load("train_mlp_sgd").unwrap();
    let grad = eng.load("grad_mlp").unwrap();
    let apply = eng.load("apply_mlp_sgd").unwrap();

    let mut rng = Rng::new(5);
    let params: Vec<HostTensor> = fused
        .spec()
        .inputs
        .iter()
        .filter(|i| i.role == Role::Param)
        .map(|s| HostTensor::from_init(s, &mut rng).unwrap())
        .collect();
    let state: Vec<HostTensor> = fused
        .spec()
        .inputs
        .iter()
        .filter(|i| i.role == Role::State)
        .map(|s| HostTensor::from_init(s, &mut rng).unwrap())
        .collect();
    let spec = fused.spec();
    let xspec = &spec.inputs[spec.input_index(Role::X).unwrap()];
    let yspec = &spec.inputs[spec.input_index(Role::Y).unwrap()];
    let n: usize = xspec.shape.iter().product();
    let mut xdata = vec![0.0f32; n];
    rng.fill_normal(&mut xdata, 0.0, 1.0);
    let x = HostTensor::from_f32(xspec.shape.clone(), xdata);
    let ydata: Vec<i32> = (0..yspec.elements()).map(|_| rng.below(10) as i32).collect();
    let y = HostTensor::from_i32(yspec.shape.clone(), ydata);

    // fused
    let mut inputs: Vec<HostTensor> = params.clone();
    inputs.extend(state.iter().cloned());
    inputs.push(x.clone());
    inputs.push(y.clone());
    inputs.push(HostTensor::scalar_f32(0.1));
    inputs.push(HostTensor::scalar_f32(1e-4));
    let fused_out = fused.run(&inputs).unwrap();

    // grad + apply
    let mut ginputs: Vec<HostTensor> = params.clone();
    ginputs.push(x);
    ginputs.push(y);
    let gout = grad.run(&ginputs).unwrap();
    let np = params.len();
    let grads = &gout[..np];
    let (loss, metric) = (gout[np].scalar(), gout[np + 1].scalar());

    let mut ainputs: Vec<HostTensor> = params.clone();
    ainputs.extend(grads.iter().cloned());
    ainputs.extend(state.iter().cloned());
    ainputs.push(HostTensor::scalar_f32(0.1));
    ainputs.push(HostTensor::scalar_f32(1e-4));
    let aout = apply.run(&ainputs).unwrap();

    // compare params, state, loss, metric
    let fl = fused_out[fused_out.len() - 2].scalar();
    let fm = fused_out[fused_out.len() - 1].scalar();
    assert!((fl - loss).abs() < 1e-5, "{fl} vs {loss}");
    assert!((fm - metric).abs() < 1e-6);
    for (i, (a, b)) in fused_out[..aout.len()].iter().zip(&aout).enumerate() {
        let av = a.as_f32().unwrap();
        let bv = b.as_f32().unwrap();
        let max_err = av.iter().zip(bv).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(max_err < 1e-4, "output {i}: {max_err}");
    }
}

#[test]
fn fused_train_step_equals_grad_plus_apply() {
    check_fused_equals_grad_plus_apply(native().as_ref());
}

/// Sanity on the grad step: loss finite and positive, grads finite.
fn check_grad_step_sane(eng: &dyn ExecBackend) {
    let grad = eng.load("grad_mlp").unwrap();
    let mut rng = Rng::new(11);
    let mut inputs: Vec<HostTensor> = Vec::new();
    for s in &grad.spec().inputs {
        match s.role {
            Role::Param => {
                let mut d = vec![0.0f32; s.elements()];
                rng.fill_normal(&mut d, 0.0, 0.1);
                inputs.push(HostTensor::from_f32(s.shape.clone(), d));
            }
            Role::X => {
                let mut d = vec![0.0f32; s.elements()];
                rng.fill_normal(&mut d, 0.0, 1.0);
                inputs.push(HostTensor::from_f32(s.shape.clone(), d));
            }
            Role::Y => {
                let d: Vec<i32> = (0..s.elements()).map(|_| rng.below(10) as i32).collect();
                inputs.push(HostTensor::from_i32(s.shape.clone(), d));
            }
            _ => unreachable!(),
        }
    }
    let out = grad.run(&inputs).unwrap();
    for (t, spec) in out.iter().zip(&grad.spec().outputs) {
        if let Some(d) = t.as_f32() {
            assert!(d.iter().all(|v| v.is_finite()), "{} not finite", spec.name);
        }
    }
    let loss = out[out.len() - 2].scalar();
    assert!(loss > 0.0 && loss < 20.0);
}

#[test]
fn grad_step_outputs_finite_and_bounded() {
    check_grad_step_sane(native().as_ref());
}

// -- HLO-artifact agreement (requires `--features pjrt` + `make artifacts`)

#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use super::*;
    use jorge::runtime::Engine;

    fn engine() -> Option<Arc<dyn ExecBackend>> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Arc::new(Engine::new(dir).unwrap()))
    }

    #[test]
    fn artifact_apply_matches_mirror_all_optimizers() {
        let Some(eng) = engine() else { return };
        check_apply_matches_mirror(eng.as_ref(), "sgd", 4, 1e-4);
        check_apply_matches_mirror(eng.as_ref(), "adamw", 4, 1e-4);
        // f32 GEMM chains: slightly looser tolerance
        check_apply_matches_mirror(eng.as_ref(), "jorge", 4, 5e-3);
        check_apply_matches_mirror(eng.as_ref(), "shampoo", 4, 5e-3);
    }

    #[test]
    fn artifact_fused_equals_grad_plus_apply() {
        let Some(eng) = engine() else { return };
        check_fused_equals_grad_plus_apply(eng.as_ref());
    }

    #[test]
    fn artifact_grad_outputs_finite() {
        let Some(eng) = engine() else { return };
        check_grad_step_sane(eng.as_ref());
    }
}
