//! Fault-tolerant runtime acceptance suite: injected worker failures,
//! silent data corruption, stragglers, and crash/resume — end-to-end
//! through the pure-Rust backend with deterministic fault plans.
//!
//! Pins the three robustness contracts:
//! 1. a sharded run survives an owner failure mid-all-gather via the
//!    stale-preconditioner fallback + survivor re-assignment;
//! 2. an injected NaN gradient trips the numerical guardrails and the
//!    run still finishes with finite losses;
//! 3. a corrupted newest checkpoint is skipped and `resume = auto`
//!    falls back to the previous valid one, continuing bitwise
//!    identically to an uninterrupted run;
//! 4. a dropped rank readmitted by a `rejoin` event resyncs through the
//!    leader state broadcast and the trajectory from the rejoin step
//!    onward is bitwise identical to a full-membership run entering
//!    that step with the same state;
//! 5. (fuzz) any random seeded fault plan either completes with finite
//!    surviving-rank losses or fails with a typed error — never a
//!    panic.

use jorge::config::{ScheduleKind, TrainConfig};
use jorge::coordinator::{checkpoint, Trainer};
use jorge::runtime::{ExecBackend, NativeBackend};
use std::sync::Arc;

fn backend() -> Arc<dyn ExecBackend> {
    Arc::new(NativeBackend::new())
}

fn cfg(opt: &str, workers: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp".into(),
        optimizer: opt.parse().unwrap(),
        epochs: 2,
        steps_per_epoch: 8,
        lr: 0.01,
        weight_decay: 1e-4,
        schedule: ScheduleKind::Constant,
        precond_every: 2,
        seed: 41,
        workers,
        dataset_size: 64 * 8 * workers.max(1) * 2,
        eval_every_epochs: 1000,
        backend: "native".into(),
        ..Default::default()
    }
}

#[test]
fn owner_drop_mid_gather_degrades_to_stale_preconditioners() {
    let eng = backend();
    let mut c = cfg("jorge_sharded", 4);
    // step 2 is an update step (precond_every = 2): kill rank 1 during
    // the preconditioner all-gather, after its refresh ran
    c.faults = "drop@2:r1:precond".into();
    let mut trainer = Trainer::new(c, eng).unwrap();
    let r = trainer.run().unwrap();

    // the run completed, numerically sound
    assert!(r.step_losses.iter().all(|l| l.is_finite()));
    assert!(r.final_val_metric.is_finite());
    let last = r.epochs.last().unwrap().train_loss;
    let first = r.step_losses.first().copied().unwrap() as f64;
    assert!(last < first, "no learning under fault: {first} -> {last}");

    // degradation is visible in the shard telemetry
    let sh = r.shard.expect("sharded run must report shard telemetry");
    assert!(
        sh.stale_fallback_layers >= 1,
        "owner drop must fall back to stale preconditioners: {sh:?}"
    );
    assert!(sh.reassignments >= 1, "survivors must re-balance ownership: {sh:?}");
    // rank 1 owns nothing after the re-assignment
    assert!(sh.owned_layers[1].is_empty(), "dead rank still owns layers: {sh:?}");
    // all preconditioned layers are owned by survivors
    let owned_total: usize = sh.owned_layers.iter().map(Vec::len).sum();
    assert_eq!(owned_total, 3, "mlp has 3 preconditioned layers: {sh:?}");

    // and in the fault report
    let f = r.faults.expect("fault plan was active");
    assert_eq!(f.dropped, vec![1]);
    assert_eq!(f.survivors, 3);
    assert_eq!(f.events.len(), 1);
    assert!(f.events[0].contains("rank 1"), "{:?}", f.events);
    assert!(f.events[0].contains("drop"), "{:?}", f.events);
}

#[test]
fn dropped_worker_during_grad_reduce_is_shed() {
    let eng = backend();
    let mut c = cfg("jorge", 2);
    c.faults = "drop@3:r1:grad".into();
    let mut trainer = Trainer::new(c, eng).unwrap();
    let r = trainer.run().unwrap();
    assert!(r.step_losses.iter().all(|l| l.is_finite()));
    let f = r.faults.expect("fault plan was active");
    assert_eq!(f.dropped, vec![1]);
    assert_eq!(f.survivors, 1);
}

#[test]
fn corrupt_gradient_trips_guardrails_and_training_survives() {
    let eng = backend();
    let mut c = cfg("jorge_sharded", 2);
    // poison rank 0's gradient buffer with NaNs before the reduce; the
    // native mirror's guardrails must absorb it
    c.faults = "corrupt@1:r0:grad".into();
    c.fault_seed = 7;
    let mut trainer = Trainer::new(c, eng).unwrap();
    let r = trainer.run().unwrap();

    // every loss and the final eval stay finite
    assert!(r.step_losses.iter().all(|l| l.is_finite()));
    assert!(r.final_val_metric.is_finite());
    for p in &trainer.params {
        assert!(p.as_f32().unwrap().iter().all(|v| v.is_finite()), "non-finite params");
    }

    // the guardrails saw the NaNs and skipped the poisoned layers
    assert!(r.guard.nonfinite_grads >= 1, "guardrails missed the NaNs: {}", r.guard);
    assert!(r.guard.skipped_updates >= 1, "poisoned update not skipped: {}", r.guard);

    // nobody died: corruption is silent, both ranks survive
    let f = r.faults.expect("fault plan was active");
    assert!(f.dropped.is_empty());
    assert_eq!(f.survivors, 2);
    assert!(f.events[0].contains("corrupt"), "{:?}", f.events);
}

#[test]
fn recovered_straggler_leaves_trajectory_bitwise_identical() {
    let eng = backend();
    // a delay within the retry budget recovers: buffers untouched, so
    // the trajectory must equal the fault-free run bit for bit
    let mut c_fault = cfg("jorge_sharded", 2);
    c_fault.faults = "delay@1:r0:grad:x2".into();
    let c_clean = cfg("jorge_sharded", 2);

    let r_fault = Trainer::new(c_fault, eng.clone()).unwrap().run().unwrap();
    let r_clean = Trainer::new(c_clean, eng).unwrap().run().unwrap();

    assert_eq!(r_fault.step_losses, r_clean.step_losses);
    assert_eq!(
        r_fault.final_val_metric.to_bits(),
        r_clean.final_val_metric.to_bits()
    );

    let f = r_fault.faults.expect("fault plan was active");
    assert_eq!(f.retries, 2);
    assert!(f.modeled_backoff_s > 0.0);
    assert!(f.dropped.is_empty());
    assert!(f.events[0].contains("recovered"), "{:?}", f.events);
    assert!(r_clean.faults.is_none(), "no plan => no fault report");
}

#[test]
fn exhausted_retry_budget_times_out_into_drop() {
    let eng = backend();
    let mut c = cfg("jorge", 2);
    // x9 exceeds the default 3-attempt budget: treated as a drop
    c.faults = "delay@2:r1:grad:x9".into();
    let mut trainer = Trainer::new(c, eng).unwrap();
    let r = trainer.run().unwrap();
    assert!(r.step_losses.iter().all(|l| l.is_finite()));
    let f = r.faults.expect("fault plan was active");
    assert_eq!(f.dropped, vec![1]);
    assert!(f.events[0].contains("timed out"), "{:?}", f.events);
}

#[test]
fn fault_free_sharded_run_reports_no_degradation() {
    // regression guard: with no plan the fault machinery must be inert
    let eng = backend();
    let r = Trainer::new(cfg("jorge_sharded", 4), eng).unwrap().run().unwrap();
    assert!(r.faults.is_none());
    assert_eq!(r.guard.total(), 0);
    let sh = r.shard.unwrap();
    assert_eq!(sh.stale_fallback_layers, 0);
    assert_eq!(sh.reassignments, 0);
}

#[test]
fn auto_resume_falls_back_past_corrupt_checkpoint_bitwise() {
    let eng = backend();
    let dir = std::env::temp_dir().join(format!("jorge_ft_resume_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_str().unwrap().to_string();

    // uninterrupted reference run, checkpointing every 5 steps
    let mut c = cfg("jorge", 1);
    c.checkpoint_every = 5;
    c.checkpoint_dir = dir_s.clone();
    let mut full = Trainer::new(c.clone(), eng.clone()).unwrap();
    let r_full = full.run().unwrap();
    assert_eq!(r_full.step_losses.len(), 16);
    for step in [5usize, 10, 15] {
        assert!(checkpoint::step_path(&dir_s, step).exists(), "missing ckpt at {step}");
    }

    // "crash": flip one payload bit in the newest checkpoint
    let newest = checkpoint::step_path(&dir_s, 15);
    let mut bytes = std::fs::read(&newest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&newest, &bytes).unwrap();
    assert!(
        checkpoint::load(&newest).is_err(),
        "bit-flipped checkpoint must fail the CRC check"
    );

    // auto-resume must skip the corrupt file, restore step 10, and land
    // on exactly the same trajectory
    let mut c2 = c.clone();
    c2.resume = "auto".into();
    let mut resumed = Trainer::new(c2, eng.clone()).unwrap();
    let r_res = resumed.run().unwrap();
    assert_eq!(r_res.step_losses.len(), 6, "should rerun steps 10..16");
    assert_eq!(r_res.step_losses[..], r_full.step_losses[10..]);
    for (a, b) in full.params.iter().zip(&resumed.params) {
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap(), "params diverged after resume");
    }

    // explicit load of the corrupt file is a typed error at the trainer
    // level too
    let mut probe = Trainer::new(c.clone(), eng.clone()).unwrap();
    assert!(probe.load_checkpoint(newest.to_str().unwrap()).is_err());

    // resume = auto with an empty directory starts fresh
    std::fs::remove_dir_all(&dir).ok();
    let mut c3 = c.clone();
    c3.resume = "auto".into();
    c3.checkpoint_every = 0;
    let r_fresh = Trainer::new(c3, eng).unwrap().run().unwrap();
    assert_eq!(r_fresh.step_losses, r_full.step_losses);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_from_explicit_checkpoint_path() {
    let eng = backend();
    let dir = std::env::temp_dir().join(format!("jorge_ft_explicit_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_str().unwrap().to_string();

    let mut c = cfg("jorge", 1);
    c.checkpoint_every = 8;
    c.checkpoint_dir = dir_s.clone();
    let mut full = Trainer::new(c.clone(), eng.clone()).unwrap();
    let r_full = full.run().unwrap();

    let mut c2 = c.clone();
    c2.resume = checkpoint::step_path(&dir_s, 8).to_str().unwrap().to_string();
    c2.checkpoint_every = 0;
    let mut resumed = Trainer::new(c2, eng).unwrap();
    let r_res = resumed.run().unwrap();
    assert_eq!(r_res.step_losses[..], r_full.step_losses[8..]);
    for (a, b) in full.params.iter().zip(&resumed.params) {
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fault_plans_only_arm_on_multi_worker_runs() {
    // config validation rejects a plan that would be silently inert
    let eng = backend();
    let mut c = cfg("jorge", 1);
    c.faults = "drop@1:r0".into();
    assert!(Trainer::new(c, eng).is_err());
}

#[test]
fn rejoined_rank_resyncs_and_telemetry_counts_it() {
    let eng = backend();
    let mut c = cfg("jorge_sharded", 4);
    c.faults = "drop@2:r1:grad; rejoin@5:r1".into();
    let mut trainer = Trainer::new(c, eng).unwrap();
    let r = trainer.run().unwrap();

    assert!(r.step_losses.iter().all(|l| l.is_finite()));
    let sh = r.shard.expect("sharded run must report shard telemetry");
    assert_eq!(sh.rejoin_events, 1, "{sh:?}");
    assert!(sh.resync_bytes > 0, "resync must move the state blob: {sh:?}");
    // shed at step 2, readmitted at step 5: two re-balances
    assert!(sh.reassignments >= 2, "{sh:?}");
    // after readmission every preconditioned layer is owned again and
    // the restored LPT gives rank 1 its share back
    let owned_total: usize = sh.owned_layers.iter().map(Vec::len).sum();
    assert_eq!(owned_total, 3, "mlp has 3 preconditioned layers: {sh:?}");
    assert!(!sh.owned_layers[1].is_empty(), "rejoined rank owns nothing: {sh:?}");

    let f = r.faults.expect("fault plan was active");
    assert_eq!(f.rejoins, 1);
    assert!(f.resync_bytes > 0);
    assert_eq!(f.membership_epochs, 2, "one leave + one rejoin: {f:?}");
    assert!(f.dropped.is_empty(), "rejoined rank must count as alive: {f:?}");
    assert_eq!(f.survivors, 4);
    let rejoin_line = f
        .events
        .iter()
        .find(|e| e.contains("rejoin"))
        .expect("rejoin event must be recorded");
    assert!(rejoin_line.contains("step 5 rank 1"), "{rejoin_line}");
    assert!(rejoin_line.contains("readmitted"), "{rejoin_line}");
}

/// The tentpole correctness bar: drop rank 1 at step 2, rejoin it at
/// step 5, run to step 16 — from step 5 onward the run must be bitwise
/// identical to a full-membership run entering step 5 with the same
/// state. The reference run is constructed by resuming (fault-free)
/// from the cadence checkpoint taken at step 5, which holds exactly
/// the state the resync broadcast carried (the blob codepaths are
/// shared and `decode(encode(x)) == x` bitwise).
#[test]
fn rejoined_run_is_bitwise_identical_from_rejoin_step_onward() {
    let eng = backend();
    for workers in [2usize, 4, 7] {
        let dir = std::env::temp_dir()
            .join(format!("jorge_ft_rejoin_{}_{workers}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let dir_s = dir.to_str().unwrap().to_string();

        // faulted run: membership shrinks over steps 2..5, rank 1 is
        // readmitted at the step-5 boundary
        let mut c_fault = cfg("jorge_sharded", workers);
        c_fault.faults = "drop@2:r1:grad; rejoin@5:r1".into();
        c_fault.checkpoint_every = 5;
        c_fault.checkpoint_dir = dir_s.clone();
        let mut faulted = Trainer::new(c_fault, eng.clone()).unwrap();
        let r_fault = faulted.run().unwrap();
        assert_eq!(r_fault.step_losses.len(), 16);
        assert_eq!(r_fault.faults.as_ref().unwrap().rejoins, 1, "workers={workers}");

        // reference run: full membership, no faults, resumed from the
        // step-5 checkpoint (= the resync state)
        let mut c_ref = cfg("jorge_sharded", workers);
        c_ref.resume = checkpoint::step_path(&dir_s, 5).to_str().unwrap().to_string();
        let mut reference = Trainer::new(c_ref, eng.clone()).unwrap();
        let r_ref = reference.run().unwrap();
        assert_eq!(r_ref.step_losses.len(), 11, "reference reruns steps 5..16");

        // losses from the rejoin step onward are bitwise equal
        for (i, (a, b)) in r_fault.step_losses[5..].iter().zip(&r_ref.step_losses).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "workers={workers}: loss diverged at step {}",
                5 + i
            );
        }

        // params and optimizer state are bitwise equal at the end
        for (a, b) in faulted.params.iter().zip(&reference.params) {
            let (a, b) = (a.as_f32().unwrap(), b.as_f32().unwrap());
            assert!(
                a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "workers={workers}: params diverged"
            );
        }
        for (a, b) in faulted.opt_state.iter().zip(&reference.opt_state) {
            let (a, b) = (a.as_f32().unwrap(), b.as_f32().unwrap());
            assert!(
                a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "workers={workers}: optimizer state diverged"
            );
        }
        // ...including the native mirror's preconditioners: the full
        // serialized state must match byte for byte
        let ckpt_a = dir.join("final_fault.ckpt");
        let ckpt_b = dir.join("final_ref.ckpt");
        faulted.save_checkpoint(ckpt_a.to_str().unwrap()).unwrap();
        reference.save_checkpoint(ckpt_b.to_str().unwrap()).unwrap();
        assert_eq!(
            std::fs::read(&ckpt_a).unwrap(),
            std::fs::read(&ckpt_b).unwrap(),
            "workers={workers}: serialized end states differ"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Satellite property test: ~50 random seeded fault plans — drops,
/// delays, corruptions, and rejoins over random steps, ranks, and ops
/// (including `:eval`) — must each either complete with finite
/// surviving-rank losses or fail with a typed error. A panic anywhere
/// fails the trial with the offending plan in the message. The seed is
/// pinned (override with `JORGE_FUZZ_SEED`) so CI failures reproduce.
#[test]
fn fuzz_random_fault_plans_never_panic() {
    use jorge::rngx::Rng;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let seed: u64 = std::env::var("JORGE_FUZZ_SEED")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(20240817);
    let mut rng = Rng::new(seed);
    let ops = ["grad", "precond", "eval"];
    for trial in 0..50 {
        let workers = 2 + rng.below(3) as usize; // 2..=4
        let n_events = 1 + rng.below(4) as usize;
        let mut events: Vec<String> = Vec::new();
        // (step, rank) pairs of generated drops, so most rejoins can be
        // paired into plans that pass static validation and exercise
        // the readmission barrier rather than just the config error
        let mut drops: Vec<(usize, usize)> = Vec::new();
        for _ in 0..n_events {
            let step = rng.below(10) as usize;
            let rank = rng.below(workers as u64) as usize;
            let op = ops[rng.below(3) as usize];
            let tok = match rng.below(4) {
                0 => {
                    drops.push((step, rank));
                    format!("drop@{step}:r{rank}:{op}")
                }
                1 => format!("delay@{step}:r{rank}:{op}:x{}", 1 + rng.below(5)),
                2 => format!("corrupt@{step}:r{rank}:{op}"),
                _ => match drops.pop() {
                    Some((s, r)) => format!("rejoin@{}:r{r}", s + 1 + rng.below(6) as usize),
                    // unpaired rejoin: Trainer::new must reject it with
                    // a typed error, not panic
                    None => format!("rejoin@{step}:r{rank}"),
                },
            };
            events.push(tok);
        }
        let spec = events.join(";");
        let opt = if rng.below(2) == 0 { "jorge_sharded" } else { "jorge" };
        let mut c = cfg(opt, workers);
        c.epochs = 1;
        c.steps_per_epoch = 6;
        c.faults = spec.clone();
        c.fault_seed = rng.below(1 << 20);
        // randomly defer the preconditioner exchange (sharded-only, so
        // config validation passes): drops and rejoins landing during a
        // deferred exchange must stay panic-free and typed too
        c.precond_overlap = opt.ends_with("_sharded") && rng.below(2) == 0;
        let outcome = catch_unwind(AssertUnwindSafe(|| -> Result<Vec<f32>, String> {
            let mut t = Trainer::new(c, backend()).map_err(|e| e.to_string())?;
            let r = t.run().map_err(|e| e.to_string())?;
            Ok(r.step_losses)
        }));
        match outcome {
            Ok(Ok(losses)) => assert!(
                losses.iter().all(|l| l.is_finite()),
                "trial {trial} (seed {seed}) plan `{spec}`: non-finite surviving loss"
            ),
            Ok(Err(err)) => assert!(
                !err.is_empty(),
                "trial {trial} (seed {seed}) plan `{spec}`: empty error message"
            ),
            Err(_) => panic!("trial {trial} (seed {seed}): plan `{spec}` panicked"),
        }
    }
}
