//! Coordinator integration: full training runs, determinism,
//! data-parallel equivalence, checkpoint round-trips, failure injection.
//!
//! Everything here executes end-to-end through the pure-Rust
//! `NativeBackend` — no artifacts, no skipping. The artifact-vs-native
//! agreement suites live in `cross_validation.rs` behind the `pjrt`
//! feature.

use jorge::config::{ScheduleKind, TrainConfig};
use jorge::coordinator::Trainer;
use jorge::runtime::{backend_for, ExecBackend, NativeBackend};
use std::sync::Arc;

fn backend() -> Arc<dyn ExecBackend> {
    Arc::new(NativeBackend::new())
}

fn tiny_cfg(opt: &str, workers: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp".into(),
        optimizer: opt.parse().unwrap(),
        epochs: 2,
        steps_per_epoch: 8,
        lr: 0.01,
        weight_decay: 1e-4,
        schedule: ScheduleKind::Constant,
        precond_every: 2,
        seed: 33,
        workers,
        dataset_size: 64 * 8 * workers.max(1) * 2,
        eval_every_epochs: 1000,
        backend: "native".into(),
        ..Default::default()
    }
}

#[test]
fn training_reduces_loss_all_optimizers() {
    let eng = backend();
    for opt in ["sgd", "adamw", "shampoo", "jorge"] {
        let mut trainer = Trainer::new(tiny_cfg(opt, 1), eng.clone()).unwrap();
        let r = trainer.run().unwrap();
        let first = r.step_losses.first().copied().unwrap() as f64;
        let last = r.epochs.last().unwrap().train_loss;
        assert!(last < first, "{opt}: loss {first} -> {last}");
        assert!(r.epochs.iter().all(|e| e.val_metric.is_finite()));
    }
}

#[test]
fn same_seed_same_trajectory() {
    let eng = backend();
    let r1 = Trainer::new(tiny_cfg("jorge", 1), eng.clone()).unwrap().run().unwrap();
    let r2 = Trainer::new(tiny_cfg("jorge", 1), eng.clone()).unwrap().run().unwrap();
    assert_eq!(r1.step_losses, r2.step_losses);
    let r3 = {
        let mut cfg = tiny_cfg("jorge", 1);
        cfg.seed = 34;
        Trainer::new(cfg, eng).unwrap().run().unwrap()
    };
    assert_ne!(r1.step_losses, r3.step_losses);
}

#[test]
fn data_parallel_runs_and_learns() {
    let eng = backend();
    for workers in [2usize, 4] {
        let mut trainer = Trainer::new(tiny_cfg("jorge", workers), eng.clone()).unwrap();
        let r = trainer.run().unwrap();
        let first = r.step_losses.first().copied().unwrap() as f64;
        let last = r.epochs.last().unwrap().train_loss;
        assert!(last < first, "{workers} workers: {first} -> {last}");
    }
}

#[test]
fn native_flag_matches_backend_apply_trajectory() {
    // data-parallel with the trainer-held native mirror (`--native`) vs
    // the backend's stateless apply step: same seed, same shards =>
    // near-identical loss trajectories. This pins the state round-trip
    // through the apply artifacts' I/O convention.
    let eng = backend();
    let mut cfg_a = tiny_cfg("sgd", 2);
    let mut cfg_n = tiny_cfg("sgd", 2);
    cfg_n.native = true;
    cfg_a.seed = 77;
    cfg_n.seed = 77;
    let ra = Trainer::new(cfg_a, eng.clone()).unwrap().run().unwrap();
    let rn = Trainer::new(cfg_n, eng).unwrap().run().unwrap();
    assert_eq!(ra.step_losses.len(), rn.step_losses.len());
    for (i, (a, n)) in ra.step_losses.iter().zip(&rn.step_losses).enumerate() {
        assert!(
            (a - n).abs() < 1e-3 * a.abs().max(1.0),
            "step {i}: backend-apply {a} vs native-mirror {n}"
        );
    }
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let eng = backend();
    let path = std::env::temp_dir().join(format!("jorge_it_ckpt_{}", std::process::id()));
    let path = path.to_str().unwrap().to_string();

    let mut trainer = Trainer::new(tiny_cfg("jorge", 1), eng.clone()).unwrap();
    trainer.run().unwrap();
    let (loss_before, metric_before) = trainer.evaluate().unwrap();
    trainer.save_checkpoint(&path).unwrap();

    let mut restored = Trainer::new(tiny_cfg("jorge", 1), eng).unwrap();
    let (fresh_loss, _) = restored.evaluate().unwrap();
    restored.load_checkpoint(&path).unwrap();
    let (loss_after, metric_after) = restored.evaluate().unwrap();

    assert!((loss_before - loss_after).abs() < 1e-6);
    assert!((metric_before - metric_after).abs() < 1e-6);
    assert!(fresh_loss > loss_after, "restore had no effect");
    std::fs::remove_file(&path).ok();
}

#[test]
fn checkpoint_rejects_wrong_model() {
    let eng = backend();
    let path = std::env::temp_dir().join(format!("jorge_it_ckpt2_{}", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    let mut trainer = Trainer::new(tiny_cfg("jorge", 1), eng.clone()).unwrap();
    trainer.save_checkpoint(&path).unwrap();

    let mut cfg = tiny_cfg("sgd", 1); // different optimizer => state mismatch
    cfg.model = "mlp".into();
    let mut other = Trainer::new(cfg, eng).unwrap();
    assert!(other.load_checkpoint(&path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn precond_interval_changes_trajectory_but_not_stability() {
    let eng = backend();
    let mut c1 = tiny_cfg("jorge", 1);
    c1.precond_every = 1;
    let mut c8 = tiny_cfg("jorge", 1);
    c8.precond_every = 8;
    let r1 = Trainer::new(c1, eng.clone()).unwrap().run().unwrap();
    let r8 = Trainer::new(c8, eng).unwrap().run().unwrap();
    assert_ne!(r1.step_losses, r8.step_losses);
    assert!(r1.step_losses.iter().all(|l| l.is_finite()));
    assert!(r8.step_losses.iter().all(|l| l.is_finite()));
}

#[test]
fn unknown_artifacts_and_backends_error_cleanly() {
    let eng = backend();
    assert!(eng.load("train_mlp_nonexistent").is_err());
    assert!(eng.load("train_resnet50_sgd").is_err());
    assert!(backend_for("artifacts", "tpu").is_err());
}

#[test]
fn trainer_runs_every_native_model_one_step() {
    // smoke every workload slot through the fused path: one step + eval
    let eng = backend();
    for model in ["mlp", "cnn", "segnet", "transformer"] {
        let mut cfg = tiny_cfg("sgd", 1);
        cfg.model = model.into();
        cfg.epochs = 1;
        cfg.steps_per_epoch = 1;
        cfg.max_steps = 1;
        cfg.dataset_size = 512;
        let mut trainer = Trainer::new(cfg, eng.clone()).unwrap();
        let r = trainer.run().unwrap();
        assert_eq!(r.step_losses.len(), 1, "{model}");
        assert!(r.step_losses[0].is_finite(), "{model}");
        assert!(r.epochs[0].val_loss.is_finite(), "{model}");
    }
}

#[test]
fn config_validation_rejected_before_engine_work() {
    let eng = backend();
    let mut cfg = tiny_cfg("jorge", 1);
    cfg.precond_every = 0;
    assert!(Trainer::new(cfg, eng).is_err());
}

#[cfg(feature = "pjrt")]
mod pjrt_only {
    use jorge::runtime::Engine;

    #[test]
    fn corrupt_artifact_fails_to_load() {
        let dir = std::env::temp_dir().join(format!("jorge_corrupt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // minimal manifest pointing at a garbage HLO file
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "hyper": {}, "models": {},
                "artifacts": {"bad": {"file": "bad.hlo.txt", "kind": "kernel",
                "inputs": [], "outputs": []}}}"#,
        )
        .unwrap();
        std::fs::write(dir.join("bad.hlo.txt"), "this is not HLO at all").unwrap();
        let eng = Engine::new(dir.to_str().unwrap()).unwrap();
        assert!(eng.load("bad").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_artifact_dir_is_error() {
        assert!(Engine::new("/definitely/not/a/dir").is_err());
    }
}
