//! Deferred preconditioner exchange (`--precond-overlap`) contract tests.
//!
//! The overlapped sharded step applies one-refresh-stale preconditioners
//! and lands the gathered import at the next step boundary (async
//! distributed Shampoo). These tests pin that trajectory bitwise against
//! an explicit delayed-import reference loop driven through the same
//! public protocol (`export/import_preconditioners`) at workers
//! ∈ {2, 4, 7}, and cover the telemetry + the workers == 1 downgrade.

use jorge::collectives::ring_all_reduce_mean;
use jorge::config::{ScheduleKind, TrainConfig};
use jorge::coordinator::Trainer;
use jorge::data::{for_model, Sharder};
use jorge::optim::{self, Hyper, OptimizerKind, StepCtx};
use jorge::rngx::Rng;
use jorge::runtime::{ExecBackend, HostTensor, Manifest, NativeBackend, Role};
use jorge::tensor::Matrix;
use std::sync::Arc;

const EVAL_BATCHES: usize = 4;

fn backend() -> Arc<dyn ExecBackend> {
    Arc::new(NativeBackend::new())
}

fn cfg(opt: &str, workers: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp".into(),
        optimizer: opt.parse().unwrap(),
        epochs: 2,
        steps_per_epoch: 6,
        lr: 0.01,
        weight_decay: 1e-4,
        schedule: ScheduleKind::Constant,
        precond_every: 2,
        seed: 91,
        workers,
        dataset_size: 64 * 6 * workers.max(1) * 2,
        eval_every_epochs: 1000,
        backend: "native".into(),
        precond_overlap: true,
        ..Default::default()
    }
}

/// 2-D collapse matching the trainer's native-mirror conversion.
fn to_matrices(tensors: &[HostTensor]) -> Vec<Matrix> {
    tensors
        .iter()
        .map(|t| {
            let sh = t.shape();
            Matrix::from_vec(
                sh.first().copied().unwrap_or(1),
                sh.get(1).copied().unwrap_or(1),
                t.as_f32().unwrap().to_vec(),
            )
        })
        .collect()
}

/// Explicit delayed-import reference: the data-parallel sharded loop
/// rebuilt from the public pieces (sharder, grad executable, ring
/// all-reduce, serial optimizer protocol), with the preconditioner
/// refresh exported to a pending buffer, the mirror reverted to the
/// pre-refresh snapshot for this step's apply, and the buffer imported
/// at the next step boundary — the semantics `--precond-overlap`
/// promises. Returns (step_losses, final param floats).
fn delayed_import_reference(c: &TrainConfig) -> (Vec<f32>, Vec<Vec<f32>>) {
    let eng = backend();
    let kind: OptimizerKind = c.optimizer;
    let train_full = eng.load(&Manifest::train_name(&c.model, kind, true)).unwrap();
    let grad_step = eng.load(&format!("grad_{}", c.model)).unwrap();

    // params + optimizer state init consumes the rng in spec order,
    // exactly as Trainer::new does
    let mut rng = Rng::new(c.seed);
    let mut params: Vec<HostTensor> = Vec::new();
    for spec in &train_full.spec().inputs {
        match spec.role {
            Role::Param => params.push(HostTensor::from_init(spec, &mut rng).unwrap()),
            Role::State => {
                let _ = HostTensor::from_init(spec, &mut rng).unwrap();
            }
            _ => {}
        }
    }

    let shapes: Vec<(usize, usize)> = train_full
        .spec()
        .inputs
        .iter()
        .filter(|s| s.role == Role::Param)
        .map(|s| (s.shape[0], s.shape.get(1).copied().unwrap_or(1)))
        .collect();
    let mut native = optim::build(kind, &shapes, Hyper::default());
    let layers: Vec<usize> =
        (0..native.n_layers()).filter(|&l| native.refresh_flops(l) > 0.0).collect();

    let meta = eng.manifest().models.get(&c.model).unwrap().clone();
    let total_len = c.dataset_size + EVAL_BATCHES * meta.eval_batch;
    let dataset = for_model(&c.model, total_len, c.seed ^ 0xDA7A5E7).unwrap();
    let sharder =
        Sharder { dataset_len: c.dataset_size, workers: c.workers, seed: c.seed ^ 0x5A4D };
    let b = meta.batch;

    let spec = grad_step.spec();
    let xi = spec.input_index(Role::X).unwrap();
    let x_spec = spec.inputs[xi].clone();
    let yi = spec.input_index(Role::Y).unwrap();
    let y_spec = spec.inputs[yi].clone();

    let mut step_losses: Vec<f32> = Vec::new();
    let mut pending: Option<Vec<f32>> = None;
    let mut global_step = 0usize;
    for epoch in 0..c.epochs {
        let shards = sharder.epoch_shards(epoch);
        let steps_this_epoch = (shards[0].len() / b).min(c.steps_per_epoch).max(1);
        for si in 0..steps_this_epoch {
            let update = global_step % c.precond_every == 0;

            // land the previous update step's deferred import at this
            // step's boundary
            if let Some(buf) = pending.take() {
                let used = native.import_preconditioners(&layers, &buf);
                assert_eq!(used, buf.len());
            }

            // per-worker gradients over this step's shard slices
            let mut grads_per_worker: Vec<Vec<HostTensor>> = Vec::new();
            let mut losses: Vec<f64> = Vec::new();
            for sh in &shards {
                let lo = (si * b) % (sh.len() - b + 1);
                let batch = dataset.batch(&sh[lo..lo + b]);
                let x = match x_spec.dtype {
                    jorge::runtime::Dtype::F32 => {
                        HostTensor::from_f32(x_spec.shape.clone(), batch.x_f32)
                    }
                    jorge::runtime::Dtype::I32 => {
                        HostTensor::from_i32(x_spec.shape.clone(), batch.x_i32)
                    }
                };
                let y = HostTensor::from_i32(y_spec.shape.clone(), batch.y);
                let mut inputs: Vec<HostTensor> = params.to_vec();
                inputs.push(x);
                inputs.push(y);
                let mut out = grad_step.run(&inputs).unwrap();
                let _metric = out.pop().unwrap().scalar();
                let loss = out.pop().unwrap().scalar();
                grads_per_worker.push(out);
                losses.push(loss);
            }

            // the same ring reduce the trainer runs
            let mut buffers: Vec<Vec<f32>> = grads_per_worker
                .iter()
                .map(|gs| {
                    let mut flat = Vec::new();
                    for g in gs {
                        flat.extend_from_slice(g.as_f32().unwrap());
                    }
                    flat
                })
                .collect();
            ring_all_reduce_mean(&mut buffers).unwrap();
            let mut red: Vec<HostTensor> = Vec::new();
            let mut off = 0usize;
            for g in &grads_per_worker[0] {
                let n = g.len();
                red.push(HostTensor::from_f32(
                    g.shape().to_vec(),
                    buffers[0][off..off + n].to_vec(),
                ));
                off += n;
            }

            // refresh, then defer the exchange: park the refreshed
            // preconditioners and revert so this apply is one stale
            let mut mats = to_matrices(&params);
            let gmats = to_matrices(&red);
            let stale = update.then(|| native.export_preconditioners(&layers));
            native.refresh_layers(&layers, &gmats, update);
            if update {
                pending = Some(native.export_preconditioners(&layers));
                let st = stale.unwrap();
                let used = native.import_preconditioners(&layers, &st);
                assert_eq!(used, st.len());
            }
            native.apply_update(
                &mut mats,
                &gmats,
                StepCtx {
                    lr: c.lr as f32,
                    weight_decay: c.weight_decay as f32,
                    update_precond: false,
                },
            );
            for (p, m) in params.iter_mut().zip(mats) {
                *p.as_f32_mut().unwrap() = m.data;
            }

            let n = losses.len() as f64;
            step_losses.push((losses.iter().sum::<f64>() / n) as f32);
            global_step += 1;
        }
    }
    let flat: Vec<Vec<f32>> = params.iter().map(|p| p.as_f32().unwrap().to_vec()).collect();
    (step_losses, flat)
}

#[test]
fn overlap_matches_delayed_import_reference() {
    // --precond-overlap must be *exactly* delayed import, not merely
    // close: the trainer's trajectory is pinned bitwise against the
    // reference loop at every worker count, for both sharded optimizers
    let eng = backend();
    for opt in ["jorge_sharded", "shampoo_sharded"] {
        for workers in [2usize, 4, 7] {
            let c = cfg(opt, workers);
            let (ref_losses, ref_params) = delayed_import_reference(&c);
            let mut trainer = Trainer::new(c, eng.clone()).unwrap();
            let r = trainer.run().unwrap();
            assert_eq!(
                r.step_losses, ref_losses,
                "{opt} x{workers} diverged from the delayed-import reference"
            );
            assert_eq!(trainer.params.len(), ref_params.len());
            for (i, (p, q)) in trainer.params.iter().zip(&ref_params).enumerate() {
                let pf = p.as_f32().unwrap();
                assert_eq!(pf.len(), q.len());
                for (a, b) in pf.iter().zip(q) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{opt} x{workers} param {i} diverged bitwise"
                    );
                }
            }
        }
    }
}

#[test]
fn overlap_actually_changes_the_trajectory() {
    // sanity: the stale apply is observable — an overlap run must not be
    // bit-identical to the synchronous exchange
    let eng = backend();
    let overlap = Trainer::new(cfg("jorge_sharded", 2), eng.clone()).unwrap().run().unwrap();
    let mut sync_cfg = cfg("jorge_sharded", 2);
    sync_cfg.precond_overlap = false;
    let sync = Trainer::new(sync_cfg, eng).unwrap().run().unwrap();
    assert_ne!(
        overlap.step_losses, sync.step_losses,
        "overlap run was bit-identical to the synchronous exchange"
    );
}

#[test]
fn overlap_reports_exchange_telemetry() {
    let eng = backend();
    let r = Trainer::new(cfg("jorge_sharded", 4), eng).unwrap().run().unwrap();
    let sh = r.shard.expect("sharded run must produce a ShardReport");
    // 12 steps at precond_every = 2 => 6 update steps, each one a
    // deferred gather applied one refresh stale
    let update_steps = (0..r.step_losses.len()).filter(|s| s % 2 == 0).count();
    assert_eq!(sh.overlap_exchanges, update_steps);
    assert_eq!(sh.stale_applies, update_steps);
    assert_eq!(sh.allgather_calls, update_steps, "overlap must not change the gather count");
    assert!(sh.allgather_floats > 0);
}

#[test]
fn overlap_downgrades_with_a_single_worker() {
    // nothing to defer on one worker: the trainer notes the downgrade
    // and runs the serial path, with no sharding telemetry
    let eng = backend();
    let r = Trainer::new(cfg("jorge_sharded", 1), eng).unwrap().run().unwrap();
    assert!(r.shard.is_none());
    assert_eq!(r.optimizer, "jorge");
}
