//! Property-based tests (mini-proptest `checkers`) over the paper's
//! mathematical invariants and the coordinator substrates.

use jorge::checkers::{check, Config, MatrixGen, PairGen, UsizeGen};
use jorge::collectives::{ring_all_reduce, tree_all_reduce};
use jorge::config::ScheduleKind;
use jorge::optim::Schedule;
use jorge::rngx::Rng;
use jorge::tensor::Matrix;
use jorge::tensor::{dynamic_beta2, gram_left, gram_right, inv_fourth_root_newton, jorge_update};
use jorge::tensor::{matmul, matmul_bias, matmul_bias_relu, matmul_nt, matmul_st, matmul_tn};

fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0x10C0_u64 ^ 0x9E3779B9, max_shrink_iters: 64 }
}

// ---------------------------------------------------------------------------
// Jorge preconditioner invariants (App. A.1)
// ---------------------------------------------------------------------------

#[test]
fn prop_gram_matrices_symmetric_psd() {
    let gen = MatrixGen { max_dim: 12, scale: 2.0 };
    check("gram-sym-psd", cfg(48), &gen, |case| {
        let g = case.to_matrix();
        let s = gram_left(&g);
        for i in 0..s.rows {
            if s.at(i, i) < -1e-4 {
                return Err(format!("negative diagonal {}", s.at(i, i)));
            }
            for j in 0..s.cols {
                if (s.at(i, j) - s.at(j, i)).abs() > 1e-4 {
                    return Err("asymmetric gram".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_jorge_update_finite_and_symmetric_for_any_gradient() {
    let gen = MatrixGen { max_dim: 10, scale: 3.0 };
    check("jorge-update-valid", cfg(48), &gen, |case| {
        let g = case.to_matrix();
        let s = gram_left(&g);
        let p = Matrix::eye(g.rows, (1e-6f32).powf(-0.25));
        let out = jorge_update(&p, &s);
        if !out.all_finite() {
            return Err("non-finite preconditioner".into());
        }
        let asym = out.sub(&out.t()).max_abs() / out.max_abs().max(1e-12);
        if asym > 0.05 {
            return Err(format!("asymmetry {asym}"));
        }
        Ok(())
    });
}

#[test]
fn prop_dynamic_beta2_validates_series() {
    // for any positive statistic norm: beta2 in (0,1) and the series
    // argument norm == 1 at the bound (Eq. 10)
    let gen = UsizeGen { lo: 1, hi: 1_000_000 };
    check("beta2-bound", cfg(64), &gen, |&n| {
        let nx = n as f64 / 100.0;
        let b2 = dynamic_beta2(nx);
        if !(0.0 < b2 && b2 < 1.0) {
            return Err(format!("beta2 {b2}"));
        }
        let arg = (1.0 - b2) / b2 * nx;
        if (arg - 1.0).abs() > 1e-9 {
            return Err(format!("series arg {arg}"));
        }
        Ok(())
    });
}

#[test]
fn prop_newton_root_inverts_spd() {
    let gen = MatrixGen { max_dim: 10, scale: 1.0 };
    check("newton-root", cfg(24), &gen, |case| {
        let g = case.to_matrix();
        let n = g.rows;
        let mut a = gram_left(&g);
        a.scale_inplace(1.0 / n as f32);
        for i in 0..n {
            a.data[i * n + i] += 0.5; // well inside SPD
        }
        let h = inv_fourth_root_newton(&a, 30, 0.0);
        let h2 = matmul(&h, &h);
        let h4 = matmul(&h2, &h2);
        let prod = matmul(&h4, &a);
        let err = prod.max_abs_diff(&Matrix::eye(n, 1.0));
        if err > 0.05 {
            return Err(format!("H^4 A != I (err {err})"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// GEMM kernels: transpose-free variants, fused epilogues, threaded grams
// ---------------------------------------------------------------------------

fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for kk in 0..k {
                acc += a.at(i, kk) as f64 * b.at(kk, j) as f64;
            }
            c.data[i * n + j] = acc as f32;
        }
    }
    c
}

#[test]
fn prop_transpose_free_variants_match_naive() {
    // A @ B via nn, nt (vs B^T), tn (vs A^T) all agree with the f64
    // reference across random odd shapes
    let gen = PairGen(MatrixGen { max_dim: 24, scale: 1.5 }, UsizeGen { lo: 1, hi: 24 });
    check("gemm-variants", cfg(24), &gen, |(ca, n)| {
        let a = ca.to_matrix();
        let mut rng = Rng::new(ca.seed ^ 0xABCD);
        let b = Matrix::randn(a.cols, *n, 1.0, &mut rng);
        let want = naive_matmul(&a, &b);
        let nn = matmul(&a, &b);
        let st = matmul_st(&a, &b);
        let nt = matmul_nt(&a, &b.t());
        let tn = matmul_tn(&a.t(), &b);
        for (name, got) in [("nn", nn), ("st", st), ("nt", nt), ("tn", tn)] {
            let err = got.max_abs_diff(&want);
            if err > 1e-3 {
                return Err(format!("{name} ({},{},{n}): err {err}", a.rows, a.cols));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fused_epilogues_match_unfused() {
    let gen = PairGen(MatrixGen { max_dim: 20, scale: 2.0 }, UsizeGen { lo: 1, hi: 20 });
    check("gemm-epilogue", cfg(24), &gen, |(ca, n)| {
        let a = ca.to_matrix();
        let mut rng = Rng::new(ca.seed ^ 0x77);
        let b = Matrix::randn(a.cols, *n, 1.0, &mut rng);
        let bias = Matrix::randn(*n, 1, 1.0, &mut rng);
        let base = matmul(&a, &b);
        let fused = matmul_bias(&a, &b, &bias);
        let relu = matmul_bias_relu(&a, &b, &bias);
        for i in 0..base.rows {
            for j in 0..*n {
                let want = base.at(i, j) + bias.data[j];
                if (fused.at(i, j) - want).abs() > 1e-4 {
                    return Err(format!("bias ({i},{j})"));
                }
                if (relu.at(i, j) - want.max(0.0)).abs() > 1e-4 {
                    return Err(format!("relu ({i},{j})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_threaded_grams_symmetric_psd_and_match() {
    // dims above the parallel gate so the pooled path is exercised
    let gen = UsizeGen { lo: 130, hi: 190 };
    check("gram-threaded", cfg(4), &gen, |&m| {
        let mut rng = Rng::new(m as u64);
        let g = Matrix::randn(m, 80, 1.0, &mut rng);
        let l = gram_left(&g);
        let r = gram_right(&g.t());
        if l.max_abs_diff(&matmul_st(&g, &g.t())) > 1e-3 {
            return Err("gram_left != G G^T".into());
        }
        if r.max_abs_diff(&l) > 1e-3 {
            return Err("gram_right(G^T) != gram_left(G)".into());
        }
        for i in 0..m {
            if l.at(i, i) < 0.0 {
                return Err(format!("negative diagonal at {i}"));
            }
            for j in 0..m {
                if l.at(i, j) != l.at(j, i) || r.at(i, j) != r.at(j, i) {
                    return Err(format!("asymmetry at ({i},{j})"));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Collectives: any (ranks, length) sums correctly
// ---------------------------------------------------------------------------

#[test]
fn prop_ring_all_reduce_equals_sum() {
    let gen = PairGen(UsizeGen { lo: 1, hi: 9 }, UsizeGen { lo: 0, hi: 300 });
    check("ring-allreduce", cfg(64), &gen, |&(ranks, len)| {
        let mut rng = Rng::new((ranks * 1000 + len) as u64);
        let bufs: Vec<Vec<f32>> = (0..ranks)
            .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let mut want = vec![0.0f32; len];
        for b in &bufs {
            for (w, v) in want.iter_mut().zip(b) {
                *w += v;
            }
        }
        let mut got = bufs.clone();
        ring_all_reduce(&mut got).unwrap();
        for (r, b) in got.iter().enumerate() {
            for i in 0..len {
                if (b[i] - want[i]).abs() > 1e-3 * want[i].abs().max(1.0) {
                    return Err(format!("rank {r} idx {i}: {} vs {}", b[i], want[i]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tree_equals_ring() {
    let gen = PairGen(UsizeGen { lo: 1, hi: 8 }, UsizeGen { lo: 1, hi: 200 });
    check("tree-vs-ring", cfg(48), &gen, |&(ranks, len)| {
        let mut rng = Rng::new((ranks * 31 + len) as u64);
        let bufs: Vec<Vec<f32>> = (0..ranks)
            .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let mut a = bufs.clone();
        let mut b = bufs;
        ring_all_reduce(&mut a).unwrap();
        tree_all_reduce(&mut b).unwrap();
        for (x, y) in a[0].iter().zip(&b[0]) {
            if (x - y).abs() > 1e-3 * x.abs().max(1.0) {
                return Err(format!("{x} vs {y}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Schedules: monotone after warmup for decaying kinds, bounded everywhere
// ---------------------------------------------------------------------------

#[test]
fn prop_schedules_bounded_and_decay_monotone() {
    let gen = PairGen(UsizeGen { lo: 10, hi: 500 }, UsizeGen { lo: 0, hi: 50 });
    check("schedule-bounds", cfg(64), &gen, |&(total, warmup)| {
        for kind in [
            ScheduleKind::Constant,
            ScheduleKind::Step,
            ScheduleKind::Cosine,
            ScheduleKind::Poly,
        ] {
            let s = Schedule::new(kind, 0.4, total, warmup.min(total / 2), &[0.33, 0.66]);
            let mut prev = f64::INFINITY;
            for step in 0..=total {
                let lr = s.lr_at(step);
                if !(0.0..=0.4 + 1e-12).contains(&lr) {
                    return Err(format!("{kind:?}@{step}: lr {lr} out of bounds"));
                }
                if step > s.warmup_steps && lr > prev + 1e-12 && kind != ScheduleKind::Constant
                {
                    return Err(format!("{kind:?}@{step}: lr increased {prev} -> {lr}"));
                }
                if step >= s.warmup_steps {
                    prev = lr;
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Optimizer step invariants across random shapes
// ---------------------------------------------------------------------------

#[test]
fn prop_all_optimizers_keep_params_finite() {
    use jorge::optim::{build, Hyper, StepCtx};
    let gen = PairGen(UsizeGen { lo: 1, hi: 12 }, UsizeGen { lo: 1, hi: 12 });
    check("optims-finite", cfg(24), &gen, |&(m, n)| {
        let shapes = [(m, n), (n.max(1), 1)];
        for opt_name in ["sgd", "adamw", "shampoo", "jorge"] {
            let mut opt = build(opt_name.parse().unwrap(), &shapes, Hyper::default());
            let mut rng = Rng::new((m * 100 + n) as u64);
            let mut params: Vec<Matrix> = shapes
                .iter()
                .map(|&(a, b)| Matrix::randn(a, b, 1.0, &mut rng))
                .collect();
            for step in 0..5 {
                let grads: Vec<Matrix> = shapes
                    .iter()
                    .map(|&(a, b)| Matrix::randn(a, b, 0.5, &mut rng))
                    .collect();
                opt.step(
                    &mut params,
                    &grads,
                    StepCtx { lr: 0.05, weight_decay: 1e-3, update_precond: step % 2 == 0 },
                );
                for p in &params {
                    if !p.all_finite() {
                        return Err(format!("{opt_name} produced non-finite params"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_grafting_magnitude_equals_sgd_on_first_step() {
    use jorge::optim::{build, Hyper, StepCtx};
    let gen = PairGen(UsizeGen { lo: 2, hi: 12 }, UsizeGen { lo: 2, hi: 12 });
    check("grafting-magnitude", cfg(24), &gen, |&(m, n)| {
        let shapes = [(m, n)];
        let mut rng = Rng::new((m * 37 + n) as u64);
        let params0: Vec<Matrix> = vec![Matrix::randn(m, n, 1.0, &mut rng)];
        let grads: Vec<Matrix> = vec![Matrix::randn(m, n, 0.2, &mut rng)];
        for opt_name in ["shampoo", "jorge"] {
            let mut opt = build(opt_name.parse().unwrap(), &shapes, Hyper::default());
            let mut params = params0.clone();
            opt.step(
                &mut params,
                &grads,
                StepCtx { lr: 0.05, weight_decay: 0.0, update_precond: true },
            );
            let step_norm = params[0].sub(&params0[0]).frobenius();
            let want = 0.05 * grads[0].frobenius();
            if (step_norm - want).abs() / want > 1e-3 {
                return Err(format!("{opt_name}: {step_norm} vs {want}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Data pipeline invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_sharder_partitions_for_any_workers() {
    use jorge::data::Sharder;
    let gen = PairGen(UsizeGen { lo: 1, hi: 8 }, UsizeGen { lo: 8, hi: 400 });
    check("sharder-partition", cfg(64), &gen, |&(workers, len)| {
        let s = Sharder { dataset_len: len, workers, seed: 9 };
        let shards = s.epoch_shards(3);
        if shards.len() != workers {
            return Err("wrong shard count".into());
        }
        let per = len / workers;
        let mut seen = std::collections::BTreeSet::new();
        for sh in &shards {
            if sh.len() != per {
                return Err(format!("ragged shard {} != {per}", sh.len()));
            }
            for &i in sh {
                if i >= len || !seen.insert(i) {
                    return Err(format!("duplicate or oob index {i}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_datasets_deterministic_and_in_range() {
    use jorge::data::for_model;
    let gen = UsizeGen { lo: 0, hi: 500 };
    check("dataset-determinism", cfg(32), &gen, |&idx| {
        for model in ["mlp", "cnn", "segnet", "transformer"] {
            let d1 = for_model(model, 1000, 5).unwrap();
            let d2 = for_model(model, 1000, 5).unwrap();
            let b1 = d1.batch(&[idx]);
            let b2 = d2.batch(&[idx]);
            if b1.x_f32 != b2.x_f32 || b1.x_i32 != b2.x_i32 || b1.y != b2.y {
                return Err(format!("{model}: non-deterministic sample {idx}"));
            }
            let max_class = match model {
                "mlp" | "cnn" => 10,
                "segnet" => 8,
                _ => 512,
            };
            if b1.y.iter().any(|&y| y < 0 || y >= max_class) {
                return Err(format!("{model}: label out of range"));
            }
        }
        Ok(())
    });
}
