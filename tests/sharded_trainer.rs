//! Sharded-optimizer coordinator tests: the trajectory contract (bitwise
//! identity to the serial optimizers at any worker count), the
//! owner-computes partition, and the all-gather telemetry.

use jorge::config::{ScheduleKind, ShardPolicy, TrainConfig};
use jorge::coordinator::{assign_owners, Trainer};
use jorge::runtime::{ExecBackend, NativeBackend};
use std::sync::Arc;

fn backend() -> Arc<dyn ExecBackend> {
    Arc::new(NativeBackend::new())
}

fn cfg(opt: &str, workers: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp".into(),
        optimizer: opt.parse().unwrap(),
        epochs: 2,
        steps_per_epoch: 6,
        lr: 0.01,
        weight_decay: 1e-4,
        schedule: ScheduleKind::Constant,
        precond_every: 2,
        seed: 91,
        workers,
        dataset_size: 64 * 6 * workers.max(1) * 2,
        eval_every_epochs: 1000,
        backend: "native".into(),
        ..Default::default()
    }
}

#[test]
fn sharded_is_bitwise_identical_to_serial() {
    // Sharding moves refresh work between workers, never the math: for
    // every worker count the sharded run must land on exactly the floats
    // the serial optimizer produces.
    let eng = backend();
    for opt in ["shampoo", "jorge"] {
        for workers in [1usize, 2, 4, 7] {
            let mut serial = cfg(opt, workers);
            serial.native = workers > 1; // same apply path as the sharded run
            let rs = Trainer::new(serial, eng.clone()).unwrap().run().unwrap();
            let rx = Trainer::new(cfg(&format!("{opt}_sharded"), workers), eng.clone())
                .unwrap()
                .run()
                .unwrap();
            assert_eq!(rs.step_losses, rx.step_losses, "{opt} x{workers} losses diverged");
            for (a, b) in rs.epochs.iter().zip(&rx.epochs) {
                assert_eq!(
                    a.val_metric.to_bits(),
                    b.val_metric.to_bits(),
                    "{opt} x{workers} val diverged"
                );
            }
        }
    }
}

#[test]
fn refreshes_are_partitioned_across_workers() {
    let eng = backend();
    let r = Trainer::new(cfg("jorge_sharded", 4), eng).unwrap().run().unwrap();
    let sh = r.shard.expect("sharded run must produce a ShardReport");
    assert_eq!(sh.workers, 4);
    assert_eq!(sh.owned_layers.len(), 4);

    // mlp has exactly 3 preconditioned layers (the weight matrices);
    // biases carry no preconditioner and stay unowned
    let total_owned: usize = sh.owned_layers.iter().map(|l| l.len()).sum();
    assert_eq!(total_owned, 3);
    // each worker owns a strict subset, spread over >= 2 workers
    assert!(sh.owned_layers.iter().all(|l| l.len() < total_owned));
    assert!(sh.owned_layers.iter().filter(|l| !l.is_empty()).count() >= 2);
    // ownership is disjoint
    let mut all = sh.owned_layers.concat();
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len(), total_owned);

    // 12 steps at precond_every = 2 => 6 update steps; one all-gather
    // each, and every preconditioned layer refreshed exactly once per
    let update_steps = (0..r.step_losses.len()).filter(|s| s % 2 == 0).count();
    assert_eq!(sh.allgather_calls, update_steps);
    assert_eq!(sh.refresh_events.iter().sum::<usize>(), total_owned * update_steps);
    assert!(sh.allgather_floats > 0);
    assert!(sh.modeled_comm_s > 0.0, "all-gather traffic must be charged to the cost model");
}

#[test]
fn workers_one_downgrades_to_serial() {
    // nothing to shard on a single worker: the trainer logs a note and
    // runs the serial base optimizer
    let eng = backend();
    let r = Trainer::new(cfg("shampoo_sharded", 1), eng.clone()).unwrap().run().unwrap();
    assert!(r.shard.is_none());
    assert_eq!(r.optimizer, "shampoo");
    // serial kinds never report sharding telemetry
    let r2 = Trainer::new(cfg("jorge", 2), eng).unwrap().run().unwrap();
    assert!(r2.shard.is_none());
}

#[test]
fn shard_policy_changes_ownership_not_trajectory() {
    let eng = backend();
    let r1 = Trainer::new(cfg("jorge_sharded", 2), eng.clone()).unwrap().run().unwrap();
    let mut c = cfg("jorge_sharded", 2);
    c.shard_policy = ShardPolicy::RoundRobin;
    let r2 = Trainer::new(c, eng).unwrap().run().unwrap();
    assert_eq!(r1.step_losses, r2.step_losses);
}

#[test]
fn owner_assignment_is_balanced_and_deterministic() {
    let costs = [8.0, 0.0, 5.0, 4.0, 3.0];
    let a = assign_owners(&costs, 2, ShardPolicy::Flops);
    assert_eq!(a, assign_owners(&costs, 2, ShardPolicy::Flops));
    // LPT trace: 8 -> w0; 5 -> w1; 4 -> w1 (load 5 < 8); 3 -> w0
    assert_eq!(a, vec![Some(0), None, Some(1), Some(1), Some(0)]);
    // round-robin deals preconditioned layers in index order
    let rr = assign_owners(&costs, 3, ShardPolicy::RoundRobin);
    assert_eq!(rr, vec![Some(0), None, Some(1), Some(2), Some(0)]);
    // a single worker owns every preconditioned layer
    let one = assign_owners(&costs, 1, ShardPolicy::Flops);
    assert!(one.iter().enumerate().all(|(i, o)| (costs[i] == 0.0) == o.is_none()));
    assert!(one.iter().flatten().all(|&w| w == 0));
}
