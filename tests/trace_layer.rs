//! Observability layer: phase traces account for step wall-clock, the
//! JSONL trace parses and covers the expected phases, and tracing-off
//! runs are bitwise identical to traced runs.
//!
//! The trace registry is process-global, so every test here serialises
//! on `TEST_LOCK` (cargo runs test fns on parallel threads).

use jorge::config::{ScheduleKind, TrainConfig};
use jorge::coordinator::Trainer;
use jorge::jsonio::Json;
use jorge::runtime::{ExecBackend, NativeBackend};
use jorge::trace::{self, Phase};
use std::sync::{Arc, Mutex};

static TEST_LOCK: Mutex<()> = Mutex::new(());

fn backend() -> Arc<dyn ExecBackend> {
    Arc::new(NativeBackend::new())
}

fn tiny_cfg(opt: &str, workers: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp".into(),
        optimizer: opt.parse().unwrap(),
        epochs: 2,
        steps_per_epoch: 15,
        lr: 0.01,
        weight_decay: 1e-4,
        schedule: ScheduleKind::Constant,
        precond_every: 2,
        seed: 33,
        workers,
        dataset_size: 64 * 15 * workers.max(1) * 2,
        eval_every_epochs: 1000,
        backend: "native".into(),
        ..Default::default()
    }
}

fn tmp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("jorge_trace_{tag}_{}.jsonl", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

#[test]
fn fused_phase_sum_accounts_for_step_time_and_jsonl_parses() {
    let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let eng = backend();
    let path = tmp_path("fused");
    let mut cfg = tiny_cfg("jorge", 1);
    cfg.trace_path = path.clone();
    let mut trainer = Trainer::new(cfg, eng).unwrap();
    let r = trainer.run().unwrap();
    assert!(!trace::enabled(), "trainer must disarm tracing it armed itself");

    let report = r.metrics.expect("traced run returns a metrics report");

    // the fully-sequential fused path (workers == 1) is the one place
    // phase totals must reconcile with wall-clock: everything a training
    // step does lands in Data/Forward/Backward/Apply. Eval and
    // Checkpoint fall outside the per-step timer, so exclude them.
    let step_sum = report.phase_total_s(Phase::Data)
        + report.phase_total_s(Phase::Forward)
        + report.phase_total_s(Phase::Backward)
        + report.phase_total_s(Phase::Apply);
    let wall = report.gauge("step_total_s").expect("step_total_s gauge");
    assert!(wall > 0.0, "no measured step time");
    let frac = step_sum / wall;
    assert!(
        (0.95..=1.05).contains(&frac),
        "phase sum {step_sum:.6}s vs step wall-clock {wall:.6}s ({:.1}% accounted)",
        100.0 * frac
    );
    // eval ran once per epoch and was captured in its own phase
    assert!(report.phase_total_s(Phase::Eval) > 0.0, "eval phase missing");
    // the GEMM dispatch counters were folded into the same registry
    assert!(
        report.counter("pool.jobs") + report.counter("pool.inline_jobs") > 0,
        "pool dispatch counters missing: {report}"
    );

    // every JSONL line parses; events cover run_start, per-step rows
    // with the fused phases, and a final summary
    let text = std::fs::read_to_string(&path).unwrap();
    let events: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(events[0].get("event").and_then(Json::as_str), Some("run_start"));
    let steps: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("event").and_then(Json::as_str) == Some("step"))
        .collect();
    assert_eq!(steps.len(), r.step_losses.len(), "one trace row per training step");
    for ev in &steps {
        assert!(ev.get("wall_s").and_then(Json::as_f64).unwrap() > 0.0);
        let phases = ev.get("phases").expect("phases object");
        for name in ["data", "forward", "backward", "apply"] {
            assert!(
                phases.get(name).and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
                "step row missing phase {name}: {ev:?}"
            );
        }
    }
    let last = events.last().unwrap();
    assert_eq!(last.get("event").and_then(Json::as_str), Some("summary"));
    let metrics = last.get("metrics").expect("summary metrics");
    assert!(matches!(metrics.get("phases"), Some(Json::Arr(rows)) if !rows.is_empty()));
    std::fs::remove_file(&path).ok();
}

#[test]
fn data_parallel_trace_covers_reduce_phase() {
    let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let eng = backend();
    let path = tmp_path("dp");
    let mut cfg = tiny_cfg("jorge", 2);
    cfg.epochs = 1;
    cfg.trace_path = path.clone();
    let r = Trainer::new(cfg, eng).unwrap().run().unwrap();
    let report = r.metrics.expect("traced run returns a metrics report");
    for phase in [Phase::Data, Phase::Forward, Phase::Backward, Phase::GradReduce, Phase::Apply] {
        assert!(
            report.phase_total_s(phase) > 0.0,
            "data-parallel run missing phase {}: {report}",
            phase.name()
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn disabled_tracing_is_bitwise_identical() {
    let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let eng = backend();
    let plain = Trainer::new(tiny_cfg("jorge", 1), eng.clone()).unwrap().run().unwrap();
    assert!(plain.metrics.is_none(), "untraced run must not build a report");

    let path = tmp_path("bitwise");
    let mut cfg = tiny_cfg("jorge", 1);
    cfg.trace_path = path.clone();
    let traced = Trainer::new(cfg, eng).unwrap().run().unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(plain.step_losses, traced.step_losses, "tracing perturbed the trajectory");
    for (a, b) in plain.epochs.iter().zip(&traced.epochs) {
        assert_eq!(a.val_loss.to_bits(), b.val_loss.to_bits());
        assert_eq!(a.val_metric.to_bits(), b.val_metric.to_bits());
    }
}
