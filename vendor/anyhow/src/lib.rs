//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network and no registry cache, so the
//! workspace vendors the small slice of anyhow's API the codebase uses:
//! [`Error`], [`Result`], the [`anyhow!`] macro and the [`Context`]
//! extension trait. Error values carry a flattened message (source chains
//! are folded into the string at conversion time), which is all the
//! coordinator and CLI ever do with them.

use std::fmt;

/// A flattened, `Send + Sync` error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend a context layer, mirroring `anyhow::Error::context`.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes the blanket `From` below
// coherent alongside the reflexive `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`, mirroring anyhow's.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
}

/// `return Err(anyhow!(...))`, mirroring anyhow's `bail!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("got {n} of {}", 7);
        assert_eq!(b.to_string(), "got 3 of 7");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn question_mark_passes_through_anyhow_errors() {
        fn inner() -> Result<()> {
            Err(anyhow!("already anyhow"))
        }
        fn outer() -> Result<()> {
            inner()?;
            Ok(())
        }
        assert_eq!(outer().unwrap_err().to_string(), "already anyhow");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening state").unwrap_err();
        assert_eq!(e.to_string(), "opening state: disk on fire");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn alternate_display_matches_plain() {
        let e = anyhow!("boom");
        assert_eq!(format!("{e:#}"), format!("{e}"));
    }
}
